//! Quickstart: load the AOT artifacts, run one fused MHA forward+backward
//! through PJRT, verify against the pure-Rust oracle, and print the I/O
//! story that motivates the paper.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::{Context, Result};
use sparkattention::attention::{self, AttnParams};
use sparkattention::exec::Scalar;
use sparkattention::iomodel::{self, MhaShape};
use sparkattention::runtime::{Engine, HostValue};
use sparkattention::tensor::{Rng, Tensor};

fn main() -> Result<()> {
    sparkattention::logging::init();
    let dir = std::env::var("SPARK_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let engine = Engine::new(&dir)
        .context("run `make artifacts` first")?;
    println!("platform: {} ({} artifacts)\n",
             engine.platform(), engine.manifest().len());

    // --- fused forward -----------------------------------------------------
    let name = "mha_fwd_fused_f32_d64_n256_bh2_c0_p0";
    let (bh, n, d) = (2usize, 256usize, 64usize);
    println!("1. fused MHA forward ({name})");
    let mut rng = Rng::new(1);
    let q = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let k = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let v = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let seed = HostValue::scalar_f32(0.0);
    let fwd = engine.execute(name, &[
        seed.clone(), HostValue::from_tensor(&q),
        HostValue::from_tensor(&k), HostValue::from_tensor(&v),
    ])?;
    let o_dev = fwd[0].as_tensor()?;

    let oracle = attention::mha_forward(&q, &k, &v,
                                        AttnParams::new(d, false), &Scalar);
    println!("   device vs oracle: max |Δ| = {:.5}  (bf16 regime)\n",
             o_dev.max_abs_diff(&oracle.output));

    // --- fused backward (recomputation) ------------------------------------
    let bwd_name = "mha_bwd_fused_f32_d64_n256_bh2_c0_p0";
    println!("2. fused MHA backward with recomputation ({bwd_name})");
    let dout = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let grads = engine.execute(bwd_name, &[
        seed, HostValue::from_tensor(&q), HostValue::from_tensor(&k),
        HostValue::from_tensor(&v), fwd[0].clone(), fwd[1].clone(),
        HostValue::from_tensor(&dout),
    ])?;
    let g_oracle = attention::mha_backward(
        &q, &k, &v, &dout, AttnParams::new(d, false), &Scalar);
    for (hv, (oracle, nm)) in grads.iter().zip([
        (&g_oracle.dq, "dq"), (&g_oracle.dk, "dk"), (&g_oracle.dv, "dv"),
    ]) {
        println!("   {nm}: max |Δ| = {:.5}",
                 hv.as_tensor()?.max_abs_diff(oracle));
    }

    // --- why fusion matters -------------------------------------------------
    println!("\n3. the I/O story (paper §2.3 / §3.2), at this shape:");
    let s = MhaShape::new(bh, n, d);
    let u = iomodel::analytic_unfused_fwd(s);
    let f = iomodel::analytic_fused_fwd(s);
    println!("   unfused: {} tensor reads / {} writes, {:>9} bytes",
             u.tensor_reads, u.tensor_writes, u.total_bytes());
    println!("   fused:   {} tensor reads / {} writes, {:>9} bytes  \
              ({:.1}× less traffic)",
             f.tensor_reads, f.tensor_writes, f.total_bytes(),
             u.total_bytes() as f64 / f.total_bytes() as f64);

    let st = engine.stats();
    println!("\nengine: {} compiles ({:.0} ms), {} executions",
             st.compiles, st.compile_ms, st.executions);
    Ok(())
}
