//! Quickstart: tour the host attention path (oracle forward, streaming
//! witness, execution backends incl. the mixed-precision TCU emulation),
//! then — when the AOT artifacts are present — run one fused MHA
//! forward+backward through PJRT and verify it against the oracle.
//!
//! ```bash
//! cargo run --release --example quickstart          # host path only
//! make artifacts && cargo run --release --example quickstart  # + device
//! ```

use anyhow::{Context, Result};
use sparkattention::attention::{self, AttnParams};
use sparkattention::exec::{self, Scalar};
use sparkattention::iomodel::{self, MhaShape};
use sparkattention::runtime::{Engine, HostValue};
use sparkattention::tensor::{Rng, Tensor};

fn main() -> Result<()> {
    sparkattention::logging::init();
    let (bh, n, d) = (2usize, 256usize, 64usize);
    let mut rng = Rng::new(1);
    let q = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let k = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let v = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let p = AttnParams::new(d, false)?;

    // --- host path: oracle, streaming witness, backends --------------------
    println!("1. host attention path (no artifacts needed)");
    let oracle = attention::mha_forward(&q, &k, &v, &p, &Scalar);
    let stream = attention::mha_forward_streaming(&q, &k, &v, &p, 64, 64,
                                                  &Scalar);
    println!("   streaming witness vs oracle: max |Δ| = {:.6}",
             stream.output.max_abs_diff(&oracle.output));
    // structured masks ride the same entry points: a sliding-window
    // mask streams only the live tile band (see DESIGN.md §mask)
    let pw = AttnParams::with_mask(
        d, attention::Mask::SlidingWindow { w: 64 })?;
    let win = attention::mha_forward_streaming(&q, &k, &v, &pw, 64, 64,
                                               &Scalar);
    let tiles = pw.mask.tile_counts(n, 64, 64);
    println!("   sliding-window w=64: {} live / {} skipped tiles, \
              output[0,0,0] = {:.4}",
             tiles.live, tiles.skipped, win.output.at(&[0, 0, 0]));
    for be in exec::roster(exec::ExecOptions::default()) {
        let got = attention::mha_forward(&q, &k, &v, &p, be.as_ref());
        println!("   backend {:<16} max |Δ| vs scalar = {:.6}  \
                  (max ulp {})",
                 be.name(), got.output.max_abs_diff(&oracle.output),
                 got.output.max_ulp_diff(&oracle.output));
    }

    // --- why fusion matters -------------------------------------------------
    println!("\n2. the I/O story (paper §2.3 / §3.2), at this shape:");
    let s = MhaShape::new(bh, n, d);
    let u = iomodel::analytic_unfused_fwd(s);
    let f = iomodel::analytic_fused_fwd(s);
    println!("   unfused: {} tensor reads / {} writes, {:>9} bytes",
             u.tensor_reads, u.tensor_writes, u.total_bytes());
    println!("   fused:   {} tensor reads / {} writes, {:>9} bytes  \
              ({:.1}× less traffic)",
             f.tensor_reads, f.tensor_writes, f.total_bytes(),
             u.total_bytes() as f64 / f.total_bytes() as f64);

    // --- device artifacts (optional) ----------------------------------------
    let dir = std::env::var("SPARK_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        println!("\n(no artifacts at {dir}; run `make artifacts` for the \
                  device sections)");
        return Ok(());
    }
    // artifacts exist: a load failure here is a real error, not a skip
    let engine = Engine::new(&dir)
        .with_context(|| format!("loading artifacts at {dir}"))?;
    println!("\nplatform: {} ({} artifacts)",
             engine.platform(), engine.manifest().len());

    let name = "mha_fwd_fused_f32_d64_n256_bh2_c0_p0";
    println!("3. fused MHA forward ({name})");
    let seed = HostValue::scalar_f32(0.0);
    let fwd = engine.execute(name, &[
        seed.clone(), HostValue::from_tensor(&q),
        HostValue::from_tensor(&k), HostValue::from_tensor(&v),
    ])?;
    let o_dev = fwd[0].as_tensor()?;
    println!("   device vs oracle: max |Δ| = {:.5}  (bf16 regime)\n",
             o_dev.max_abs_diff(&oracle.output));

    let bwd_name = "mha_bwd_fused_f32_d64_n256_bh2_c0_p0";
    println!("4. fused MHA backward with recomputation ({bwd_name})");
    let dout = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let grads = engine.execute(bwd_name, &[
        seed, HostValue::from_tensor(&q), HostValue::from_tensor(&k),
        HostValue::from_tensor(&v), fwd[0].clone(), fwd[1].clone(),
        HostValue::from_tensor(&dout),
    ])?;
    let g_oracle = attention::mha_backward(&q, &k, &v, &dout, &p, &Scalar);
    for (hv, (oracle, nm)) in grads.iter().zip([
        (&g_oracle.dq, "dq"), (&g_oracle.dk, "dk"), (&g_oracle.dv, "dv"),
    ]) {
        println!("   {nm}: max |Δ| = {:.5}",
                 hv.as_tensor()?.max_abs_diff(oracle));
    }

    let st = engine.stats();
    println!("\nengine: {} compiles ({:.0} ms), {} executions",
             st.compiles, st.compile_ms, st.executions);
    Ok(())
}
