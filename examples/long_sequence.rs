//! Long-sequence scenario — the paper's motivating workload (§1, §4.2.1):
//! as N grows, the unfused baseline's N×N tensors exhaust device memory
//! while the fused kernel's footprint stays operand-sized.
//!
//! Prints a Fig-12-style admission table from the memory model (including
//! the paper-scale n=16384 point), then *executes* the longest sequences
//! that fit the host budget to show the fused path actually running where
//! the baseline cannot.
//!
//! ```bash
//! make artifacts && cargo run --release --example long_sequence
//! ```

use anyhow::{Context, Result};
use sparkattention::coordinator::inputs::synth_inputs;
use sparkattention::iomodel::{self, MhaShape};
use sparkattention::perfmodel;
use sparkattention::runtime::Engine;

fn main() -> Result<()> {
    sparkattention::logging::init();
    let dir = std::env::var("SPARK_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let engine = Engine::new(&dir).context("run `make artifacts` first")?;

    // --- 1. admission table at paper scale (V100 32 GB) --------------------
    println!("V100-32GB admission at paper scale (batch=16384/n, \
              heads=2048/d, d=64):");
    println!("{:>7} {:>14} {:>14}  {}", "n", "unfused_peak", "fused_peak",
             "verdict");
    let cap = perfmodel::V100.hbm_capacity;
    for n in [512usize, 1024, 2048, 4096, 8192, 16384] {
        let s = perfmodel::paper_shape(n, 64);
        let up = iomodel::peak_resident_bytes(s, false);
        let fp = iomodel::peak_resident_bytes(s, true);
        let gb = |b: usize| format!("{:.2} GiB", b as f64 / (1 << 30) as f64);
        let verdict = match (up > cap, fp > cap) {
            (false, false) => "both run",
            (true, false) => "PyTorch OOM — SparkAttention runs",
            _ => "both OOM",
        };
        println!("{n:>7} {:>14} {:>14}  {verdict}", gb(up), gb(fp));
    }

    // --- 2. actually run the longest standard artifacts --------------------
    println!("\nexecuting the longest built artifacts (host CPU):");
    let mut fused: Vec<_> = engine.manifest().of_kind("mha_fwd")
        .filter(|m| m.attr_str("acc") == Some("f32")
                && m.attr_bool("causal") == Some(false)
                && m.attr_i64("d") == Some(64))
        .cloned().collect();
    fused.sort_by_key(|m| m.attr_i64("n").unwrap_or(0));
    for meta in fused.iter().rev().take(1) {
        let n = meta.attr_i64("n").unwrap_or(0);
        let bh = meta.attr_i64("bh").unwrap_or(0) as usize;
        let ins = synth_inputs(meta, 1)?;
        let (out, secs) = engine.execute_timed(&meta.name, &ins)?;
        println!("  fused   n={n:<6} ok in {:7.1} ms  (|o|₀₀ = {:.4})",
                 secs * 1e3, out[0].as_f32_slice()?[0]);
        // the matching unfused artifact moves N×N through memory
        if let Some(unf) = engine.manifest().of_kind("mha_fwd_unf").find(
            |u| u.attr_i64("n") == meta.attr_i64("n")
                && u.attr_i64("d") == meta.attr_i64("d")
                && u.attr_bool("causal") == Some(false)) {
            let shape = MhaShape::new(bh, n as usize, 64);
            let peak = iomodel::peak_resident_bytes(shape, false);
            println!("  unfused n={n:<6} materialises {:.1} MiB of N×N \
                      intermediates…", (2 * shape.score_bytes()) as f64
                      / (1 << 20) as f64);
            let uins = synth_inputs(unf, 1)?;
            let (_, usecs) = engine.execute_timed(&unf.name, &uins)?;
            println!("  unfused n={n:<6} ok in {:7.1} ms  \
                      ({:.2}× slower; peak {:.1} MiB)",
                     usecs * 1e3, usecs / secs,
                     peak as f64 / (1 << 20) as f64);
        }
    }

    println!("\nconclusion: the fused schedule is what makes n = 16384 \
              feasible at all — exactly Fig 10's OOM row.");
    Ok(())
}
