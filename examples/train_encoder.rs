//! End-to-end driver (experiment E7): train a byte-level transformer LM —
//! whose attention runs through the SparkAttention fused kernels, forward
//! *and* backward — on a synthetic structured corpus, and log the loss
//! curve.  All compute is the AOT `train_step` HLO; Python is not involved.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_encoder -- [steps]
//! ```
//!
//! The run recorded in EXPERIMENTS.md §E7 used the default 300 steps.

use anyhow::{Context, Result};
use sparkattention::config::TrainConfig;
use sparkattention::coordinator::Trainer;
use sparkattention::runtime::Engine;

fn main() -> Result<()> {
    sparkattention::logging::init();
    let steps: usize = std::env::args().nth(1)
        .map(|s| s.parse().expect("steps must be an integer"))
        .unwrap_or(300);
    let dir = std::env::var("SPARK_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());

    let engine = Engine::new(&dir).context("run `make artifacts` first")?;
    let meta = engine.manifest().get("train_step")?;
    println!("model: {} params, {} layers, d_model {}, seq {}, batch {}",
             meta.attr_i64("param_count").unwrap_or(0),
             meta.attr_i64("num_layers").unwrap_or(0),
             meta.attr_i64("d_model").unwrap_or(0),
             meta.attr_i64("seq").unwrap_or(0),
             meta.attr_i64("batch").unwrap_or(0));

    let cfg = TrainConfig {
        artifact_dir: dir,
        steps,
        seed: 42,
        log_every: 10,
        checkpoint_every: 100,
        checkpoint_dir: "checkpoints".into(),
        corpus_tokens: 1 << 19,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(&engine, cfg);
    let out = trainer.run()?;

    // Loss curve, decimated to ≤30 lines for the log.
    println!("\nloss curve (step, loss):");
    let stride = (out.losses.len() / 30).max(1);
    for (i, l) in out.losses.iter().enumerate() {
        if i % stride == 0 || i == out.losses.len() - 1 {
            let bar_len = ((l / 6.0) * 60.0) as usize;
            println!("  {i:4}  {l:7.4}  {}", "#".repeat(bar_len.min(70)));
        }
    }
    println!("\nuniform-byte entropy ln(256) = {:.3}", (256f64).ln());
    println!("loss {:.4} → {:.4} (tail-10 mean {:.4}) over {} steps",
             out.first_loss(), out.last_loss(), out.tail_mean(10),
             out.steps);
    println!("throughput: {:.0} tokens/s ({:.2} s/step)",
             out.tokens_per_step as f64 / out.mean_step_seconds,
             out.mean_step_seconds);
    anyhow::ensure!(out.tail_mean(10) < out.first_loss(),
                    "loss did not improve — training is broken");
    Ok(())
}
