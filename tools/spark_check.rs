//! CI entry point for the static invariant analyzer — the bin behind
//! the `spark-check` job in `.github/workflows/ci.yml`.
//!
//! Equivalent to `spark check` but a separate target, so CI runs it
//! with a single `cargo run --bin spark_check` and no artifact setup.
//! Exit codes: 0 clean, 1 findings survived waivers, 2 operational
//! error (unreadable tree, bad flags).

use std::path::PathBuf;
use std::process::ExitCode;

use sparkattention::analysis;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => {
                    eprintln!("spark_check: --root needs a directory");
                    return ExitCode::from(2);
                }
            },
            "--list-rules" => {
                for r in analysis::RULES {
                    println!("{:<16} {}", r.id, r.summary);
                }
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("spark_check: unknown flag {other:?} \
                           (supported: --root DIR, --list-rules)");
                return ExitCode::from(2);
            }
        }
    }
    let report = match analysis::check_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("spark_check: {e:#}");
            return ExitCode::from(2);
        }
    };
    for f in &report.findings {
        println!("{f}");
    }
    println!("spark check: {} files scanned, {} findings, {} waived",
             report.files, report.findings.len(), report.waived);
    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
