//! `bench_compare` — the CI bench-trajectory gate.
//!
//! Re-runs the host MHA-Forward backend sweep at the shape pinned in the
//! committed baseline (`BENCH_6.json`) and compares the *scalar-relative
//! speedups* of the parallel backend families (`blocked*`, `simd*`)
//! against the baseline's.  Absolute wall-clock varies wildly across CI
//! machines, so it is never gated; the speedup of a parallel backend
//! over the scalar reference *on the same machine in the same process*
//! is the machine-portable trajectory signal.  A family whose speedup
//! falls more than `--tolerance` (default 0.25, i.e. 25%) below the
//! baseline fails the gate with a non-zero exit.
//!
//! The gate always runs with the default (MC, KC) blocks — it installs
//! no tuning table — so baseline and fresh runs measure the same
//! configuration.  Mixed-precision and streamed variants are excluded
//! from the family aggregate: they answer accuracy/dataflow questions,
//! not the pool-throughput question this gate watches.
//!
//! Re-baselining after an intentional perf change:
//!
//! ```text
//! cargo run --release --bin bench_compare -- --update
//! ```
//!
//! rewrites `BENCH_6.json` in place from a fresh sweep (review the diff
//! like any other code change).

use std::process::ExitCode;

use anyhow::{anyhow, bail, Context, Result};
use sparkattention::bench::Options;
use sparkattention::cli::Command;
use sparkattention::coordinator::harness::HarnessOptions;
use sparkattention::coordinator::host_backend_report;
use sparkattention::exec::ExecOptions;
use sparkattention::jsonio::{self, Value};

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("bench_compare: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> Result<bool> {
    let cmd = Command::new(
        "bench_compare",
        "gate scalar-relative backend speedups against a committed baseline")
        .flag("baseline", "baseline JSON (schema 1, see BENCH_6.json)",
              Some("BENCH_6.json"))
        .flag("tolerance",
              "allowed fractional speedup drop before failing (0.25 = 25%)",
              Some("0.25"))
        .flag("threads", "override the baseline's worker-thread count", None)
        .switch("update", "re-measure and rewrite the baseline in place");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = cmd.parse(&args)?;
    let path = p.get("baseline").expect("has default").to_string();
    let tolerance = p.get_f64("tolerance")?.expect("has default");
    if !(0.0..1.0).contains(&tolerance) {
        bail!("--tolerance must be in [0, 1), got {tolerance}");
    }

    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading baseline {path}"))?;
    let base = jsonio::parse(&text)
        .with_context(|| format!("parsing baseline {path}"))?;
    let schema = base.get("schema").and_then(Value::as_usize);
    if schema != Some(1) {
        bail!("{path}: unsupported schema {schema:?} (expected 1)");
    }

    // Pinned problem shape + iteration policy from the baseline, so every
    // run measures the same work.
    let shape = base.get("shape")
        .ok_or_else(|| anyhow!("{path}: missing \"shape\""))?;
    let field = |key: &str| {
        shape.get(key).and_then(Value::as_usize)
            .ok_or_else(|| anyhow!("{path}: shape.{key} must be an integer"))
    };
    let ns: Vec<usize> = shape.get("ns").and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("{path}: shape.ns must be an array"))?
        .iter().map(|v| v.as_usize()
            .ok_or_else(|| anyhow!("{path}: shape.ns entries must be \
                                    integers")))
        .collect::<Result<_>>()?;
    let (bh, d) = (field("bh")?, field("d")?);
    let mut threads = field("threads")?;
    if let Some(t) = p.get_usize("threads")? {
        threads = t;
    }
    let bench = base.get("bench")
        .ok_or_else(|| anyhow!("{path}: missing \"bench\""))?;
    let iters = bench.get("iters").and_then(Value::as_usize)
        .ok_or_else(|| anyhow!("{path}: bench.iters must be an integer"))?;
    let warmup = bench.get("warmup").and_then(Value::as_usize)
        .ok_or_else(|| anyhow!("{path}: bench.warmup must be an integer"))?;

    let opts = HarnessOptions {
        bench: Options { warmup_iters: warmup, iters },
        exec: ExecOptions { threads, ..ExecOptions::default() },
        ..HarnessOptions::default()
    };
    println!("bench_compare: sweeping ns={ns:?} bh={bh} d={d} \
              threads={threads} (warmup {warmup}, iters {iters})");
    // the trajectory gate compares dense-mask rows only: masked-variant
    // groups carry different FLOPs and would corrupt the family ratios
    let masks = [sparkattention::attention::MaskSpec::Dense];
    let fresh = host_backend_report(&ns, bh, d, false, &masks, opts)
        .context("running the host backend sweep")?;
    let fresh_json = fresh.to_json();

    if p.switch("update") {
        let mut wrapper = match base {
            Value::Obj(o) => o,
            _ => bail!("{path}: baseline must be a JSON object"),
        };
        wrapper.insert("report".to_string(), fresh_json);
        let mut out = String::new();
        write_pretty(&mut out, &Value::Obj(wrapper), 0);
        out.push('\n');
        std::fs::write(&path, out)
            .with_context(|| format!("rewriting baseline {path}"))?;
        println!("bench_compare: baseline {path} updated — commit the diff \
                  to re-baseline");
        return Ok(true);
    }

    let base_rows = report_rows(&base, &path)?;
    let fresh_rows = report_rows_owned(&fresh_json)?;
    let mut ok = true;
    println!("{:<10} {:>14} {:>14} {:>8}  verdict", "family",
             "baseline_sp", "current_sp", "ratio");
    for family in ["blocked", "simd"] {
        let (bx, bsp) = family_speedup(base_rows, family).ok_or_else(
            || anyhow!("{path}: no usable {family} rows in baseline"))?;
        let (fx, fsp) = family_speedup(&fresh_rows, family).ok_or_else(
            || anyhow!("fresh sweep produced no usable {family} rows"))?;
        let ratio = fsp / bsp;
        let pass = ratio >= 1.0 - tolerance;
        println!("{family:<10} {:>11.3}@{bx} {:>11.3}@{fx} {ratio:>8.3}  {}",
                 bsp, fsp, if pass { "ok" } else { "REGRESSED" });
        ok &= pass;
    }
    if ok {
        println!("bench_compare: PASS (tolerance {:.0}%)",
                 tolerance * 100.0);
    } else {
        println!("bench_compare: REGRESSED — a backend family lost more \
                  than {:.0}% of its scalar-relative speedup vs {path}.\n\
                  If intentional, re-baseline with `cargo run --release \
                  --bin bench_compare -- --update` and commit the diff.",
                 tolerance * 100.0);
    }
    Ok(ok)
}

/// The `report.rows` array of a baseline wrapper, with loud errors.
fn report_rows<'a>(wrapper: &'a Value, path: &str) -> Result<&'a [Value]> {
    wrapper.get("report").and_then(|r| r.get("rows"))
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("{path}: missing report.rows"))
}

/// Same, for the freshly generated report JSON (owned by the caller).
fn report_rows_owned(report: &Value) -> Result<&[Value]> {
    report.get("rows").and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("fresh report has no rows"))
}

/// Scalar-relative speedup of a backend family at the largest sequence
/// length where both the family and the scalar reference have `ok` rows:
/// `mean(scalar mean_s) / mean(family mean_s)` at that `x`.
///
/// Family membership: `variant` starts with the family name and is
/// neither a `_stream` nor a `_mixed` variant.
fn family_speedup(rows: &[Value], family: &str) -> Option<(usize, f64)> {
    let in_family = |v: &Value| {
        let name = v.get("variant")?.as_str()?;
        let ok = v.get("status")?.as_str()? == "ok"
            && name.starts_with(family)
            && !name.contains("stream")
            && !name.contains("mixed");
        ok.then_some(())
    };
    let is_scalar = |v: &Value| {
        (v.get("variant")?.as_str()? == "scalar"
         && v.get("status")?.as_str()? == "ok").then_some(())
    };
    let mean_at = |x: usize, pick: &dyn Fn(&Value) -> Option<()>| {
        let ms: Vec<f64> = rows.iter()
            .filter(|v| v.get("x").and_then(Value::as_usize) == Some(x)
                    && pick(v).is_some())
            .filter_map(|v| v.get("mean_s").and_then(Value::as_f64))
            .collect();
        if ms.is_empty() {
            None
        } else {
            Some(ms.iter().sum::<f64>() / ms.len() as f64)
        }
    };
    let x = rows.iter()
        .filter(|v| in_family(v).is_some())
        .filter_map(|v| v.get("x").and_then(Value::as_usize))
        .filter(|&x| mean_at(x, &is_scalar).is_some())
        .max()?;
    let scalar = mean_at(x, &is_scalar)?;
    let fam = mean_at(x, &in_family)?;
    if fam > 0.0 {
        Some((x, scalar / fam))
    } else {
        None
    }
}

// ---- pretty printer (diff-friendly committed baselines) -----------------

/// True for values printed inline (no structural children).
fn scalar(v: &Value) -> bool {
    !matches!(v, Value::Arr(_) | Value::Obj(_))
}

/// One value, compact but with spaces (`{"a": 1, "b": 2}`).
fn write_inline(out: &mut String, v: &Value) {
    match v {
        Value::Arr(a) => {
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                write_inline(out, e);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, e)) in o.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                out.push_str(&jsonio::to_string(&jsonio::s(k.clone())));
                out.push_str(": ");
                write_inline(out, e);
            }
            out.push('}');
        }
        _ => out.push_str(&jsonio::to_string(v)),
    }
}

/// Indented rendering: containers whose children are all scalar (bench
/// rows, the `ns` list) stay on one line; everything else nests.
fn write_pretty(out: &mut String, v: &Value, indent: usize) {
    let pad = "  ".repeat(indent + 1);
    match v {
        Value::Arr(a) if !a.is_empty() && !a.iter().all(scalar) => {
            out.push_str("[\n");
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&pad);
                let flat = match e {
                    Value::Obj(o) => o.values().all(scalar),
                    Value::Arr(x) => x.iter().all(scalar),
                    _ => true,
                };
                if flat {
                    write_inline(out, e);
                } else {
                    write_pretty(out, e, indent + 1);
                }
            }
            out.push('\n');
            out.push_str(&"  ".repeat(indent));
            out.push(']');
        }
        Value::Obj(o) if !o.is_empty() && !o.values().all(scalar) => {
            out.push_str("{\n");
            for (i, (k, e)) in o.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&pad);
                out.push_str(&jsonio::to_string(&jsonio::s(k.clone())));
                out.push_str(": ");
                write_pretty(out, e, indent + 1);
            }
            out.push('\n');
            out.push_str(&"  ".repeat(indent));
            out.push('}');
        }
        _ => write_inline(out, v),
    }
}
