"""The unfused baseline: numerics vs oracle + honesty of the staging."""

import jax
import jax.numpy as jnp
import pytest

from compile.kernels import naive, ref

jax.config.update("jax_platform_name", "cpu")


def qkv(bh, n, d, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    return tuple(jax.random.normal(k, (bh, n, d), jnp.bfloat16) for k in ks)


@pytest.mark.parametrize("causal", [False, True])
def test_matches_oracle(causal):
    q, k, v = qkv(2, 128, 32)
    o = naive.mha_fwd_unfused(q, k, v, causal=causal)
    r, _ = ref.mha_fwd(q, k, v, causal=causal)
    assert jnp.allclose(o.astype(jnp.float32), r.astype(jnp.float32),
                        atol=2e-2, rtol=2e-2)


def test_backward_matches_autodiff_of_ref():
    q, k, v = qkv(1, 64, 16, seed=1)
    do = jax.random.normal(jax.random.PRNGKey(9), q.shape, jnp.bfloat16)
    dq, dk, dv = naive.mha_bwd_unfused(q, k, v, do, causal=True)
    rdq, rdk, rdv = ref.mha_bwd(q, k, v, do, causal=True)
    for got, want in [(dq, rdq), (dk, rdk), (dv, rdv)]:
        assert jnp.allclose(got.astype(jnp.float32),
                            want.astype(jnp.float32), atol=3e-2, rtol=3e-2)


def test_stage_barriers_survive_lowering():
    """The baseline's honesty: optimization_barrier must still be in the
    lowered HLO, so XLA cannot fuse away the N×N round-trips."""
    q, k, v = qkv(1, 64, 16)

    def fn(q, k, v):
        return naive.mha_fwd_unfused(q, k, v)

    hlo = jax.jit(fn).lower(q, k, v).compiler_ir("hlo").as_hlo_text()
    assert hlo.count("opt-barrier") >= 2, "stage barriers were optimised out"


def test_dropout_applies():
    q, k, v = qkv(1, 64, 16, seed=2)
    o_plain = naive.mha_fwd_unfused(q, k, v, 1.0, dropout_rate=0.0)
    o_drop = naive.mha_fwd_unfused(q, k, v, 1.0, dropout_rate=0.5)
    assert not jnp.allclose(o_plain.astype(jnp.float32),
                            o_drop.astype(jnp.float32), atol=1e-3)
