"""flash_bwd vs the oracle and vs JAX autodiff — Equation 4 correctness."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_bwd, flash_fwd, ref

jax.config.update("jax_platform_name", "cpu")

TOL = dict(atol=3e-2, rtol=3e-2)


def tensors(bh, n, d, seed=0, dtype=jnp.bfloat16):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    return tuple(jax.random.normal(k, (bh, n, d), dtype) for k in ks)


def run_pair(q, k, v, do, *, causal, dropout=0.0, seed=0.0, acc="f32",
             bq=64, bk=64):
    o, lse = flash_fwd.flash_fwd(q, k, v, seed, causal=causal,
                                 dropout_rate=dropout, acc="f32",
                                 block_q=bq, block_k=bk)
    return flash_bwd.flash_bwd(q, k, v, o, lse, do, seed, causal=causal,
                               dropout_rate=dropout, acc=acc,
                               block_q=bq, block_k=bk)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("acc", ["f32", "bf16"])
def test_matches_oracle(causal, acc):
    q, k, v, do = tensors(2, 256, 64)
    dq, dk, dv = run_pair(q, k, v, do, causal=causal, acc=acc)
    rdq, rdk, rdv = ref.mha_bwd(q, k, v, do, causal=causal)
    for got, want, nm in [(dq, rdq, "dq"), (dk, rdk, "dk"), (dv, rdv, "dv")]:
        assert jnp.allclose(got.astype(jnp.float32),
                            want.astype(jnp.float32), **TOL), nm


@pytest.mark.parametrize("causal", [False, True])
def test_dropout_replay_consistency(causal):
    """Backward must regenerate the forward's exact dropout masks."""
    q, k, v, do = tensors(2, 128, 32, seed=1)
    dq, dk, dv = run_pair(q, k, v, do, causal=causal, dropout=0.1, seed=3.0)
    rdq, rdk, rdv = ref.mha_bwd(q, k, v, do, causal=causal,
                                dropout_rate=0.1, seed=3.0,
                                block_q=64, block_k=64)
    for got, want, nm in [(dq, rdq, "dq"), (dk, rdk, "dk"), (dv, rdv, "dv")]:
        assert jnp.allclose(got.astype(jnp.float32),
                            want.astype(jnp.float32), **TOL), nm


def test_oracle_matches_autodiff():
    """ref.mha_bwd is itself pinned to jax.grad of ref.mha_fwd (f32)."""
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 4)
    q, k, v, do = (jax.random.normal(kk, (1, 64, 16), jnp.float32)
                   for kk in ks)

    def f(q, k, v):
        o, _ = ref.mha_fwd(q, k, v, causal=True, dropout_rate=0.1, seed=2.0,
                           block_q=32, block_k=32)
        return jnp.sum(o * do)

    adq, adk, adv = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    rdq, rdk, rdv = ref.mha_bwd(q, k, v, do, causal=True, dropout_rate=0.1,
                                seed=2.0, block_q=32, block_k=32)
    assert jnp.allclose(adq, rdq, atol=1e-4)
    assert jnp.allclose(adk, rdk, atol=1e-4)
    assert jnp.allclose(adv, rdv, atol=1e-4)


def test_dpsum_kernel():
    """The Pallas dPsum preprocess equals rowsum(dO ∘ O)."""
    key = jax.random.PRNGKey(9)
    o = jax.random.normal(key, (2, 128, 32), jnp.bfloat16)
    do = jax.random.normal(jax.random.fold_in(key, 1), (2, 128, 32),
                           jnp.bfloat16)
    got = flash_bwd.dpsum(o, do, block_q=64)
    want = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32), axis=-1)
    assert jnp.allclose(got, want, atol=1e-2, rtol=1e-2)


def test_block_shape_invariance():
    q, k, v, do = tensors(1, 128, 32, seed=2)
    base = run_pair(q, k, v, do, causal=True, bq=128, bk=128)
    for bq, bk in [(32, 32), (64, 32), (32, 64)]:
        got = run_pair(q, k, v, do, causal=True, bq=bq, bk=bk)
        for g, b in zip(got, base):
            assert jnp.allclose(g.astype(jnp.float32),
                                b.astype(jnp.float32), **TOL), (bq, bk)


@settings(max_examples=12, deadline=None)
@given(
    bh=st.integers(1, 2),
    n_pow=st.integers(4, 7),
    d=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
)
def test_hypothesis_grad_sweep(bh, n_pow, d, causal):
    n = 1 << n_pow
    block = min(32, n)
    q, k, v, do = tensors(bh, n, d, seed=n_pow * 17 + d)
    dq, dk, dv = run_pair(q, k, v, do, causal=causal, bq=block, bk=block)
    rdq, rdk, rdv = ref.mha_bwd(q, k, v, do, causal=causal)
    for got, want in [(dq, rdq), (dk, rdk), (dv, rdv)]:
        assert got.shape == (bh, n, d)
        assert jnp.allclose(got.astype(jnp.float32),
                            want.astype(jnp.float32), atol=5e-2, rtol=5e-2)
