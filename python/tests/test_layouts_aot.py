"""Block/VMEM budgeting + the AOT export contract."""

import json
import os

import jax
import pytest

from compile import aot
from compile.kernels import layouts

jax.config.update("jax_platform_name", "cpu")


# -- layouts ---------------------------------------------------------------

def test_choose_blocks_default_is_mxu_square():
    cfg = layouts.choose_blocks(2048, 128)
    assert (cfg.block_q, cfg.block_k) == (256, 256) or \
        (cfg.block_q, cfg.block_k) == (128, 128) or cfg.block_q >= 128
    assert cfg.vmem_bytes <= layouts.VMEM_BYTES
    assert cfg.mxu_utilization == 1.0


def test_choose_blocks_small_n():
    cfg = layouts.choose_blocks(64, 64)
    assert cfg.block_q <= 64
    assert cfg.vmem_bytes <= layouts.VMEM_BYTES


def test_vmem_footprint_matches_design_doc():
    # DESIGN.md §7: (128,128,d=128) ≈ 225 KB single-buffered
    fp = layouts.vmem_footprint(128, 128, 128, double_buffer=False)
    assert 200_000 < fp < 250_000, fp


def test_tiny_vmem_budget_shrinks_blocks():
    cfg = layouts.choose_blocks(2048, 128, vmem_budget=200_000)
    assert cfg.block_q < 256
    with pytest.raises(ValueError):
        layouts.choose_blocks(2048, 128, vmem_budget=1000)


def test_io_formulas_ordering():
    for n in (512, 2048, 16384):
        unf = layouts.hbm_bytes_unfused_fwd(8, n, 64)
        fus = layouts.hbm_bytes_fused_fwd(8, n, 64)
        assert unf > fus
    # N² term dominates as n grows
    r1 = layouts.hbm_bytes_unfused_fwd(8, 512, 64) / \
        layouts.hbm_bytes_fused_fwd(8, 512, 64)
    r2 = layouts.hbm_bytes_unfused_fwd(8, 4096, 64) / \
        layouts.hbm_bytes_fused_fwd(8, 4096, 64)
    assert r2 > r1 * 3


def test_mxu_utilization_degrades_below_128():
    assert layouts.mxu_utilization(128, 128, 128) == 1.0
    assert layouts.mxu_utilization(64, 128, 128) == 0.5
    assert layouts.mxu_utilization(128, 128, 64) == 0.5


# -- aot export ------------------------------------------------------------

@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("arts")
    manifest = aot.build(str(out), ["accuracy"],
                         only="d64_n256_bh2_c0")
    return out, manifest


def test_manifest_entries_complete(built):
    out, manifest = built
    arts = manifest["artifacts"]
    assert arts, "no artifacts built"
    for a in arts:
        assert os.path.exists(out / a["file"]), a["name"]
        assert a["kind"]
        for io in ("inputs", "outputs"):
            for t in a[io]:
                assert t["shape"], (a["name"], t)
                assert t["dtype"] in aot.DTYPE_NAMES.values()
        assert "flops" in a["attrs"]


def test_hlo_is_custom_call_free(built):
    out, manifest = built
    for a in manifest["artifacts"]:
        text = open(out / a["file"]).read()
        assert "custom-call" not in text, \
            f"{a['name']} contains a custom-call (won't run on CPU PJRT)"
        assert text.startswith("HloModule"), a["name"]


def test_keep_unused_inputs_preserved(built):
    """dropout-0 artifacts must still take their seed parameter."""
    out, manifest = built
    fwd = [a for a in manifest["artifacts"] if a["kind"] == "mha_fwd"]
    assert fwd
    for a in fwd:
        assert a["inputs"][0]["name"] == "seed"
        text = open(out / a["file"]).read()
        entry = text.split("ENTRY")[1]
        assert entry.count("parameter(") == len(a["inputs"]), a["name"]


def test_incremental_build_skips(built):
    out, _ = built
    before = {f: os.path.getmtime(out / f) for f in os.listdir(out)}
    aot.build(str(out), ["accuracy"], only="d64_n256_bh2_c0")
    after = {f: os.path.getmtime(out / f) for f in os.listdir(out)}
    changed = {f for f in before
               if f != "manifest.json" and before[f] != after.get(f)}
    assert not changed, f"incremental build rebuilt {changed}"


def test_manifest_json_is_valid(built):
    out, _ = built
    with open(out / "manifest.json") as f:
        doc = json.load(f)
    assert doc["version"] == 1
    names = [a["name"] for a in doc["artifacts"]]
    assert len(names) == len(set(names)), "duplicate artifact names"
