"""The custom_vjp wiring: fused attention gradients vs autodiff ground
truth, and the MHA layer plumbing."""

import jax
import jax.numpy as jnp
import pytest

from compile import mha
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def qkv(bh, n, d, seed=0, dtype=jnp.bfloat16):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    return tuple(jax.random.normal(k, (bh, n, d), dtype) for k in ks)


@pytest.mark.parametrize("causal", [False, True])
def test_custom_vjp_grads_match_reference(causal):
    q, k, v = qkv(2, 128, 32)
    attn = mha.make_attention(mha.AttentionConfig(
        causal=causal, block_q=64, block_k=64))
    seed = jnp.zeros((1,), jnp.float32)

    def loss(q, k, v):
        return jnp.sum(attn(q, k, v, seed).astype(jnp.float32) ** 2)

    dq, dk, dv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    # reference cotangent: dO = 2·O
    o_ref, _ = ref.mha_fwd(q, k, v, causal=causal)
    do = (2.0 * o_ref.astype(jnp.float32)).astype(q.dtype)
    rdq, rdk, rdv = ref.mha_bwd(q, k, v, do, causal=causal)
    for got, want, nm in [(dq, rdq, "dq"), (dk, rdk, "dk"), (dv, rdv, "dv")]:
        assert jnp.allclose(got.astype(jnp.float32),
                            want.astype(jnp.float32),
                            atol=5e-2, rtol=5e-2), nm


def test_seed_gradient_is_zero():
    q, k, v = qkv(1, 64, 16)
    attn = mha.make_attention(mha.AttentionConfig(
        dropout_rate=0.1, block_q=32, block_k=32))

    def loss(seed):
        return jnp.sum(attn(q, k, v, seed).astype(jnp.float32))

    g = jax.grad(loss)(jnp.ones((1,), jnp.float32))
    assert jnp.array_equal(g, jnp.zeros((1,), jnp.float32))


def test_unfused_impl_same_function():
    q, k, v = qkv(1, 128, 32, seed=3)
    seed = jnp.zeros((1,), jnp.float32)
    fused = mha.make_attention(mha.AttentionConfig(block_q=64, block_k=64))
    unfused = mha.make_attention(mha.AttentionConfig(impl="unfused"))
    a = fused(q, k, v, seed).astype(jnp.float32)
    b = unfused(q, k, v, seed).astype(jnp.float32)
    assert jnp.allclose(a, b, atol=2e-2, rtol=2e-2)


def test_split_merge_heads_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 16, 24), jnp.float32)
    h = mha.split_heads(x, 4)
    assert h.shape == (12, 16, 6)
    back = mha.merge_heads(h, 3)
    assert jnp.array_equal(back, x)


def test_mha_layer_shapes_and_grad_flow():
    cfg = mha.AttentionConfig(block_q=32, block_k=32)
    attn = mha.make_attention(cfg)
    key = jax.random.PRNGKey(1)
    params = mha.init_mha_params(key, 32)
    x = jax.random.normal(key, (2, 64, 32), jnp.bfloat16)
    seed = jnp.zeros((1,), jnp.float32)
    y = mha.mha_layer(x, params, seed, num_heads=4, attn=attn)
    assert y.shape == x.shape

    def loss(params):
        return jnp.sum(mha.mha_layer(x, params, seed, num_heads=4,
                                     attn=attn).astype(jnp.float32) ** 2)

    grads = jax.grad(loss)(params)
    for name in ("wq", "wk", "wv", "wo", "bo"):
        g = grads[name].astype(jnp.float32)
        assert bool(jnp.any(g != 0.0)), f"no gradient reached {name}"


def test_invalid_impl_rejected():
    import pytest as _pytest
    with _pytest.raises(ValueError, match="unknown attention impl"):
        mha.make_attention(mha.AttentionConfig(impl="magic"))
