"""Dropout RNG: tile draws must be schedule-independent and replayable."""

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import rng


def test_full_mask_assembles_tiles():
    seed, bh, n, block = 7.0, 2, 64, 16
    nq = nk = n // block
    full = rng.full_keep_mask(seed, bh, n, n, block, block, 0.1)
    for b in range(bh):
        for iq in range(nq):
            for ik in range(nk):
                tile = rng.tile_keep_mask(
                    seed, jnp.uint32(b), jnp.uint32(iq), jnp.uint32(ik),
                    nq, nk, (block, block), 0.1)
                got = full[b, iq * block:(iq + 1) * block,
                           ik * block:(ik + 1) * block]
                assert jnp.array_equal(tile, got), (b, iq, ik)


def test_tiles_differ_across_indices():
    args = dict(nq=4, nk=4, shape=(16, 16), rate=0.5)
    t0 = rng.tile_keep_mask(1.0, jnp.uint32(0), jnp.uint32(0),
                            jnp.uint32(0), **args)
    t1 = rng.tile_keep_mask(1.0, jnp.uint32(0), jnp.uint32(0),
                            jnp.uint32(1), **args)
    t2 = rng.tile_keep_mask(1.0, jnp.uint32(1), jnp.uint32(0),
                            jnp.uint32(0), **args)
    assert not jnp.array_equal(t0, t1)
    assert not jnp.array_equal(t0, t2)


def test_zero_rate_keeps_everything():
    m = rng.full_keep_mask(0.0, 1, 32, 32, 16, 16, 0.0)
    assert bool(m.all())


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1 << 20), rate=st.sampled_from([0.1, 0.3, 0.5]))
def test_keep_fraction_near_rate(seed, rate):
    m = rng.full_keep_mask(float(seed), 2, 64, 64, 32, 32, rate)
    keep = float(m.mean())
    assert abs(keep - (1.0 - rate)) < 0.08, (keep, rate)


def test_seed_determinism():
    a = rng.full_keep_mask(3.0, 1, 32, 32, 16, 16, 0.2)
    b = rng.full_keep_mask(3.0, 1, 32, 32, 16, 16, 0.2)
    c = rng.full_keep_mask(4.0, 1, 32, 32, 16, 16, 0.2)
    assert jnp.array_equal(a, b)
    assert not jnp.array_equal(a, c)
