"""flash_fwd vs the pure-jnp oracle: the core L1 correctness signal."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_fwd, layouts, ref

jax.config.update("jax_platform_name", "cpu")


def qkv(bh, n, d, seed=0, dtype=jnp.bfloat16):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    return tuple(jax.random.normal(k, (bh, n, d), dtype) for k in ks)


TOL = dict(atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("acc", ["f32", "bf16"])
def test_matches_oracle(causal, acc):
    q, k, v = qkv(2, 256, 64)
    o, lse = flash_fwd.flash_fwd(q, k, v, causal=causal, acc=acc,
                                 block_q=64, block_k=64)
    ro, rlse = ref.mha_fwd(q, k, v, causal=causal)
    assert jnp.allclose(o.astype(jnp.float32), ro.astype(jnp.float32), **TOL)
    assert jnp.allclose(lse, rlse, atol=1e-3)


@pytest.mark.parametrize("causal", [False, True])
def test_dropout_matches_oracle_with_shared_masks(causal):
    q, k, v = qkv(2, 128, 32, seed=1)
    o, _ = flash_fwd.flash_fwd(q, k, v, 5.0, causal=causal,
                               dropout_rate=0.1, block_q=64, block_k=64)
    ro, _ = ref.mha_fwd(q, k, v, causal=causal, dropout_rate=0.1, seed=5.0,
                        block_q=64, block_k=64)
    assert jnp.allclose(o.astype(jnp.float32), ro.astype(jnp.float32), **TOL)


def test_dropout_seed_changes_output():
    q, k, v = qkv(1, 128, 32, seed=2)
    o1, _ = flash_fwd.flash_fwd(q, k, v, 1.0, dropout_rate=0.1,
                                block_q=64, block_k=64)
    o2, _ = flash_fwd.flash_fwd(q, k, v, 2.0, dropout_rate=0.1,
                                block_q=64, block_k=64)
    assert not jnp.allclose(o1.astype(jnp.float32), o2.astype(jnp.float32),
                            atol=1e-3)


def test_dropout_zero_equals_no_dropout():
    q, k, v = qkv(1, 128, 32, seed=3)
    o1, _ = flash_fwd.flash_fwd(q, k, v, 7.0, dropout_rate=0.0,
                                block_q=64, block_k=64)
    o2, _ = flash_fwd.flash_fwd(q, k, v, 9.0, dropout_rate=0.0,
                                block_q=64, block_k=64)
    assert jnp.array_equal(o1, o2)


def test_block_shape_invariance():
    """Equation 3: any block partition computes the same softmax."""
    q, k, v = qkv(2, 128, 32, seed=4)
    base, base_lse = flash_fwd.flash_fwd(q, k, v, block_q=128, block_k=128)
    for bq, bk in [(32, 32), (64, 32), (32, 64), (128, 32)]:
        o, lse = flash_fwd.flash_fwd(q, k, v, block_q=bq, block_k=bk)
        assert jnp.allclose(o.astype(jnp.float32),
                            base.astype(jnp.float32), **TOL), (bq, bk)
        assert jnp.allclose(lse, base_lse, atol=1e-3)


def test_scale_parameter():
    q, k, v = qkv(1, 64, 16, seed=5)
    o1, _ = flash_fwd.flash_fwd(q, k, v, scale=0.5, block_q=64, block_k=64)
    r1, _ = ref.mha_fwd(q, k, v, scale=0.5)
    assert jnp.allclose(o1.astype(jnp.float32), r1.astype(jnp.float32),
                        **TOL)


def test_rejects_bad_args():
    q, k, v = qkv(1, 64, 16)
    with pytest.raises(ValueError, match="acc"):
        flash_fwd.flash_fwd(q, k, v, acc="f16")
    with pytest.raises(ValueError, match="divisible"):
        flash_fwd.flash_fwd(q, k, v, block_q=48)


def test_f32_inputs_supported():
    q, k, v = qkv(1, 64, 16, dtype=jnp.float32)
    o, _ = flash_fwd.flash_fwd(q, k, v, block_q=32, block_k=32)
    r, _ = ref.mha_fwd(q, k, v)
    assert o.dtype == jnp.float32
    assert jnp.allclose(o, r, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    bh=st.integers(1, 3),
    n_pow=st.integers(4, 8),          # n ∈ {16 … 256}
    d=st.sampled_from([8, 16, 32, 64]),
    causal=st.booleans(),
    acc=st.sampled_from(["f32", "bf16"]),
    block_pow=st.integers(3, 6),      # blocks ∈ {8 … 64}
)
def test_hypothesis_shape_sweep(bh, n_pow, d, causal, acc, block_pow):
    """Property: kernel ≈ oracle over random shape/block/dtype configs."""
    n = 1 << n_pow
    block = min(1 << block_pow, n)
    q, k, v = qkv(bh, n, d, seed=n_pow * 31 + d)
    o, lse = flash_fwd.flash_fwd(q, k, v, causal=causal, acc=acc,
                                 block_q=block, block_k=block)
    ro, rlse = ref.mha_fwd(q, k, v, causal=causal)
    assert o.shape == (bh, n, d)
    assert jnp.allclose(o.astype(jnp.float32), ro.astype(jnp.float32),
                        atol=3e-2, rtol=3e-2)
    assert jnp.allclose(lse, rlse, atol=2e-3)


def test_default_blocks_from_layouts():
    q, k, v = qkv(1, 256, 64, seed=6)
    cfg = layouts.choose_blocks(256, 64)
    o_default, _ = flash_fwd.flash_fwd(q, k, v)
    o_explicit, _ = flash_fwd.flash_fwd(q, k, v, block_q=cfg.block_q,
                                        block_k=cfg.block_k)
    assert jnp.array_equal(o_default, o_explicit)
