"""Layer-2 model: encoder variants, LM loss, Adam train step."""

import functools

import jax
import jax.numpy as jnp
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

SMALL = M.ModelConfig(num_layers=1, d_model=64, num_heads=2, d_ff=128,
                      seq=64, batch=4, dropout_rate=0.0)


@pytest.fixture(scope="module")
def params():
    return M.init_params(SMALL, jax.random.PRNGKey(0))


def test_param_tree_and_names(params):
    leaves, _ = M.flatten_params(params)
    names = M.param_names(params)
    assert len(leaves) == len(names)
    assert "embed" in names
    assert any(n.startswith("layers/0/attn/") for n in names)
    # deterministic ordering
    assert names == M.param_names(params)


@pytest.mark.parametrize("impl", ["unfused", "fused", "fully_fused"])
def test_encoder_variants_agree(params, impl):
    cfg = M.ModelConfig(**{**SMALL.__dict__, "attn_impl": impl})
    base_cfg = M.ModelConfig(**{**SMALL.__dict__, "attn_impl": "unfused"})
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64), jnp.bfloat16)
    seed = jnp.zeros((1,), jnp.float32)
    y = M.encoder_forward(params, x, seed, cfg=cfg)
    y0 = M.encoder_forward(params, x, seed, cfg=base_cfg)
    assert y.shape == (2, 64, 64)
    assert jnp.allclose(y.astype(jnp.float32), y0.astype(jnp.float32),
                        atol=5e-2, rtol=5e-2)


def test_lm_forward_logits(params):
    toks = jax.random.randint(jax.random.PRNGKey(2), (4, 64), 0, 256)
    logits = M.lm_forward(params, toks, jnp.zeros((1,), jnp.float32),
                          cfg=SMALL)
    assert logits.shape == (4, 64, 256)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform(params):
    toks = jax.random.randint(jax.random.PRNGKey(3), (4, 65), 0, 256)
    loss = M.loss_fn(params, toks, jnp.zeros((1,), jnp.float32), cfg=SMALL)
    # fresh init ⇒ close to ln(256) ≈ 5.545
    assert 4.5 < float(loss) < 7.0


def test_train_step_reduces_loss(params):
    opt = M.init_opt_state(params)
    toks = jax.random.randint(jax.random.PRNGKey(4),
                              (SMALL.batch, SMALL.seq + 1), 0, 256)
    step = jax.jit(functools.partial(M.train_step, cfg=SMALL))
    p = params
    losses = []
    for i in range(10):
        p, opt, loss = step(p, opt, jnp.float32(i + 1), toks,
                            jnp.float32(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses
    assert all(jnp.isfinite(jnp.asarray(losses)))


def test_train_step_with_fused_attention_and_dropout():
    cfg = M.ModelConfig(num_layers=1, d_model=32, num_heads=2, d_ff=64,
                        seq=32, batch=2, dropout_rate=0.1,
                        attn_impl="fused")
    p = M.init_params(cfg, jax.random.PRNGKey(5))
    opt = M.init_opt_state(p)
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 33), 0, 256)
    step = jax.jit(functools.partial(M.train_step, cfg=cfg))
    l0 = None
    for i in range(6):
        p, opt, loss = step(p, opt, jnp.float32(i + 1), toks,
                            jnp.float32(i))
        l0 = l0 or float(loss)
    assert float(loss) < l0


def test_layer_norm_properties():
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 8, 32), jnp.float32) \
        * 10.0 + 3.0
    g = jnp.ones((32,), jnp.float32)
    b = jnp.zeros((32,), jnp.float32)
    y = M.layer_norm(x, g, b)
    mu = y.mean(-1)
    sd = y.std(-1)
    assert jnp.allclose(mu, jnp.zeros_like(mu), atol=1e-4)
    assert jnp.allclose(sd, jnp.ones_like(sd), atol=1e-2)


def test_ffn_fused_matches_unfused(params):
    lp = params["layers"][0]
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 64, 64), jnp.bfloat16)
    y_ref = M.ffn(x, lp, fused=False)
    y_fused = M.ffn(x, lp, fused=True)
    assert jnp.allclose(y_ref.astype(jnp.float32),
                        y_fused.astype(jnp.float32), atol=3e-2, rtol=3e-2)
