"""Decoder support: cross-attention kernels + the Figure-1 decoder layer."""

import jax
import jax.numpy as jnp
import pytest

from compile import mha, model as M
from compile.kernels import flash_bwd, flash_fwd, ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, dtype=jnp.bfloat16):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


@pytest.mark.parametrize("nq,nk", [(64, 128), (128, 64), (32, 256)])
def test_cross_attention_fwd_matches_oracle(nq, nk):
    q = rand((2, nq, 32), 0)
    k = rand((2, nk, 32), 1)
    v = rand((2, nk, 32), 2)
    o, lse = flash_fwd.flash_fwd(q, k, v, block_q=32, block_k=32)
    ro, rlse = ref.mha_fwd(q, k, v)
    assert o.shape == (2, nq, 32)
    assert jnp.allclose(o.astype(jnp.float32), ro.astype(jnp.float32),
                        atol=2e-2, rtol=2e-2)
    assert jnp.allclose(lse, rlse, atol=1e-3)


def test_cross_attention_bwd_matches_oracle():
    nq, nk, d = 64, 128, 16
    q = rand((1, nq, d), 3)
    k = rand((1, nk, d), 4)
    v = rand((1, nk, d), 5)
    do = rand((1, nq, d), 6)
    o, lse = flash_fwd.flash_fwd(q, k, v, block_q=32, block_k=32)
    dq, dk, dv = flash_bwd.flash_bwd(q, k, v, o, lse, do,
                                     block_q=32, block_k=32, acc="f32")
    rdq, rdk, rdv = ref.mha_bwd(q, k, v, do)
    assert dk.shape == (1, nk, d)
    for got, want, nm in [(dq, rdq, "dq"), (dk, rdk, "dk"), (dv, rdv, "dv")]:
        assert jnp.allclose(got.astype(jnp.float32),
                            want.astype(jnp.float32),
                            atol=3e-2, rtol=3e-2), nm


def test_causal_cross_attention_rejected():
    q = rand((1, 64, 16), 0)
    k = rand((1, 128, 16), 1)
    with pytest.raises(ValueError, match="causal"):
        flash_fwd.flash_fwd(q, k, k, causal=True)


def test_decoder_layer_shapes_and_grads():
    cfg = M.ModelConfig(num_layers=1, d_model=64, num_heads=2, d_ff=128,
                        seq=32, batch=2)
    lp = M.init_decoder_layer_params(cfg, jax.random.PRNGKey(0))
    x = rand((2, 32, 64), 7)        # decoder stream
    memory = rand((2, 48, 64), 8)   # encoder output, different length
    seed = jnp.zeros((1,), jnp.float32)
    y = M.decoder_layer(x, memory, lp, seed, cfg=cfg)
    assert y.shape == x.shape

    def loss(lp):
        return jnp.sum(M.decoder_layer(x, memory, lp, seed,
                                       cfg=cfg).astype(jnp.float32) ** 2)

    grads = jax.grad(loss)(lp)
    for name in ("attn", "cross"):
        for pname in ("wq", "wk", "wv", "wo"):
            g = grads[name][pname].astype(jnp.float32)
            assert bool(jnp.any(g != 0.0)), f"no grad at {name}/{pname}"


def test_decoder_masked_self_attention_is_causal():
    """Token t of the decoder must ignore decoder tokens > t."""
    cfg = M.ModelConfig(num_layers=1, d_model=32, num_heads=2, d_ff=64,
                        seq=16, batch=1)
    lp = M.init_decoder_layer_params(cfg, jax.random.PRNGKey(1))
    memory = rand((1, 16, 32), 9)
    x1 = rand((1, 16, 32), 10, jnp.float32).astype(jnp.bfloat16)
    x2 = jnp.concatenate([x1[:, :-1], rand((1, 1, 32), 11)], axis=1)
    seed = jnp.zeros((1,), jnp.float32)
    y1 = M.decoder_layer(x1, memory, lp, seed, cfg=cfg)
    y2 = M.decoder_layer(x2, memory, lp, seed, cfg=cfg)
    diff = jnp.abs(y1[:, :-1].astype(jnp.float32)
                   - y2[:, :-1].astype(jnp.float32)).max()
    assert float(diff) < 1e-2, f"future token leaked into the past: {diff}"
