"""Pure-jnp oracle for multi-head attention forward and backward.

This is the correctness ground truth for every fused kernel: pytest compares
`flash_fwd`/`flash_bwd` against these functions (and these against
`jax.vjp` of the forward), mirroring the paper's §4.2.3 accuracy protocol
(PyTorch_FP32 as the benchmark implementation).

Everything here computes in f32 (or f64 when `precise=True`), materialises
the full N×N score matrix, and is deliberately *unoptimised* — it is an
oracle, not a baseline.  The performance baseline with the paper's HBM
traffic pattern lives in `naive.py`.

Note on Equation 1 of the paper: it types ``P = softmax(S)/sqrt(d)``; the
standard (and FlashAttention-2's) scaling is ``softmax(S/sqrt(d))``, which
is what we use.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import rng

NEG_INF = -1e30


def causal_mask(n_q: int, n_k: int) -> jax.Array:
    """Lower-triangular boolean mask (True = attend)."""
    iq = jnp.arange(n_q)[:, None]
    ik = jnp.arange(n_k)[None, :]
    return iq >= ik


def mha_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
            causal: bool = False, scale: float | None = None,
            dropout_rate: float = 0.0, seed: jax.Array | float = 0.0,
            block_q: int = 128, block_k: int = 128,
            precise: bool = False) -> tuple[jax.Array, jax.Array]:
    """Reference MHA forward.

    Args:
      q, k, v: (bh, n, d) arrays; any float dtype (upcast internally).
      causal: apply the lower-triangular mask.
      scale: softmax temperature; defaults to 1/sqrt(d).
      dropout_rate / seed / block_q / block_k: dropout replay parameters —
        the mask is tile-derived (see `rng.py`) so block sizes must match
        the fused kernel under test.
      precise: compute in f64 (accuracy-table ground truth).

    Returns:
      (o, lse): o is (bh, n, d) in q's dtype; lse is (bh, n) f32 — the
      log-sum-exp statistics the backward pass recomputes from (the paper's
      "LES" record).
    """
    ctype = jnp.float64 if precise else jnp.float32
    bh, n_q, d = q.shape
    n_k = k.shape[1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qf, kf, vf = (x.astype(ctype) for x in (q, k, v))
    s = jnp.einsum("bnd,bmd->bnm", qf, kf) * scale
    if causal:
        s = jnp.where(causal_mask(n_q, n_k)[None], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    lse = (m + jnp.log(l)).astype(jnp.float32)
    p = p / l[..., None]
    if dropout_rate > 0.0:
        keep = rng.full_keep_mask(seed, bh, n_q, n_k, block_q, block_k,
                                  dropout_rate)
        p = jnp.where(keep, p / (1.0 - dropout_rate), 0.0)
    o = jnp.einsum("bnm,bmd->bnd", p, vf)
    return o.astype(q.dtype), lse


def mha_bwd(q: jax.Array, k: jax.Array, v: jax.Array, do: jax.Array, *,
            causal: bool = False, scale: float | None = None,
            dropout_rate: float = 0.0, seed: jax.Array | float = 0.0,
            block_q: int = 128, block_k: int = 128,
            precise: bool = False) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Reference MHA backward (Equation 4 of the paper), explicit form.

    Recomputes the forward internally (the paper's recomputation strategy at
    oracle fidelity) and returns (dq, dk, dv) in the input dtype.
    """
    ctype = jnp.float64 if precise else jnp.float32
    bh, n_q, d = q.shape
    n_k = k.shape[1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qf, kf, vf, dof = (x.astype(ctype) for x in (q, k, v, do))
    s = jnp.einsum("bnd,bmd->bnm", qf, kf) * scale
    if causal:
        s = jnp.where(causal_mask(n_q, n_k)[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if dropout_rate > 0.0:
        keep = rng.full_keep_mask(seed, bh, n_q, n_k, block_q, block_k,
                                  dropout_rate)
        scale_keep = jnp.where(keep, 1.0 / (1.0 - dropout_rate), 0.0)
        p_drop = p * scale_keep
    else:
        scale_keep = None
        p_drop = p

    # Equation 4:  dV = PᵀdO;  dP = dO Vᵀ;  dS = dsoftmax(dP);
    #              dQ = dS·K·scale;  dK = dSᵀ·Q·scale.
    dv = jnp.einsum("bnm,bnd->bmd", p_drop, dof)
    dp_drop = jnp.einsum("bnd,bmd->bnm", dof, vf)
    dp = dp_drop * scale_keep if scale_keep is not None else dp_drop
    # dsoftmax: dS = P ∘ (dP - rowsum(P_drop ∘ dP_drop)); the rowsum term is
    # the paper's dPsum = rowsum(dO ∘ O), computed here in expanded form.
    dpsum = jnp.sum(p_drop * dp_drop, axis=-1, keepdims=True)
    ds = p * (dp - dpsum)
    dq = jnp.einsum("bnm,bmd->bnd", ds, kf) * scale
    dk = jnp.einsum("bnm,bnd->bmd", ds, qf) * scale
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def attention_flops(bh: int, n: int, d: int, *, causal: bool,
                    backward: bool = False) -> int:
    """Matmul FLOPs of one MHA, the paper's Fig 10/11 TFLOPs denominator.

    Forward: 2 matmuls of 2·n²·d each; backward: 5 (Equation 4).  With the
    causal mask the workload halves ("the computational workload is reduced
    by half under the same configuration", §4.2.1).
    """
    matmuls = 5 if backward else 2
    flops = matmuls * 2 * n * n * d * bh
    return flops // 2 if causal else flops
