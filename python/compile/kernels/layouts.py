"""Block-shape selection and on-chip memory budgeting.

This module is the TPU analog of the paper's §3.2 layout reasoning.  On
Volta, SparkAttention sizes its thread-block tiles so Q plus the softmax
statistics stay resident in the 128 KB SRAM per SM while K/V stream through;
the m8n8k4 MMA shape quantises the tile dimensions.  On a TPU-style target
the binding constraints are instead

* VMEM (~16 MB/core) must hold the Q tile, one K/V tile pair, the S/P
  scratch tile, the f32 accumulator, and the (m, l) statistics — ×2 for
  double buffering of the streamed operands;
* the MXU's 128×128 systolic array quantises tile dimensions to multiples
  of 128 (8 sublanes × 128 lanes for bf16 loads).

`choose_blocks` picks (block_q, block_k) under those constraints and
`vmem_footprint` reports the budget, which `rust/src/perfmodel` consumes to
estimate real-hardware behaviour (interpret-mode wallclock is CPU-numpy,
not a TPU proxy — we optimise structure, then project).
"""

from __future__ import annotations

import dataclasses

MXU_TILE = 128
VMEM_BYTES = 16 * 1024 * 1024
ITEM_BYTES = {"bf16": 2, "f32": 4}


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """Chosen tile shape plus its static VMEM budget."""

    block_q: int
    block_k: int
    vmem_bytes: int
    mxu_utilization: float  # fraction of the 128×128 array a step fills


def vmem_footprint(block_q: int, block_k: int, d: int, *,
                   in_dtype: str = "bf16", acc_dtype: str = "f32",
                   double_buffer: bool = True) -> int:
    """Bytes of VMEM one forward grid step needs (DESIGN.md §7).

    Q tile + (K, V) tile pair (×2 when double-buffered) + S/P scratch +
    output accumulator + m/l statistics.
    """
    in_b, acc_b = ITEM_BYTES[in_dtype], ITEM_BYTES[acc_dtype]
    q_tile = block_q * d * in_b
    kv_tiles = 2 * block_k * d * in_b
    if double_buffer:
        kv_tiles *= 2
    sp_scratch = block_q * block_k * acc_b
    acc = block_q * d * acc_b
    stats = 2 * block_q * acc_b
    return q_tile + kv_tiles + sp_scratch + acc + stats


def mxu_utilization(block_q: int, block_k: int, d: int) -> float:
    """How fully a (block_q×d)·(d×block_k) step tiles the 128×128 MXU."""
    def frac(dim: int) -> float:
        return min(dim, MXU_TILE) / MXU_TILE

    return frac(block_q) * frac(block_k) * min(1.0, d / MXU_TILE)


def choose_blocks(n: int, d: int, *, in_dtype: str = "bf16",
                  acc_dtype: str = "f32",
                  vmem_budget: int = VMEM_BYTES) -> BlockConfig:
    """Largest MXU-aligned (block_q, block_k) that fits the VMEM budget.

    Prefers square 128×128 tiles (full MXU occupancy); shrinks block_k
    first — K/V tiles are the streamed operand, so smaller block_k costs
    loop trips, not extra HBM traffic.
    """
    candidates = [t for t in (256, 128, 64, 32, 16, 8) if t <= n]
    if not candidates:
        candidates = [n]
    for bq in candidates:
        for bk in candidates:
            fp = vmem_footprint(bq, bk, d, in_dtype=in_dtype,
                                acc_dtype=acc_dtype)
            if fp <= vmem_budget:
                return BlockConfig(bq, bk, fp, mxu_utilization(bq, bk, d))
    raise ValueError(
        f"no (block_q, block_k) fits VMEM budget {vmem_budget} for n={n} d={d}")


def hbm_bytes_fused_fwd(bh: int, n: int, d: int, *,
                        in_dtype: str = "bf16") -> int:
    """HBM traffic of the fused forward: 3 reads (Q,K,V) + 1 write (O).

    This is the paper's §3.2 claim; `rust/src/iomodel` re-derives the same
    number from a schedule simulation and the two are cross-checked in
    tests.  LSE (f32, n per head) is also written for the backward.
    """
    b = ITEM_BYTES[in_dtype]
    return bh * (4 * n * d * b + n * 4)


def hbm_bytes_unfused_fwd(bh: int, n: int, d: int, *,
                          in_dtype: str = "bf16") -> int:
    """HBM traffic of the unfused forward: 5 reads + 3 writes (§2.3).

    Reads: Q, K (→S), S (→P), P, V (→O); writes: S, P, O.  The N×N S and P
    round-trips dominate at long sequence length — the paper's motivation.
    """
    b = ITEM_BYTES[in_dtype]
    nn = n * n * b
    qkv_reads = 3 * n * d * b
    return bh * (qkv_reads + 2 * nn      # reads: Q,K,V + S + P
                 + 2 * nn + n * d * b)   # writes: S, P, O


def peak_bytes_unfused(bh: int, n: int, d: int, *,
                       in_dtype: str = "bf16") -> int:
    """Resident-memory high-water mark of the unfused forward (S and P live
    simultaneously with QKV) — drives the Fig 12 OOM cells."""
    b = ITEM_BYTES[in_dtype]
    return bh * (4 * n * d * b + 2 * n * n * b)


def fit_block(block: int, n: int) -> int:
    """Largest tile ≤ `block` that evenly divides `n` (≥ 1).

    Cross-attention memories need not be power-of-two sized; the grid
    requires exact tiling, so shrink to the nearest divisor.
    """
    b = min(block, n)
    while b > 1 and n % b:
        b -= 1
    return max(b, 1)
