"""Layer-1 Pallas kernels and their pure-jnp oracle.

* `flash_fwd` / `flash_bwd` — the SparkAttention fused MHA kernels
  (online softmax, two-stage matmul fusion, recomputation backward).
* `naive` — the unfused baseline with the paper's 5-read/3-write HBM
  pattern (the PyTorch_FP16 analog).
* `ref` — the correctness oracle (PyTorch_FP32 analog).
* `rng` — deterministic tile-level dropout masks shared by all of the above.
* `layouts` — block-shape selection and VMEM budget accounting.
"""

from . import flash_bwd, flash_fwd, layouts, naive, ref, rng  # noqa: F401
