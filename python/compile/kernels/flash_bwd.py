"""Fused MHA backward with forward recomputation — SparkAttention §3.3.

The paper implements the backward as one fused CUDA kernel that recomputes
the forward (saving only the per-row softmax statistics, its "LES" record),
accumulates dK/dV locally per thread block, and scatters dQ with HBM atomic
adds.  The TPU-style formulation of the *same dataflow* splits the pass into
three kernels (atomics have no Pallas analog; re-looping replaces them —
see DESIGN.md §3):

* `_preprocess_kernel` — the paper's **dPsum**: Δ = rowsum(dO ∘ O).
* `_dkv_kernel` — grid over K-blocks, inner loop over Q-blocks; recomputes
  the (Sᵢⱼ − Lᵢ) exponentials and locally accumulates dK, dV, exactly the
  per-TB accumulation of Figure 9.
* `_dq_kernel` — grid over Q-blocks, inner loop over K-blocks; accumulates
  dQ in VMEM scratch instead of HBM atomics.

Per §3.1 the paper ships only FP16-ACC for the backward ("MHA-Backward does
not require high precision"); we default to the bf16-ACC analog and keep
f32-ACC available for the accuracy study.

Dropout replays the forward's tile-counter masks (`rng.py`) — bit-identical,
no mask tensor in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import layouts, rng
from .flash_fwd import ACC_DTYPES, NEG_INF


def _preprocess_kernel(o_ref, do_ref, delta_ref):
    """Δ = rowsum(dO ∘ O) — the paper's dPsum, one Q-block per step."""
    o = o_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    delta_ref[0] = jnp.sum(o * do, axis=1)


def _recompute_p(q, k, lse, *, scale, causal, iq, ik, block_q, block_k, acc):
    """Recompute the normalised P tile from Q, K and the saved LSE.

    ``exp(S − L)`` of Figure 9: no second softmax pass is needed because the
    forward's log-sum-exp already normalises.
    """
    acc_t = ACC_DTYPES[acc]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=acc_t)
    s = s.astype(jnp.float32) * scale
    if causal:
        span_q = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        span_k = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(span_q >= span_k, s, NEG_INF)
    return jnp.exp(s - lse[:, None])


def _dq_kernel(seed_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
               dq_ref, dq_acc_ref, *, scale: float, causal: bool,
               dropout_rate: float, nq: int, nk: int, block_q: int,
               block_k: int, acc: str):
    """dQ = Σ_k dS·K·scale, accumulated across K-blocks in VMEM scratch."""
    b, iq, ik = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        dq_acc_ref[...] = jnp.zeros_like(dq_acc_ref)

    def _step():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        delta = delta_ref[0]
        acc_t = ACC_DTYPES[acc]

        p = _recompute_p(q, k, lse, scale=scale, causal=causal, iq=iq, ik=ik,
                         block_q=block_q, block_k=block_k, acc=acc)
        # dP = dO·Vᵀ; with dropout, route through the replayed mask.
        dp = jax.lax.dot_general(do.astype(v.dtype), v,
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=acc_t
                                 ).astype(jnp.float32)
        if dropout_rate > 0.0:
            keep = rng.tile_keep_mask(seed_ref[0], b, iq, ik, nq, nk,
                                      dp.shape, dropout_rate)
            dp = jnp.where(keep, dp / (1.0 - dropout_rate), 0.0)
        # dS = P ∘ (dP − Δ) (the dsoftmax of Equation 4).
        ds = (p * (dp - delta[:, None]) * scale).astype(q.dtype)
        dq_acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=acc_t).astype(dq_acc_ref.dtype)

    if causal:
        pl.when(ik * block_k <= iq * block_q + block_q - 1)(_step)
    else:
        _step()

    @pl.when(ik == nk - 1)
    def _finalize():
        dq_ref[0] = dq_acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(seed_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc_ref, dv_acc_ref, *, scale: float,
                causal: bool, dropout_rate: float, nq: int, nk: int,
                block_q: int, block_k: int, acc: str):
    """dK, dV accumulated per K-block over an inner sweep of Q-blocks.

    This is the paper's per-thread-block dK/dV accumulation (Figure 9): one
    grid row owns one K-block and sees every Q-block stream past it.
    """
    b, ik, iq = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        dk_acc_ref[...] = jnp.zeros_like(dk_acc_ref)
        dv_acc_ref[...] = jnp.zeros_like(dv_acc_ref)

    def _step():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        delta = delta_ref[0]
        acc_t = ACC_DTYPES[acc]

        p = _recompute_p(q, k, lse, scale=scale, causal=causal, iq=iq, ik=ik,
                         block_q=block_q, block_k=block_k, acc=acc)
        if dropout_rate > 0.0:
            keep = rng.tile_keep_mask(seed_ref[0], b, iq, ik, nq, nk,
                                      p.shape, dropout_rate)
            p_drop = jnp.where(keep, p / (1.0 - dropout_rate), 0.0)
        else:
            keep = None
            p_drop = p
        # dV += P_dropᵀ·dO  (Equation 4, first line).
        dv_acc_ref[...] += jax.lax.dot_general(
            p_drop.astype(do.dtype), do, (((0,), (0,)), ((), ())),
            preferred_element_type=acc_t).astype(dv_acc_ref.dtype)
        dp = jax.lax.dot_general(do.astype(v.dtype), v,
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=acc_t
                                 ).astype(jnp.float32)
        if keep is not None:
            dp = jnp.where(keep, dp / (1.0 - dropout_rate), 0.0)
        ds = (p * (dp - delta[:, None]) * scale).astype(q.dtype)
        # dK += dSᵀ·Q (Equation 4, last line).
        dk_acc_ref[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=acc_t).astype(dk_acc_ref.dtype)

    if causal:
        pl.when(ik * block_k <= iq * block_q + block_q - 1)(_step)
    else:
        _step()

    @pl.when(iq == nq - 1)
    def _finalize():
        dk_ref[0] = dk_acc_ref[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc_ref[...].astype(dv_ref.dtype)


def _resolve_blocks(n: int, d: int, block_q: int | None,
                    block_k: int | None) -> tuple[int, int]:
    if block_q is None or block_k is None:
        cfg = layouts.choose_blocks(n, d)
        block_q = block_q or cfg.block_q
        block_k = block_k or cfg.block_k
    # divisibility is enforced (for explicit blocks) and repaired (for
    # defaults, via layouts.fit_block) by the caller
    return min(block_q, n), min(block_k, n)


def dpsum(o: jax.Array, do: jax.Array, *, block_q: int = 128) -> jax.Array:
    """Δ = rowsum(dO ∘ O) as a Pallas preprocess kernel (paper's dPsum)."""
    bh, n, d = o.shape
    bq = min(block_q, n)
    return pl.pallas_call(
        _preprocess_kernel,
        grid=(bh, n // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, iq: (b, iq, 0)),
            pl.BlockSpec((1, bq, d), lambda b, iq: (b, iq, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq), lambda b, iq: (b, iq)),
        out_shape=jax.ShapeDtypeStruct((bh, n), jnp.float32),
        interpret=True,
    )(o, do)


def flash_bwd(q: jax.Array, k: jax.Array, v: jax.Array, o: jax.Array,
              lse: jax.Array, do: jax.Array,
              seed: jax.Array | float = 0.0, *, causal: bool = False,
              scale: float | None = None, dropout_rate: float = 0.0,
              acc: str = "bf16", block_q: int | None = None,
              block_k: int | None = None
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused MHA backward: (dq, dk, dv) from the recomputation dataflow.

    Args mirror `flash_fwd`; `o` and `lse` are the forward's outputs (only
    the statistics are *required* — O enters only through dPsum — matching
    the paper's memory-saving claim).  Default ``acc="bf16"`` per §3.1.
    """
    bh, n, d = q.shape
    n_kv = k.shape[1]
    if causal and n_kv != n:
        raise ValueError("causal masking requires n_q == n_kv")
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    explicit_q, explicit_k = block_q is not None, block_k is not None
    block_q, block_k = _resolve_blocks(max(n, n_kv), d, block_q, block_k)
    if (explicit_q and n % min(block_q, n)) \
            or (explicit_k and n_kv % min(block_k, n_kv)):
        raise ValueError(
            f"(n={n}, n_kv={n_kv}) not divisible by blocks "
            f"({block_q},{block_k})")
    block_q = layouts.fit_block(block_q, n)
    block_k = layouts.fit_block(block_k, n_kv)
    nq, nk = n // block_q, n_kv // block_k
    if acc not in ACC_DTYPES:
        raise ValueError(f"acc must be one of {sorted(ACC_DTYPES)}, got {acc}")
    seed_arr = jnp.asarray(seed, jnp.float32).reshape(1)
    delta = dpsum(o, do, block_q=block_q)
    common = dict(scale=scale, causal=causal, dropout_rate=dropout_rate,
                  nq=nq, nk=nk, block_q=block_q, block_k=block_k, acc=acc)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **common),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, iq, ik: (0,)),             # seed
            pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_q), lambda b, iq, ik: (b, iq)),  # lse
            pl.BlockSpec((1, block_q), lambda b, iq, ik: (b, iq)),  # delta
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=True,
    )(seed_arr, q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, **common),
        grid=(bh, nk, nq),
        in_specs=[
            pl.BlockSpec((1,), lambda b, ik, iq: (0,)),             # seed
            pl.BlockSpec((1, block_q, d), lambda b, ik, iq: (b, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ik, iq: (b, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ik, iq: (b, ik, 0)),
            pl.BlockSpec((1, block_q, d), lambda b, ik, iq: (b, iq, 0)),
            pl.BlockSpec((1, block_q), lambda b, ik, iq: (b, iq)),  # lse
            pl.BlockSpec((1, block_q), lambda b, ik, iq: (b, iq)),  # delta
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), lambda b, ik, iq: (b, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ik, iq: (b, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n_kv, d), k.dtype),
            jax.ShapeDtypeStruct((bh, n_kv, d), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, d), jnp.float32),
            pltpu.VMEM((block_k, d), jnp.float32),
        ],
        interpret=True,
    )(seed_arr, q, k, v, do, lse, delta)
    return dq, dk, dv
