"""Fused transformer FFN (bias + GELU + second matmul) as a Pallas kernel.

This kernel is **not** part of the paper's contribution — SparkAttention
"only focuses on optimizing the computation of MHA" (§3.1).  It exists to
build the *FasterTransformer analog* for the Fig 12 end-to-end comparison:
FT wins at head-dim 64 because "excluding the computation of MHA-Forward,
FasterTransformer leverages techniques such as layer fusion" (§4.2.4).  Our
`fully_fused` encoder variant = flash attention + this kernel, reproducing
that competitive dynamic.

Schedule: grid over row-blocks of the (B·N, d_model) activation; per step
the (block, d_ff) intermediate lives only in kernel scope (one HBM
round-trip saved versus the staged baseline).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gelu(x: jax.Array) -> jax.Array:
    """tanh-approximation GELU, computed in f32."""
    c = 0.7978845608028654  # sqrt(2/pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x ** 3)))


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    w1 = w1_ref[...].astype(jnp.float32)
    h = _gelu(jnp.dot(x, w1, preferred_element_type=jnp.float32)
              + b1_ref[...].astype(jnp.float32))
    w2 = w2_ref[...].astype(jnp.float32)
    o = jnp.dot(h, w2, preferred_element_type=jnp.float32) \
        + b2_ref[...].astype(jnp.float32)
    o_ref[...] = o.astype(o_ref.dtype)


def ffn_fused(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array,
              b2: jax.Array, *, block_rows: int = 128) -> jax.Array:
    """y = GELU(x·W1 + b1)·W2 + b2 with the intermediate kept on-chip.

    Args:
      x: (rows, d_model) activations (callers flatten batch × seq).
      w1: (d_model, d_ff); b1: (d_ff,); w2: (d_ff, d_model); b2: (d_model,).
    """
    rows, d_model = x.shape
    d_ff = w1.shape[1]
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows={rows} not divisible by block_rows={br}")
    return pl.pallas_call(
        functools.partial(_ffn_kernel),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d_model), lambda i: (i, 0)),
            pl.BlockSpec((d_model, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((d_ff,), lambda i: (0,)),
            pl.BlockSpec((d_ff, d_model), lambda i: (0, 0)),
            pl.BlockSpec((d_model,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d_model), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d_model), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)
