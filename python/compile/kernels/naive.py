"""Unfused MHA baseline — the paper's PyTorch_FP16 / cuBLAS analog (§2.3).

The traditional schedule the paper benchmarks against:

    1. read Q, K      → S = Q·Kᵀ       → write S to HBM
    2. read S         → P = softmax(S)  → write P to HBM
    3. read P, V      → O = P·V        → write O to HBM

i.e. **5 HBM reads + 3 writes**, with two N×N round-trips and an N×N
resident high-water mark (the OOM driver in Fig 10/12).  To keep the
baseline honest under XLA — which would otherwise fuse the softmax into the
matmuls — each stage boundary carries `jax.lax.optimization_barrier`, the
compiler-level equivalent of PyTorch dispatching three separate cuBLAS /
elementwise kernels.  The N×N S and P tensors are therefore genuinely
materialised, byte-for-byte like the paper's baseline.

Dropout draws one full-tensor mask per call (a PyTorch-style `dropout`
kernel over the materialised P — more HBM traffic, faithfully).  The mask
therefore differs from the fused kernels' tile-counter masks; accuracy
comparisons across implementations are done at ``dropout_rate = 0``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import rng
from .ref import NEG_INF, causal_mask


def _barrier(x: jax.Array) -> jax.Array:
    """Stage boundary: forces XLA to materialise `x` (an HBM round-trip)."""
    return jax.lax.optimization_barrier(x)


def mha_fwd_unfused(q: jax.Array, k: jax.Array, v: jax.Array,
                    seed: jax.Array | float = 0.0, *, causal: bool = False,
                    scale: float | None = None, dropout_rate: float = 0.0,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Three-stage unfused forward; returns O (bh, n, d) in input dtype."""
    bh, n, d = q.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    # Stage 1: S = Q·Kᵀ (one cuBLAS-style batched GEMM; fp16 in/out).
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    s = (s * scale).astype(q.dtype)
    s = _barrier(s)

    # Stage 2: P = softmax(S) (separate elementwise/reduction kernels).
    sf = s.astype(jnp.float32)
    if causal:
        sf = jnp.where(causal_mask(n, n)[None], sf, NEG_INF)
    p = jax.nn.softmax(sf, axis=-1)
    if dropout_rate > 0.0:
        keep = rng.full_tensor_keep_mask(seed, p.shape, dropout_rate)
        p = jnp.where(keep, p / (1.0 - dropout_rate), 0.0)
    p = p.astype(q.dtype)
    p = _barrier(p)

    # Stage 3: O = P·V (second batched GEMM).
    o = jax.lax.dot_general(p, v, (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def mha_bwd_unfused(q: jax.Array, k: jax.Array, v: jax.Array, do: jax.Array,
                    seed: jax.Array | float = 0.0, *, causal: bool = False,
                    scale: float | None = None, dropout_rate: float = 0.0,
                    block_q: int = 128, block_k: int = 128
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Unfused backward via `jax.vjp` of the staged forward.

    PyTorch autograd replays the same staged kernels in reverse, saving S
    and P from the forward; `optimization_barrier` in the primal keeps the
    cotangent graph staged the same way, so the N×N tensors round-trip
    through HBM here too (the paper's 'PyTorch_FP16' backward).
    """
    def fwd(q, k, v):
        return mha_fwd_unfused(q, k, v, seed, causal=causal, scale=scale,
                               dropout_rate=dropout_rate, block_q=block_q,
                               block_k=block_k)

    _, pullback = jax.vjp(fwd, q, k, v)
    return pullback(do)
