"""Fused MHA forward — the SparkAttention kernel, TPU-style (Pallas).

Maps the paper's §3.2 Volta design onto Pallas primitives:

* **Thread-block grid over (batch·head, Q-blocks)** → pallas ``grid =
  (bh, n/block_q, n/block_k)``; the innermost K-block dimension iterates
  sequentially so VMEM scratch carries the online-softmax state across it
  (the role the paper's per-TB SRAM plays in Figure 6).
* **Online softmax (§3.2.1)** → running (m, l) statistics in VMEM scratch;
  each step rescales the accumulator by ``exp(m_prev − m_cur)`` exactly as
  Equation 3.
* **Warp-level layout transform (§3.2.2)** → the S/P tile lives only as a
  kernel-local value between the two ``dot``s; the second matmul consumes
  it directly, so the fusion boundary (the pallas kernel body) *is* the
  layout transform — no HBM round-trip for the N×N matrix, 3 HBM reads +
  1 write per MHA.
* **FP16-ACC vs FP32-ACC (§3.1)** → ``acc ∈ {"bf16", "f32"}``: the MMA
  ``preferred_element_type`` and the dtype the S tile is produced in.  The
  bf16 variant converts to f32 for the softmax (the conversion overhead the
  paper measures); the f32 variant needs no conversion (its cost on Volta —
  the shuffle — has no TPU analog, the reduction is free within a tile).
* **Fused dropout** → tile-counter RNG (`rng.py`), no mask tensor in HBM.

``interpret=True`` everywhere: CPU-PJRT cannot execute Mosaic custom-calls;
structure (blocking, scratch residency, grid order) is what we optimise,
and `layouts.py` + `rust/src/perfmodel` project real-hardware behaviour.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import layouts, rng

NEG_INF = -1e30

ACC_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def _fwd_kernel(seed_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_ref, l_ref, acc_ref, *, scale: float, causal: bool,
                dropout_rate: float, nq: int, nk: int, block_q: int,
                block_k: int, acc: str):
    """One (batch·head, iq, ik) grid step of the fused forward."""
    b, iq, ik = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _step():
        q = q_ref[0]
        k = k_ref[0]
        # Stage 1: S = Q·Kᵀ on the matrix unit.  FP16-ACC produces the tile
        # in bf16 and pays an explicit conversion before the softmax, the
        # trade-off §4.2.1 measures; FP32-ACC accumulates wide directly.
        acc_t = ACC_DTYPES[acc]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=acc_t)
        s = s.astype(jnp.float32) * scale
        if causal:
            span_q = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            span_k = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(span_q >= span_k, s, NEG_INF)

        # Online softmax (Equation 3): fold this block into (m, l) and
        # rescale the running accumulator.
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_prev * alpha + p.sum(axis=1)
        m_ref[...] = m_cur

        if dropout_rate > 0.0:
            keep = rng.tile_keep_mask(seed_ref[0], b, iq, ik, nq, nk,
                                      p.shape, dropout_rate)
            p = jnp.where(keep, p / (1.0 - dropout_rate), 0.0)

        # Stage 2: the P tile feeds the second matmul *in place* — the
        # layout-transform analog; it never leaves the kernel.
        v = v_ref[0]
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=acc_t)
        acc_ref[...] = acc_ref[...] * alpha[:, None].astype(acc_ref.dtype) \
            + pv.astype(acc_ref.dtype)

    if causal:
        # K-blocks strictly above the diagonal contribute nothing; skip
        # their matmuls (the paper's "workload reduced by half", §4.2.1).
        pl.when(ik * block_k <= iq * block_q + block_q - 1)(_step)
    else:
        _step()

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...].astype(jnp.float32)
                    / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(l)


def flash_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
              seed: jax.Array | float = 0.0, *, causal: bool = False,
              scale: float | None = None, dropout_rate: float = 0.0,
              acc: str = "f32", block_q: int | None = None,
              block_k: int | None = None
              ) -> tuple[jax.Array, jax.Array]:
    """Fused MHA forward.

    Args:
      q: (bh, n, d); k, v: (bh, n_kv, d) — cross-attention (the decoder's
        second MHA in Figure 1) is supported via n_kv ≠ n.  bf16 in
        production, any float dtype in tests.
      seed: f32 scalar dropout seed (see `rng.py`); ignored if
        ``dropout_rate == 0``.
      causal: lower-triangular masking.
      scale: softmax temperature, default 1/sqrt(d).
      acc: "f32" (FP32-ACC) or "bf16" (FP16-ACC analog).
      block_q / block_k: tile shape; default from `layouts.choose_blocks`.

    Returns:
      (o, lse): o (bh, n, d) in the input dtype; lse (bh, n) f32, saved for
      the recomputation backward.
    """
    bh, n, d = q.shape
    n_kv = k.shape[1]
    if v.shape != k.shape or k.shape[0] != bh or k.shape[2] != d:
        raise ValueError(f"k/v shape {k.shape} incompatible with q {q.shape}")
    if causal and n_kv != n:
        raise ValueError("causal masking requires n_q == n_kv")
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    explicit_q, explicit_k = block_q is not None, block_k is not None
    if block_q is None or block_k is None:
        cfg = layouts.choose_blocks(max(n, n_kv), d)
        block_q = block_q or cfg.block_q
        block_k = block_k or cfg.block_k
    if (explicit_q and n % min(block_q, n)) \
            or (explicit_k and n_kv % min(block_k, n_kv)):
        raise ValueError(
            f"(n={n}, n_kv={n_kv}) not divisible by blocks "
            f"({block_q},{block_k})")
    block_q = layouts.fit_block(block_q, n)
    block_k = layouts.fit_block(block_k, n_kv)
    nq, nk = n // block_q, n_kv // block_k
    if acc not in ACC_DTYPES:
        raise ValueError(f"acc must be one of {sorted(ACC_DTYPES)}, got {acc}")

    kern = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, dropout_rate=dropout_rate,
        nq=nq, nk=nk, block_q=block_q, block_k=block_k, acc=acc)
    seed_arr = jnp.asarray(seed, jnp.float32).reshape(1)
    acc_t = ACC_DTYPES[acc]
    return pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, iq, ik: (0,)),           # seed
            pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_q), lambda b, iq, ik: (b, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, d), q.dtype),
            jax.ShapeDtypeStruct((bh, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # running row max m
            pltpu.VMEM((block_q,), jnp.float32),   # running row sum l
            pltpu.VMEM((block_q, d), acc_t),       # output accumulator
        ],
        interpret=True,
    )(seed_arr, q, k, v)
