"""Deterministic, counter-based dropout masks shared by kernel and oracle.

The paper fuses dropout (rate 0.1) into the MHA kernels and replays the
*same* mask in the backward pass ("We apply the same dropout logic as in the
MHA-Forward process to obtain consistent dropout results", §4.2.2).  On the
GPU this is done with a counter-based RNG seeded per thread; our TPU-style
analog derives one PRNG key per (batch-head, q-block, k-block) tile via
`jax.random.fold_in`, so

* the forward kernel, the two backward kernels, and the pure-jnp oracle all
  regenerate bit-identical masks from `(seed, tile index)` alone — no mask
  tensor ever exists in HBM, and
* the mask depends only on the *logical* tile index, not on the grid
  iteration order, so any schedule reproduces it.

The seed travels as an f32 scalar (bit-exact for step counters < 2^24) so it
can pass through `jax.custom_vjp` without a float0 cotangent dance; kernels
read it with `seed_ref[0]`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


GOLDEN = 0x9E3779B9


def _murmur_fmix(x: jax.Array) -> jax.Array:
    """murmur3's 32-bit finalizer: ~5 integer ops, full avalanche."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _tile_lin(seed: jax.Array, b: jax.Array, iq: jax.Array, ik: jax.Array,
              nq: int, nk: int) -> jax.Array:
    """Mixed (seed, tile) word — the per-tile stream id."""
    seed_u32 = jnp.asarray(seed, jnp.float32).reshape(()).astype(jnp.uint32)
    lin = (b.astype(jnp.uint32) * jnp.uint32(nq * nk)
           + iq.astype(jnp.uint32) * jnp.uint32(nk)
           + ik.astype(jnp.uint32))
    return _murmur_fmix(lin ^ (seed_u32 * jnp.uint32(GOLDEN)))


def tile_keep_mask(seed: jax.Array, b: jax.Array, iq: jax.Array,
                   ik: jax.Array, nq: int, nk: int, shape: tuple[int, int],
                   rate: float) -> jax.Array:
    """Boolean keep-mask (True = keep) for one (block_q, block_k) tile.

    Counter-based hash (two murmur3 finalizer rounds per element) instead
    of threefry: §Perf P-L1-2 measured threefry at ~35% of the fused
    kernels' runtime on the CPU substrate; the 10-int-op hash has the same
    replay/determinism properties at a fraction of the cost (the role
    cuRAND Philox plays in the paper's CUDA kernels).
    """
    if rate <= 0.0:
        return jnp.ones(shape, jnp.bool_)
    stream = _tile_lin(seed, b, iq, ik, nq, nk)
    rows = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    elem = rows * jnp.uint32(shape[1]) + cols
    bits = _murmur_fmix(elem * jnp.uint32(GOLDEN) ^ stream)
    # uniform in [0,1) from the top 24 bits; keep iff u >= rate
    u = (bits >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24))
    return u >= jnp.float32(rate)


def full_tensor_keep_mask(seed: jax.Array, shape: tuple[int, ...],
                          rate: float) -> jax.Array:
    """Single-draw keep-mask over a whole tensor (the unfused baseline's
    dropout kernel).  Same hash as the tile masks so baseline and fused
    kernels pay comparable RNG cost — cuRAND-Philox-class, not threefry —
    but a different stream (masks are not meant to match across impls)."""
    if rate <= 0.0:
        return jnp.ones(shape, jnp.bool_)
    seed_u32 = jnp.asarray(seed, jnp.float32).reshape(()).astype(jnp.uint32)
    n = 1
    for dim in shape:
        n *= dim
    elem = jax.lax.iota(jnp.uint32, n).reshape(shape)
    bits = _murmur_fmix(elem * jnp.uint32(GOLDEN)
                        ^ _murmur_fmix(seed_u32 + jnp.uint32(1)))
    u = (bits >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24))
    return u >= jnp.float32(rate)


def full_keep_mask(seed: jax.Array, bh: int, n_q: int, n_k: int,
                   block_q: int, block_k: int, rate: float) -> jax.Array:
    """Assemble the full (bh, n_q, n_k) keep-mask from per-tile draws.

    Used only by the oracle (`ref.py`) and tests; the fused kernels never
    materialise this tensor.  Bit-identical to the per-tile draws above.
    """
    if rate <= 0.0:
        return jnp.ones((bh, n_q, n_k), jnp.bool_)
    nq, nk = n_q // block_q, n_k // block_k
    rows = []
    for b in range(bh):
        qrows = []
        for iq in range(nq):
            krows = [
                tile_keep_mask(seed, jnp.uint32(b), jnp.uint32(iq),
                               jnp.uint32(ik), nq, nk, (block_q, block_k),
                               rate)
                for ik in range(nk)
            ]
            qrows.append(jnp.concatenate(krows, axis=1))
        rows.append(jnp.concatenate(qrows, axis=0))
    return jnp.stack(rows, axis=0)
