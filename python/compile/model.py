"""Layer-2 transformer model: encoder stack, LM head, loss, Adam train step.

This is the compute graph the Rust coordinator drives at run time.  It is
written so each piece lowers to a single HLO entry point:

* `encoder_forward` — the Fig 12 end-to-end workload (one or more encoder
  layers) in three fusion variants:
    - ``unfused``     → staged attention (PyTorch_JIT analog),
    - ``fused``       → SparkAttention flash MHA (ours),
    - ``fully_fused`` → flash MHA + fused FFN kernel (FasterTransformer
      analog; wins when non-MHA time dominates, as in the paper §4.2.4).
* `loss_fn` / `train_step` — next-token LM training with Adam; exported as
  one HLO so the Rust side runs a full optimizer step per `execute` call.

Parameters are a nested dict; `flatten_params` fixes a deterministic
ordering (recorded in the artifact manifest) so Rust can manage them as a
flat buffer list.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from . import mha
from .kernels import fused_ffn


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture + training hyperparameters."""

    vocab: int = 256
    d_model: int = 128
    num_heads: int = 4
    d_ff: int = 512
    num_layers: int = 2
    seq: int = 128
    batch: int = 8
    causal: bool = True
    dropout_rate: float = 0.0
    attn_impl: str = "fused"        # "fused" | "unfused" | "fully_fused"
    acc_fwd: str = "f32"
    acc_bwd: str = "bf16"
    dtype: str = "bf16"
    # Adam
    lr: float = 3e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads

    @property
    def jdtype(self):
        return {"bf16": jnp.bfloat16, "f32": jnp.float32}[self.dtype]

    def attention(self) -> Callable:
        impl = "unfused" if self.attn_impl == "unfused" else "fused"
        return mha.make_attention(mha.AttentionConfig(
            causal=self.causal, dropout_rate=self.dropout_rate,
            acc_fwd=self.acc_fwd, acc_bwd=self.acc_bwd, impl=impl))


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """Initialise all trainable parameters (nested dict pytree)."""
    dt = cfg.jdtype
    keys = jax.random.split(key, cfg.num_layers + 3)
    s = cfg.d_model ** -0.5

    def layer_params(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "attn": mha.init_mha_params(k1, cfg.d_model, dt),
            "ln1_g": jnp.ones((cfg.d_model,), dt),
            "ln1_b": jnp.zeros((cfg.d_model,), dt),
            "ln2_g": jnp.ones((cfg.d_model,), dt),
            "ln2_b": jnp.zeros((cfg.d_model,), dt),
            "w1": (jax.random.normal(k2, (cfg.d_model, cfg.d_ff)) * s).astype(dt),
            "b1": jnp.zeros((cfg.d_ff,), dt),
            "w2": (jax.random.normal(k3, (cfg.d_ff, cfg.d_model))
                   * cfg.d_ff ** -0.5).astype(dt),
            "b2": jnp.zeros((cfg.d_model,), dt),
        }

    return {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                  * 0.02).astype(dt),
        "pos": (jax.random.normal(keys[1], (cfg.seq, cfg.d_model))
                * 0.02).astype(dt),
        "layers": [layer_params(keys[2 + i]) for i in range(cfg.num_layers)],
        "lnf_g": jnp.ones((cfg.d_model,), dt),
        "lnf_b": jnp.zeros((cfg.d_model,), dt),
        "head": (jax.random.normal(keys[-1], (cfg.d_model, cfg.vocab))
                 * s).astype(dt),
    }


def flatten_params(params) -> tuple[list[jax.Array], object]:
    """Deterministic flat ordering for the Rust buffer protocol."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    return leaves, treedef


def param_names(params) -> list[str]:
    """Stable slash-joined names aligned with `flatten_params` order."""
    paths = jax.tree_util.tree_flatten_with_path(params)[0]
    return ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in paths]


# --------------------------------------------------------------------------
# Forward graph
# --------------------------------------------------------------------------

def layer_norm(x: jax.Array, g: jax.Array, b: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype) * g + b


def _gelu(x: jax.Array) -> jax.Array:
    c = 0.7978845608028654
    xf = x.astype(jnp.float32)
    return (0.5 * xf * (1.0 + jnp.tanh(c * (xf + 0.044715 * xf ** 3)))
            ).astype(x.dtype)


def ffn(x: jax.Array, lp: dict, *, fused: bool) -> jax.Array:
    """Position-wise FFN; optionally the fused Pallas kernel (FT analog)."""
    if fused:
        b, n, dm = x.shape
        y = fused_ffn.ffn_fused(x.reshape(b * n, dm), lp["w1"], lp["b1"],
                                lp["w2"], lp["b2"])
        return y.reshape(b, n, dm)
    return _gelu(x @ lp["w1"] + lp["b1"]) @ lp["w2"] + lp["b2"]


def encoder_layer(x: jax.Array, lp: dict, seed: jax.Array, *,
                  cfg: ModelConfig, attn: Callable) -> jax.Array:
    """Pre-LN encoder layer: x + MHA(LN(x)); x + FFN(LN(x))."""
    h = layer_norm(x, lp["ln1_g"], lp["ln1_b"])
    x = x + mha.mha_layer(h, lp["attn"], seed, num_heads=cfg.num_heads,
                          attn=attn)
    h = layer_norm(x, lp["ln2_g"], lp["ln2_b"])
    return x + ffn(h, lp, fused=cfg.attn_impl == "fully_fused")


def encoder_forward(params: dict, x: jax.Array, seed: jax.Array, *,
                    cfg: ModelConfig) -> jax.Array:
    """Hidden-states-in → hidden-states-out encoder stack (Fig 12 workload).

    `x` is (batch, seq, d_model) activations — the Fig 12 benchmark measures
    the encoder layer itself, embedding excluded, like the baselines.
    """
    attn = cfg.attention()
    for i, lp in enumerate(params["layers"]):
        x = encoder_layer(x, lp, seed + jnp.float32(i), cfg=cfg, attn=attn)
    return x


def lm_forward(params: dict, tokens: jax.Array, seed: jax.Array, *,
               cfg: ModelConfig) -> jax.Array:
    """Token ids (batch, seq) → logits (batch, seq, vocab)."""
    x = params["embed"][tokens] + params["pos"][None, :tokens.shape[1]]
    x = encoder_forward(params, x, seed, cfg=cfg)
    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    return (x @ params["head"]).astype(jnp.float32)


def loss_fn(params: dict, tokens: jax.Array, seed: jax.Array, *,
            cfg: ModelConfig) -> jax.Array:
    """Next-token cross-entropy, mean over (batch, seq−1)."""
    logits = lm_forward(params, tokens[:, :-1], seed, cfg=cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return nll.mean()


# --------------------------------------------------------------------------
# Adam train step (exported as a single HLO entry point)
# --------------------------------------------------------------------------

def init_opt_state(params) -> dict:
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros_like(a, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params)}


def train_step(params: dict, opt: dict, step: jax.Array, tokens: jax.Array,
               seed: jax.Array, *, cfg: ModelConfig):
    """One fused forward + backward + Adam update.

    Returns (params', opt', loss).  `step` is f32 (1-based) for the bias
    correction; Rust increments it between calls.
    """
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, tokens, seed, cfg=cfg))(params)

    t = step
    b1, b2 = cfg.beta1, cfg.beta2

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mhat = m2 / (1 - b1 ** t)
        vhat = v2 / (1 - b2 ** t)
        p2 = p.astype(jnp.float32) - cfg.lr * mhat / (jnp.sqrt(vhat) + cfg.eps)
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v}, loss


# --------------------------------------------------------------------------
# Decoder (Figure 1's right-hand stack: masked self-attn + cross-attn + FFN)
# --------------------------------------------------------------------------

def init_decoder_layer_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """Decoder layer = encoder layer params + a cross-attention block."""
    k1, k2 = jax.random.split(key)
    dt = cfg.jdtype
    base_key, cross_key = jax.random.split(k1)
    lp = {
        "attn": mha.init_mha_params(base_key, cfg.d_model, dt),
        "cross": mha.init_mha_params(cross_key, cfg.d_model, dt),
        "ln1_g": jnp.ones((cfg.d_model,), dt),
        "ln1_b": jnp.zeros((cfg.d_model,), dt),
        "ln2_g": jnp.ones((cfg.d_model,), dt),
        "ln2_b": jnp.zeros((cfg.d_model,), dt),
        "ln3_g": jnp.ones((cfg.d_model,), dt),
        "ln3_b": jnp.zeros((cfg.d_model,), dt),
        "w1": (jax.random.normal(k2, (cfg.d_model, cfg.d_ff))
               * cfg.d_model ** -0.5).astype(dt),
        "b1": jnp.zeros((cfg.d_ff,), dt),
        "w2": (jax.random.normal(jax.random.fold_in(k2, 1),
                                 (cfg.d_ff, cfg.d_model))
               * cfg.d_ff ** -0.5).astype(dt),
        "b2": jnp.zeros((cfg.d_model,), dt),
    }
    return lp


def decoder_layer(x: jax.Array, memory: jax.Array, lp: dict,
                  seed: jax.Array, *, cfg: ModelConfig) -> jax.Array:
    """Pre-LN decoder layer: masked self-attn → cross-attn → FFN.

    Self-attention is always causal (the decoder's "masked computation");
    cross-attention attends over the full encoder memory (no mask), with
    possibly different source/target lengths — both run through the fused
    SparkAttention kernels.
    """
    self_attn = mha.make_attention(mha.AttentionConfig(
        causal=True, dropout_rate=cfg.dropout_rate, acc_fwd=cfg.acc_fwd,
        acc_bwd=cfg.acc_bwd,
        impl="unfused" if cfg.attn_impl == "unfused" else "fused"))
    cross_attn = mha.make_attention(mha.AttentionConfig(
        causal=False, dropout_rate=cfg.dropout_rate, acc_fwd=cfg.acc_fwd,
        acc_bwd=cfg.acc_bwd,
        impl="unfused" if cfg.attn_impl == "unfused" else "fused"))

    h = layer_norm(x, lp["ln1_g"], lp["ln1_b"])
    x = x + mha.mha_layer(h, lp["attn"], seed, num_heads=cfg.num_heads,
                          attn=self_attn)
    h = layer_norm(x, lp["ln2_g"], lp["ln2_b"])
    x = x + mha.mha_layer_cross(h, memory, lp["cross"],
                                seed + jnp.float32(101),
                                num_heads=cfg.num_heads, attn=cross_attn)
    h = layer_norm(x, lp["ln3_g"], lp["ln3_b"])
    return x + ffn(h, lp, fused=cfg.attn_impl == "fully_fused")
