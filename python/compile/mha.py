"""Differentiable multi-head attention built on the SparkAttention kernels.

`make_attention` ties `flash_fwd` and `flash_bwd` together with
`jax.custom_vjp`, exactly mirroring the paper's training integration
(Figure 5): the forward saves only (O, LSE); the backward recomputes the
attention matrix from Q, K and the statistics.  `mha_layer` adds the QKV /
output projections and head split of a full MHA block (Equation 1's
multi-head form).

The dropout seed travels as an f32 scalar so it can be a *traced* argument
(fresh mask every training step) while keeping `custom_vjp` happy — its
cotangent is simply zero.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import flash_bwd, flash_fwd, naive


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    """Static configuration of one attention operator instance."""

    causal: bool = False
    dropout_rate: float = 0.0
    acc_fwd: str = "f32"    # paper's FP32-ACC default for the forward
    acc_bwd: str = "bf16"   # paper ships FP16-ACC only for the backward
    block_q: int | None = None
    block_k: int | None = None
    impl: str = "fused"     # "fused" | "unfused"


def make_attention(cfg: AttentionConfig) -> Callable:
    """Return `attn(q, k, v, seed) -> o` with the SparkAttention VJP.

    q, k, v: (bh, n, d); seed: f32 scalar array.  For ``impl="unfused"``
    the staged baseline (with its own staged autodiff) is returned instead —
    same signature, so model code is implementation-agnostic.
    """
    kw = dict(causal=cfg.causal, dropout_rate=cfg.dropout_rate,
              block_q=cfg.block_q, block_k=cfg.block_k)

    if cfg.impl == "unfused":
        def unfused(q, k, v, seed):
            return naive.mha_fwd_unfused(q, k, v, seed, **kw)
        return unfused
    if cfg.impl != "fused":
        raise ValueError(f"unknown attention impl {cfg.impl!r}")

    @jax.custom_vjp
    def attn(q, k, v, seed):
        o, _ = flash_fwd.flash_fwd(q, k, v, seed, acc=cfg.acc_fwd, **kw)
        return o

    def attn_fwd(q, k, v, seed):
        o, lse = flash_fwd.flash_fwd(q, k, v, seed, acc=cfg.acc_fwd, **kw)
        # Residuals: inputs + (O, LSE) only — no N×N tensor is saved; the
        # backward recomputes it (the paper's §3.3 memory-saving strategy).
        return o, (q, k, v, o, lse, seed)

    def attn_bwd(res, do):
        q, k, v, o, lse, seed = res
        dq, dk, dv = flash_bwd.flash_bwd(q, k, v, o, lse, do, seed,
                                         acc=cfg.acc_bwd, **kw)
        return dq, dk, dv, jnp.zeros_like(seed)

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


def split_heads(x: jax.Array, num_heads: int) -> jax.Array:
    """(b, n, h·d) → (b·h, n, d) — the kernels' batch-head major layout."""
    b, n, dm = x.shape
    d = dm // num_heads
    return (x.reshape(b, n, num_heads, d)
            .transpose(0, 2, 1, 3)
            .reshape(b * num_heads, n, d))


def merge_heads(x: jax.Array, batch: int) -> jax.Array:
    """(b·h, n, d) → (b, n, h·d) — inverse of `split_heads`."""
    bh, n, d = x.shape
    h = bh // batch
    return (x.reshape(batch, h, n, d)
            .transpose(0, 2, 1, 3)
            .reshape(batch, n, h * d))


def init_mha_params(key: jax.Array, d_model: int,
                    dtype=jnp.bfloat16) -> dict:
    """Xavier-ish init for the four projection matrices (+ biases)."""
    ks = jax.random.split(key, 4)
    s = d_model ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (d_model, d_model)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, d_model)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, d_model)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (d_model, d_model)) * s).astype(dtype),
        "bo": jnp.zeros((d_model,), dtype),
    }


def mha_layer(x: jax.Array, params: dict, seed: jax.Array, *,
              num_heads: int, attn: Callable) -> jax.Array:
    """Full MHA block: project → split heads → attention → merge → project."""
    b = x.shape[0]
    q = split_heads(x @ params["wq"], num_heads)
    k = split_heads(x @ params["wk"], num_heads)
    v = split_heads(x @ params["wv"], num_heads)
    o = merge_heads(attn(q, k, v, seed), b)
    return o @ params["wo"] + params["bo"]


def mha_layer_cross(x: jax.Array, memory: jax.Array, params: dict,
                    seed: jax.Array, *, num_heads: int,
                    attn: Callable) -> jax.Array:
    """Cross-attention MHA block — the decoder's second attention of
    Figure 1: queries from the decoder stream `x`, keys/values from the
    encoder output `memory` (lengths may differ)."""
    b = x.shape[0]
    q = split_heads(x @ params["wq"], num_heads)
    k = split_heads(memory @ params["wk"], num_heads)
    v = split_heads(memory @ params["wv"], num_heads)
    o = merge_heads(attn(q, k, v, seed), b)
    return o @ params["wo"] + params["bo"]
