"""AOT export: lower the artifact matrix to HLO text + manifest.json.

``make artifacts`` runs this once at build time; the Rust coordinator then
executes the artifacts through PJRT with **no Python on the request path**.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifact kinds
  mha_fwd        (seed, q, k, v)                  → (o, lse)      fused
  mha_fwd_unf    (q, k, v, seed)                  → (o,)          baseline
  mha_bwd        (seed, q, k, v, o, lse, do)      → (dq, dk, dv)  fused
  mha_fwdbwd_unf (q, k, v, do, seed)              → (dq, dk, dv)  baseline
  encoder_fwd    (params…, x, seed)               → (y,)
  lm_init        ()                               → params ∥ opt leaves
  train_step     (params…, m…, v…, step, tokens, seed)
                                                  → (params'…, m'…, v'…, loss)

Profiles: ``standard`` (CPU-scale perf grid), ``accuracy`` (§4.2.3 shapes,
dropout 0), ``train`` (lm_init + train_step), ``e2e`` (Fig 12 encoder
variants), ``paper`` (paper-scale shapes — export only; execution is gated
by the Rust memory budget).  Default builds standard+accuracy+train+e2e.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import re
import sys
import time
from typing import Callable

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .kernels import flash_bwd, flash_fwd, layouts, naive, ref

DTYPE_NAMES = {
    jnp.dtype("bfloat16"): "bf16",
    jnp.dtype("float32"): "f32",
    jnp.dtype("float64"): "f64",
    jnp.dtype("int32"): "s32",
    jnp.dtype("uint32"): "u32",
    jnp.dtype("bool"): "pred",
}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


@dataclasses.dataclass
class Artifact:
    """One HLO entry point plus the metadata the Rust side needs."""

    name: str
    kind: str
    fn: Callable                       # positional-arg function of arrays
    args: list[jax.ShapeDtypeStruct]   # flat example inputs, in call order
    input_names: list[str]
    attrs: dict                        # static scalars (n, d, bh, causal, …)

    def lower(self) -> tuple[str, list[dict], list[dict]]:
        # keep_unused: a dropout-0 variant still takes its seed parameter so
        # every artifact of a kind shares one calling convention in Rust.
        lowered = jax.jit(self.fn, keep_unused=True).lower(*self.args)
        text = to_hlo_text(lowered)
        ins = [
            {"name": nm, "shape": list(a.shape), "dtype": DTYPE_NAMES[a.dtype]}
            for nm, a in zip(self.input_names, self.args)
        ]
        out_avals = jax.eval_shape(self.fn, *self.args)
        leaves = jax.tree_util.tree_leaves(out_avals)
        outs = [
            {"name": f"out{i}", "shape": list(a.shape),
             "dtype": DTYPE_NAMES[jnp.dtype(a.dtype)]}
            for i, a in enumerate(leaves)
        ]
        return text, ins, outs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _mha_attrs(bh, n, d, causal, dropout, acc, fused, backward=False):
    blk = layouts.choose_blocks(n, d)
    return {
        "bh": bh, "n": n, "d": d, "causal": causal, "dropout": dropout,
        "acc": acc, "fused": fused,
        "block_q": blk.block_q, "block_k": blk.block_k,
        "vmem_bytes": blk.vmem_bytes,
        "mxu_utilization": round(blk.mxu_utilization, 4),
        "flops": ref.attention_flops(bh, n, d, causal=causal,
                                     backward=backward),
        "hbm_bytes_fused": layouts.hbm_bytes_fused_fwd(bh, n, d),
        "hbm_bytes_unfused": layouts.hbm_bytes_unfused_fwd(bh, n, d),
        "peak_bytes_unfused": layouts.peak_bytes_unfused(bh, n, d),
    }


# --------------------------------------------------------------------------
# Artifact builders
# --------------------------------------------------------------------------

def mha_fwd_artifact(*, bh, n, d, causal, dropout, acc,
                     block_q=None, block_k=None, tag="") -> Artifact:
    def fn(seed, q, k, v):
        return flash_fwd.flash_fwd(q, k, v, seed, causal=causal,
                                   dropout_rate=dropout, acc=acc,
                                   block_q=block_q, block_k=block_k)

    c = "c1" if causal else "c0"
    dt = jnp.bfloat16
    attrs = _mha_attrs(bh, n, d, causal, dropout, acc, True)
    if block_q is not None:
        attrs["block_q"] = block_q
        attrs["block_k"] = block_k
        attrs["vmem_bytes"] = layouts.vmem_footprint(block_q, block_k, d)
        attrs["mxu_utilization"] = round(
            layouts.mxu_utilization(block_q, block_k, d), 4)
    return Artifact(
        name=(f"mha_fwd_fused_{acc}_d{d}_n{n}_bh{bh}_{c}"
              f"_p{int(dropout*100)}{tag}"),
        kind="mha_fwd_ablation" if tag else "mha_fwd", fn=fn,
        args=[_sds((1,), jnp.float32)] + [_sds((bh, n, d), dt)] * 3,
        input_names=["seed", "q", "k", "v"],
        attrs=attrs)


def mha_fwd_unfused_artifact(*, bh, n, d, causal, dropout) -> Artifact:
    def fn(seed, q, k, v):
        return (naive.mha_fwd_unfused(q, k, v, seed, causal=causal,
                                      dropout_rate=dropout),)

    c = "c1" if causal else "c0"
    dt = jnp.bfloat16
    return Artifact(
        name=f"mha_fwd_unfused_d{d}_n{n}_bh{bh}_{c}_p{int(dropout*100)}",
        kind="mha_fwd_unf", fn=fn,
        args=[_sds((1,), jnp.float32)] + [_sds((bh, n, d), dt)] * 3,
        input_names=["seed", "q", "k", "v"],
        attrs=_mha_attrs(bh, n, d, causal, dropout, "f32", False))


def mha_bwd_artifact(*, bh, n, d, causal, dropout, acc) -> Artifact:
    def fn(seed, q, k, v, o, lse, do):
        return flash_bwd.flash_bwd(q, k, v, o, lse, do, seed, causal=causal,
                                   dropout_rate=dropout, acc=acc)

    c = "c1" if causal else "c0"
    dt = jnp.bfloat16
    t = _sds((bh, n, d), dt)
    return Artifact(
        name=f"mha_bwd_fused_{acc}_d{d}_n{n}_bh{bh}_{c}_p{int(dropout*100)}",
        kind="mha_bwd", fn=fn,
        args=[_sds((1,), jnp.float32), t, t, t, t,
              _sds((bh, n), jnp.float32), t],
        input_names=["seed", "q", "k", "v", "o", "lse", "do"],
        attrs=_mha_attrs(bh, n, d, causal, dropout, acc, True,
                         backward=True))


def mha_fwdbwd_unfused_artifact(*, bh, n, d, causal, dropout) -> Artifact:
    def fn(seed, q, k, v, do):
        return naive.mha_bwd_unfused(q, k, v, do, seed, causal=causal,
                                     dropout_rate=dropout)

    c = "c1" if causal else "c0"
    dt = jnp.bfloat16
    t = _sds((bh, n, d), dt)
    return Artifact(
        name=f"mha_fwdbwd_unfused_d{d}_n{n}_bh{bh}_{c}_p{int(dropout*100)}",
        kind="mha_fwdbwd_unf", fn=fn,
        args=[_sds((1,), jnp.float32), t, t, t, t],
        input_names=["seed", "q", "k", "v", "do"],
        attrs=_mha_attrs(bh, n, d, causal, dropout, "f32", False,
                         backward=True))


def encoder_artifact(*, impl, batch, n, d_model, num_heads,
                     dropout=0.0) -> Artifact:
    cfg = model_mod.ModelConfig(
        d_model=d_model, num_heads=num_heads, d_ff=4 * d_model, num_layers=1,
        seq=n, batch=batch, causal=False, dropout_rate=dropout,
        attn_impl=impl)
    params_shape = jax.eval_shape(
        lambda: model_mod.init_params(cfg, jax.random.PRNGKey(0)))
    layer_leaves, layer_tree = jax.tree_util.tree_flatten(
        params_shape["layers"])
    lnames = model_mod.param_names(
        jax.tree_util.tree_unflatten(layer_tree, layer_leaves))

    def fn(seed, x, *layer_params):
        layers = jax.tree_util.tree_unflatten(layer_tree, list(layer_params))
        return (model_mod.encoder_forward({"layers": layers}, x, seed,
                                          cfg=cfg),)

    d_head = d_model // num_heads
    return Artifact(
        name=(f"encoder_{impl}_dm{d_model}_h{num_heads}_n{n}_b{batch}"
              f"_p{int(dropout * 100)}"),
        kind="encoder_fwd", fn=fn,
        args=[_sds((1,), jnp.float32),
              _sds((batch, n, d_model), jnp.bfloat16)]
        + [_sds(l.shape, l.dtype) for l in layer_leaves],
        input_names=["seed", "x"] + lnames,
        attrs={
            "impl": impl, "batch": batch, "n": n, "d_model": d_model,
            "dropout": dropout,
            "num_heads": num_heads, "d_head": d_head, "d_ff": 4 * d_model,
            "flops_attn": ref.attention_flops(batch * num_heads, n, d_head,
                                              causal=False),
            "peak_bytes_unfused": layouts.peak_bytes_unfused(
                batch * num_heads, n, d_head),
        })


def lm_init_artifact(cfg: model_mod.ModelConfig) -> Artifact:
    def fn(seed):
        params = model_mod.init_params(
            cfg, jax.random.PRNGKey(seed.reshape(())))
        opt = model_mod.init_opt_state(params)
        return (jax.tree_util.tree_leaves(params)
                + jax.tree_util.tree_leaves(opt["m"])
                + jax.tree_util.tree_leaves(opt["v"]))

    return Artifact(
        name="lm_init", kind="lm_init", fn=fn,
        args=[_sds((1,), jnp.uint32)], input_names=["seed"],
        attrs=_lm_attrs(cfg))


def _lm_attrs(cfg: model_mod.ModelConfig) -> dict:
    params_shape = jax.eval_shape(
        lambda: model_mod.init_params(cfg, jax.random.PRNGKey(0)))
    names = model_mod.param_names(params_shape)
    leaves = jax.tree_util.tree_leaves(params_shape)
    return {
        "vocab": cfg.vocab, "d_model": cfg.d_model,
        "num_heads": cfg.num_heads, "d_ff": cfg.d_ff,
        "num_layers": cfg.num_layers, "seq": cfg.seq, "batch": cfg.batch,
        "lr": cfg.lr, "dropout": cfg.dropout_rate,
        "param_count": int(sum(
            functools.reduce(lambda a, b: a * b, l.shape, 1)
            for l in leaves)),
        "param_names": names,
    }


def train_step_artifact(cfg: model_mod.ModelConfig) -> Artifact:
    params_shape = jax.eval_shape(
        lambda: model_mod.init_params(cfg, jax.random.PRNGKey(0)))
    leaves, treedef = jax.tree_util.tree_flatten(params_shape)
    nleaves = len(leaves)
    names = model_mod.param_names(params_shape)

    def fn(*flat):
        p = jax.tree_util.tree_unflatten(treedef, list(flat[:nleaves]))
        m = jax.tree_util.tree_unflatten(
            treedef, list(flat[nleaves:2 * nleaves]))
        v = jax.tree_util.tree_unflatten(
            treedef, list(flat[2 * nleaves:3 * nleaves]))
        step, tokens, seed = flat[3 * nleaves:]
        p2, opt2, loss = model_mod.train_step(p, {"m": m, "v": v}, step[0],
                                              tokens, seed, cfg=cfg)
        return (jax.tree_util.tree_leaves(p2)
                + jax.tree_util.tree_leaves(opt2["m"])
                + jax.tree_util.tree_leaves(opt2["v"]) + [loss])

    f32 = jnp.float32
    args = ([_sds(l.shape, l.dtype) for l in leaves]
            + [_sds(l.shape, f32) for l in leaves] * 2
            + [_sds((1,), f32),
               _sds((cfg.batch, cfg.seq + 1), jnp.int32),
               _sds((1,), f32)])
    input_names = ([f"p/{n}" for n in names] + [f"m/{n}" for n in names]
                   + [f"v/{n}" for n in names] + ["step", "tokens", "seed"])
    return Artifact(name="train_step", kind="train_step", fn=fn, args=args,
                    input_names=input_names, attrs=_lm_attrs(cfg))


# --------------------------------------------------------------------------
# Profiles
# --------------------------------------------------------------------------

STANDARD_SEQS = (256, 512, 1024, 2048)
PAPER_SEQS = (512, 1024, 2048, 4096, 16384)
DROPOUT = 0.1


def standard_profile() -> list[Artifact]:
    """CPU-scale Fig 10/11 grid: bh=4, dropout 0.1 (paper hyperparams)."""
    arts = []
    for d in (64, 128):
        for n in STANDARD_SEQS:
            for causal in (False, True):
                for acc in ("f32", "bf16"):
                    arts.append(mha_fwd_artifact(
                        bh=4, n=n, d=d, causal=causal, dropout=DROPOUT,
                        acc=acc))
                arts.append(mha_fwd_unfused_artifact(
                    bh=4, n=n, d=d, causal=causal, dropout=DROPOUT))
                arts.append(mha_bwd_artifact(
                    bh=4, n=n, d=d, causal=causal, dropout=DROPOUT,
                    acc="bf16"))
                arts.append(mha_fwdbwd_unfused_artifact(
                    bh=4, n=n, d=d, causal=causal, dropout=DROPOUT))
    return arts


def accuracy_profile() -> list[Artifact]:
    """§4.2.3 shapes, dropout 0 so all implementations are comparable."""
    arts = []
    for d in (64, 128):
        for causal in (False, True):
            for acc in ("f32", "bf16"):
                arts.append(mha_fwd_artifact(
                    bh=2, n=256, d=d, causal=causal, dropout=0.0, acc=acc))
                arts.append(mha_bwd_artifact(
                    bh=2, n=256, d=d, causal=causal, dropout=0.0, acc=acc))
            arts.append(mha_fwd_unfused_artifact(
                bh=2, n=256, d=d, causal=causal, dropout=0.0))
            arts.append(mha_fwdbwd_unfused_artifact(
                bh=2, n=256, d=d, causal=causal, dropout=0.0))
    return arts


def e2e_profile() -> list[Artifact]:
    """Fig 12: single encoder layer, head-dim {64,128}, sequence sweep.

    Benchmarked at dropout 0.1 (the paper's §4.1 hyperparameter); a
    dropout-0 copy of each point is exported for cross-implementation
    numerical-agreement tests (masks differ across impls at p > 0).
    """
    arts = []
    for num_heads, d_model in ((8, 512), (4, 512)):  # d_head 64 / 128
        for n in (128, 256, 512, 1024):
            for impl in ("unfused", "fused", "fully_fused"):
                for dropout in (DROPOUT, 0.0):
                    arts.append(encoder_artifact(
                        impl=impl, batch=1, n=n, d_model=d_model,
                        num_heads=num_heads, dropout=dropout))
    return arts


def train_profile() -> list[Artifact]:
    cfg = model_mod.ModelConfig()
    return [lm_init_artifact(cfg), train_step_artifact(cfg)]


def paper_profile() -> list[Artifact]:
    """Paper-scale shapes (batch = 16384/n, heads = 2048/d).  Export-only:
    the Rust harness gates execution on the host memory budget."""
    arts = []
    for d in (64, 128):
        heads = 2048 // d
        for n in PAPER_SEQS:
            batch = max(1, 16384 // n)
            bh = min(batch * heads, 64)  # cap bh: CPU host, not a V100 fleet
            for causal in (False, True):
                arts.append(mha_fwd_artifact(
                    bh=bh, n=n, d=d, causal=causal, dropout=DROPOUT,
                    acc="f32"))
    return arts


def ablation_profile() -> list[Artifact]:
    """Block-size ablation (DESIGN.md §8): same problem, tile sweep."""
    arts = []
    for b in (32, 64, 128, 256):
        arts.append(mha_fwd_artifact(
            bh=4, n=1024, d=64, causal=False, dropout=0.0, acc="f32",
            block_q=b, block_k=b, tag=f"_bq{b}_bk{b}"))
    # asymmetric tiles: stream more K per resident Q and vice versa
    for bq, bk in ((256, 64), (64, 256)):
        arts.append(mha_fwd_artifact(
            bh=4, n=1024, d=64, causal=False, dropout=0.0, acc="f32",
            block_q=bq, block_k=bk, tag=f"_bq{bq}_bk{bk}"))
    return arts


def longseq_profile() -> list[Artifact]:
    """Long-sequence feasibility points (bh=1; the example's showpiece)."""
    arts = []
    for n in (4096, 8192):
        arts.append(mha_fwd_artifact(
            bh=1, n=n, d=64, causal=False, dropout=0.0, acc="f32"))
    arts.append(mha_fwd_unfused_artifact(
        bh=1, n=4096, d=64, causal=False, dropout=0.0))
    return arts


PROFILES = {
    "standard": standard_profile,
    "accuracy": accuracy_profile,
    "e2e": e2e_profile,
    "train": train_profile,
    "paper": paper_profile,
    "ablation": ablation_profile,
    "longseq": longseq_profile,
}
DEFAULT_PROFILES = ("standard", "accuracy", "e2e", "train", "ablation",
                    "longseq")


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------

def build(out_dir: str, profiles: list[str], only: str | None = None,
          force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest = {"version": 1, "artifacts": []}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    known = {a["name"]: a for a in manifest["artifacts"]}

    arts: list[Artifact] = []
    for p in profiles:
        arts.extend(PROFILES[p]())
    if only:
        pat = re.compile(only)
        arts = [a for a in arts if pat.search(a.name)]

    built = 0
    for art in arts:
        fname = f"{art.name}.hlo.txt"
        fpath = os.path.join(out_dir, fname)
        if not force and art.name in known and os.path.exists(fpath):
            continue
        t0 = time.time()
        text, ins, outs = art.lower()
        with open(fpath, "w") as f:
            f.write(text)
        entry = {"name": art.name, "file": fname, "kind": art.kind,
                 "attrs": art.attrs, "inputs": ins, "outputs": outs}
        known[art.name] = entry
        built += 1
        print(f"  [{built}] {art.name}  ({time.time() - t0:.1f}s, "
              f"{len(text) // 1024} KiB)")

    manifest["artifacts"] = sorted(known.values(), key=lambda a: a["name"])
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts "
          f"({built} rebuilt) → {manifest_path}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--profile", action="append", default=None,
                    choices=sorted(PROFILES), help="repeatable; default: "
                    + ",".join(DEFAULT_PROFILES))
    ap.add_argument("--only", default=None,
                    help="regex filter on artifact names")
    ap.add_argument("--force", action="store_true",
                    help="rebuild even if present")
    ap.add_argument("--list", action="store_true",
                    help="print artifact names and exit")
    ns = ap.parse_args()
    profiles = ns.profile or list(DEFAULT_PROFILES)
    if ns.list:
        for p in profiles:
            for a in PROFILES[p]():
                print(a.name)
        return
    build(ns.out_dir, profiles, only=ns.only, force=ns.force)


if __name__ == "__main__":
    main()
