"""Build-time compile path: JAX model + Pallas kernels, AOT-lowered to HLO.

Nothing in this package runs at serving/training time — `aot.py` lowers the
artifact matrix once (``make artifacts``) and the Rust coordinator executes
the resulting HLO text via PJRT.
"""
