// placeholder
