//! Offline shim of the `log` facade: levels, `Record`/`Metadata`, the
//! `Log` trait, the global logger registry, and the level macros.
//!
//! Behaviourally equivalent to the real crate for everything
//! `sparkattention::logging` relies on; the registry is a `OnceLock` plus
//! an atomic max-level, so `set_logger` is idempotent-safe under tests
//! that initialise twice.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of one message; `Error` is most severe (and orders lowest).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.pad(s)
    }
}

/// Global filter ceiling; `Off` disables everything.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Static facts about a message (level + target module).
#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log message, handed to [`Log::log`].
#[derive(Debug, Clone)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink.  Implementations must be thread-safe.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger; errors if one is already set.
pub fn set_logger(logger: &'static dyn Log)
    -> Result<(), SetLoggerError>
{
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Raise/lower the global level ceiling.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global ceiling.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — not part of the public facade.
#[doc(hidden)]
pub fn __dispatch(level: Level, target: &str, args: fmt::Arguments) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__dispatch($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_matches_facade() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
        assert!(LevelFilter::Off < LevelFilter::Error);
    }

    #[test]
    fn display_pads() {
        assert_eq!(format!("{:5}", Level::Warn), "WARN ");
    }

    #[test]
    fn dispatch_without_logger_is_noop() {
        set_max_level(LevelFilter::Trace);
        info!("no logger installed, must not panic: {}", 1);
    }
}
