//! Offline shim of the `anyhow` API surface used by this repository.
//!
//! The build environment has no crates.io registry, so this path
//! dependency re-implements exactly what the coordinator needs: a
//! string-backed `Error`, the `Result` alias, the `anyhow!`/`bail!`/
//! `ensure!` macros, and the `Context` extension trait for `Result` and
//! `Option`.  Source-error chains are flattened into the message at
//! conversion time, so `{:#}` and `{}` both print the full story.

use std::fmt;

/// String-backed error value.  Deliberately does NOT implement
/// `std::error::Error`: that keeps the blanket `From`/`Context` impls
/// below coherent, mirroring the real crate's design.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Flatten a std error and its source chain into one message.
    pub fn from_std<E: std::error::Error + ?Sized>(e: &E) -> Self {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }

    /// Prepend a context line (what `.context(...)` does).
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::from_std(&e)
    }
}

/// `anyhow::Result<T>` — the crate-wide fallible return type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from_std(&e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T, Error>
    {
        self.map_err(|e| Error::from_std(&e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T, Error>
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T, Error>
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad {}", 7);
        assert_eq!(e.to_string(), "bad 7");
        assert_eq!(format!("{e:#}"), "bad 7");
    }

    #[test]
    fn context_chains() {
        let r: Result<()> = io_fail().context("reading config");
        let msg = r.unwrap_err().to_string();
        assert!(msg.starts_with("reading config: "), "{msg}");
        assert!(msg.contains("gone"), "{msg}");
    }

    #[test]
    fn option_context() {
        let r: Result<i32> = None.context("missing field");
        assert_eq!(r.unwrap_err().to_string(), "missing field");
        let r: Result<i32> = Some(3).with_context(|| "unused");
        assert_eq!(r.unwrap(), 3);
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn bail_and_ensure() {
        fn b() -> Result<()> {
            bail!("stop {}", "now")
        }
        fn e(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(b().unwrap_err().to_string(), "stop now");
        assert!(e(-1).is_err());
        assert_eq!(e(2).unwrap(), 2);
    }
}
