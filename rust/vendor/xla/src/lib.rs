//! Typed stub of the `xla` PJRT binding.
//!
//! The runtime layer (`sparkattention::runtime`) is written against the
//! PJRT C-API wrapper crate.  That crate needs a libxla build which this
//! environment does not ship, so this path dependency provides the same
//! type surface with two behaviours:
//!
//! * **Literals are real.**  `Literal` is a faithful host-side container
//!   (element type + dims + little-endian bytes) with working encode /
//!   decode / convert, so `HostValue ⇄ Literal` round-trips — and the unit
//!   tests exercising them — behave exactly as with the real binding.
//! * **The device is absent.**  `PjRtClient::cpu()` returns a descriptive
//!   error, so anything needing artifact execution fails fast with an
//!   actionable message instead of segfaulting on a missing shared object.
//!   Integration tests skip before reaching this (no `manifest.json`).
//!
//! Swapping in a real PJRT binding is a Cargo.toml change; no runtime
//! source edits are required as long as this surface is kept in sync.

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `anyhow` interop.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }

    fn backend_unavailable() -> Self {
        Error::new(
            "PJRT backend unavailable: this build uses the offline xla \
             stub (rust/vendor/xla); artifact execution requires a real \
             PJRT binding")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types accepted when building literals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    Bf16,
    F16,
    F32,
    F64,
    S32,
    S64,
    U32,
    U64,
}

/// Primitive types reported by array shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    Pred,
    Bf16,
    F16,
    F32,
    F64,
    S32,
    S64,
    U32,
    U64,
    Tuple,
}

impl ElementType {
    fn primitive(self) -> PrimitiveType {
        match self {
            ElementType::Pred => PrimitiveType::Pred,
            ElementType::Bf16 => PrimitiveType::Bf16,
            ElementType::F16 => PrimitiveType::F16,
            ElementType::F32 => PrimitiveType::F32,
            ElementType::F64 => PrimitiveType::F64,
            ElementType::S32 => PrimitiveType::S32,
            ElementType::S64 => PrimitiveType::S64,
            ElementType::U32 => PrimitiveType::U32,
            ElementType::U64 => PrimitiveType::U64,
        }
    }
}

fn byte_size(ty: PrimitiveType) -> Result<usize> {
    Ok(match ty {
        PrimitiveType::Pred => 1,
        PrimitiveType::Bf16 | PrimitiveType::F16 => 2,
        PrimitiveType::F32 | PrimitiveType::S32 | PrimitiveType::U32 => 4,
        PrimitiveType::F64 | PrimitiveType::S64 | PrimitiveType::U64 => 8,
        PrimitiveType::Tuple => {
            return Err(Error::new("tuples have no element byte size"))
        }
    })
}

/// Shape of an array literal: primitive type + dimensions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    ty: PrimitiveType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn primitive_type(&self) -> PrimitiveType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host types a literal can decode into.
pub trait NativeType: Sized {
    const PRIMITIVE: PrimitiveType;
    fn read_le(bytes: &[u8]) -> Self;
}

macro_rules! native {
    ($t:ty, $prim:expr, $n:expr) => {
        impl NativeType for $t {
            const PRIMITIVE: PrimitiveType = $prim;
            fn read_le(bytes: &[u8]) -> Self {
                let mut buf = [0u8; $n];
                buf.copy_from_slice(bytes);
                <$t>::from_le_bytes(buf)
            }
        }
    };
}

native!(f32, PrimitiveType::F32, 4);
native!(f64, PrimitiveType::F64, 8);
native!(i32, PrimitiveType::S32, 4);
native!(i64, PrimitiveType::S64, 8);
native!(u32, PrimitiveType::U32, 4);
native!(u64, PrimitiveType::U64, 8);

fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h >> 15) as u32) << 31;
    let exp = ((h >> 10) & 0x1F) as u32;
    let frac = (h & 0x3FF) as u32;
    let bits = match (exp, frac) {
        (0, 0) => sign,
        (0, f) => {
            // subnormal: value = f × 2⁻²⁴; renormalise for f32.  The top
            // set bit of f sits at position p = 10 − shift, so the f32
            // exponent is 127 + (p − 24) = 113 − shift and the mantissa is
            // the remainder shifted to fill 23 bits (leading 1 masked off).
            let shift = f.leading_zeros() - 21;
            let exp32 = 113 - shift;
            let frac32 = (f << (13 + shift)) & 0x007F_FFFF;
            sign | (exp32 << 23) | frac32
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, f) => sign | 0x7F80_0000 | (f << 13) | 0x0040_0000,
        (e, f) => sign | ((e + 127 - 15) << 23) | (f << 13),
    };
    f32::from_bits(bits)
}

/// A host-resident XLA literal: array payload or tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    Array {
        ty: PrimitiveType,
        dims: Vec<i64>,
        /// Little-endian element bytes, row-major.
        data: Vec<u8>,
    },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Build an array literal from raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType, dims: &[usize], data: &[u8]) -> Result<Literal>
    {
        let prim = ty.primitive();
        let count: usize = dims.iter().product();
        let want = count * byte_size(prim)?;
        if data.len() != want {
            return Err(Error::new(format!(
                "literal {dims:?} of {prim:?} needs {want} bytes, got {}",
                data.len())));
        }
        Ok(Literal::Array {
            ty: prim,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: data.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { ty, dims, .. } => Ok(ArrayShape {
                ty: *ty,
                dims: dims.clone(),
            }),
            Literal::Tuple(_) => {
                Err(Error::new("tuple literal has no array shape"))
            }
        }
    }

    /// Decode into a host vector; the requested type must match exactly.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { ty, data, .. } => {
                if *ty != T::PRIMITIVE {
                    return Err(Error::new(format!(
                        "literal is {ty:?}, requested {:?}", T::PRIMITIVE)));
                }
                let n = byte_size(*ty)?;
                Ok(data.chunks_exact(n).map(T::read_le).collect())
            }
            Literal::Tuple(_) => Err(Error::new("cannot to_vec a tuple")),
        }
    }

    /// Convert to another primitive type (the upcasts the runtime uses).
    pub fn convert(&self, target: PrimitiveType) -> Result<Literal> {
        let Literal::Array { ty, dims, data } = self else {
            return Err(Error::new("cannot convert a tuple literal"));
        };
        if *ty == target {
            return Ok(self.clone());
        }
        match (ty, target) {
            (PrimitiveType::Bf16, PrimitiveType::F32) => {
                let out: Vec<u8> = data.chunks_exact(2)
                    .flat_map(|c| {
                        let v = bf16_bits_to_f32(
                            u16::from_le_bytes([c[0], c[1]]));
                        v.to_le_bytes()
                    })
                    .collect();
                Ok(Literal::Array {
                    ty: PrimitiveType::F32,
                    dims: dims.clone(),
                    data: out,
                })
            }
            (PrimitiveType::F16, PrimitiveType::F32) => {
                let out: Vec<u8> = data.chunks_exact(2)
                    .flat_map(|c| {
                        let v = f16_bits_to_f32(
                            u16::from_le_bytes([c[0], c[1]]));
                        v.to_le_bytes()
                    })
                    .collect();
                Ok(Literal::Array {
                    ty: PrimitiveType::F32,
                    dims: dims.clone(),
                    data: out,
                })
            }
            (a, b) => Err(Error::new(format!(
                "conversion {a:?} → {b:?} not supported by the stub"))),
        }
    }

    /// Destructure a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts.clone()),
            Literal::Array { .. } => {
                Err(Error::new("literal is not a tuple"))
            }
        }
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module (text form only in the stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text file; parsing is deferred to compile time (which
    /// the stub cannot reach), so this only validates readability.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }

    pub fn proto(&self) -> &HloModuleProto {
        &self.proto
    }
}

/// Device-resident buffer handle.  Unreachable through the stub client.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::backend_unavailable())
    }
}

/// Compiled executable handle.  Unreachable through the stub client.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L])
        -> Result<Vec<Vec<PjRtBuffer>>>
    {
        Err(Error::backend_unavailable())
    }
}

/// PJRT client.  `cpu()` reports the backend absent in this build.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::backend_unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation)
        -> Result<PjRtLoadedExecutable>
    {
        Err(Error::backend_unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let vals = [1.0f32, -2.5, 0.0, 3.25e8];
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32, &[2, 2], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.primitive_type(), PrimitiveType::F32);
    }

    #[test]
    fn byte_length_checked() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32, &[3], &[0u8; 11]).is_err());
    }

    #[test]
    fn bf16_converts_to_f32() {
        // 1.0 in bf16 is 0x3F80; -2.0 is 0xC000
        let bytes = [0x80u8, 0x3F, 0x00, 0xC0];
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::Bf16, &[2], &bytes).unwrap();
        let f = lit.convert(PrimitiveType::F32).unwrap();
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![1.0, -2.0]);
    }

    #[test]
    fn f16_converts_to_f32() {
        // 1.0 = 0x3C00, -0.5 = 0xB800, +inf = 0x7C00
        let bytes = [0x00u8, 0x3C, 0x00, 0xB8, 0x00, 0x7C];
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F16, &[3], &bytes).unwrap();
        let f = lit.convert(PrimitiveType::F32).unwrap();
        let got = f.to_vec::<f32>().unwrap();
        assert_eq!(got[0], 1.0);
        assert_eq!(got[1], -0.5);
        assert!(got[2].is_infinite() && got[2] > 0.0);
    }

    #[test]
    fn f16_subnormals_convert() {
        // 0x0001 is the smallest f16 subnormal, 2⁻²⁴; 0x03FF the largest.
        let bytes = [0x01u8, 0x00, 0xFF, 0x03];
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F16, &[2], &bytes).unwrap();
        let got = lit.convert(PrimitiveType::F32).unwrap()
            .to_vec::<f32>().unwrap();
        assert_eq!(got[0], 2.0f32.powi(-24));
        assert_eq!(got[1], 1023.0 * 2.0f32.powi(-24));
    }

    #[test]
    fn type_mismatch_rejected() {
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::S32, &[1], &1i32.to_le_bytes()).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1]);
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("PJRT backend unavailable"), "{err}");
    }
}
