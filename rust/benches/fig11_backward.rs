//! `cargo bench --bench fig11_backward` — regenerates Fig 11 (E2):
//! MHA-Backward with recomputation vs the staged PyTorch-style backward
//! (reported as t(fwd+bwd) − t(fwd)), plus the V100 projection.
//!
//! Opens with the host backend sweep of the block-streamed backward
//! (every exec backend side by side — scalar/blocked/simd/simd-mixed —
//! with mixed-vs-f32 accuracy notes; always runs, no artifacts needed).
//! Honours `SPARK_EXEC_TUNING_TABLE` for autotuned (MC, KC) blocks.
//! See EXPERIMENTS.md §E2.

mod common;

use sparkattention::coordinator::{fig11_backward, host_backend_report,
                                  projected_fig10};
use sparkattention::perfmodel::V100;

fn main() {
    sparkattention::logging::init();

    // --- host backend sweep: streamed backward ---------------------------
    // Per-backend speedups and the mixed-vs-f32 accuracy summary are
    // emitted as report notes (table + JSON).
    let (ns, bh, d) = common::host_shape();
    let opts = common::harness_options();
    let masks = common::host_masks();
    let host = host_backend_report(&ns, bh, d, true, &masks, opts)
        .expect("host backward report");
    common::emit(&host, "fig11_host");

    // --- measured artifact sweep ----------------------------------------
    if let Some(engine) = common::engine_or_skip() {
        let report = fig11_backward(&engine, common::harness_options())
            .expect("fig11 harness");
        common::emit(&report, "fig11_measured");
        if let Some((mean, max)) =
            report.speedup_summary("spark_bf16acc", "pytorch_fp16") {
            println!("measured speedup: avg {mean:.2}× (max {max:.2}×)");
        }
    }

    // --- V100 projection --------------------------------------------------
    let proj = projected_fig10(&V100, true);
    common::emit(&proj, "fig11_projected");
    if let Some((mean, max)) =
        proj.speedup_summary("spark_projected", "pytorch_projected") {
        println!("projected V100 speedup: avg {mean:.2}× (max {max:.2}×)  \
                  [paper: avg 3.44× (max 7.91×)]");
    }
}
