//! `cargo bench --bench io_model` — regenerates the §2.3 / Table-1-adjacent
//! I/O analysis (E5): HBM traffic per schedule from both the closed-form
//! model and the schedule simulator, plus the V100 roofline projections
//! that turn traffic into the paper's headline speedups.  Closes with the
//! *achieved* host GEMM throughput per exec backend, grounding the
//! roofline discussion in a measured compute ceiling.  Honours
//! `SPARK_EXEC_TUNING_TABLE` for autotuned (MC, KC) blocks.

mod common;

use sparkattention::attention::Mask;
use sparkattention::bench::measure_wallclock;
use sparkattention::coordinator::{io_report, report_roster};
use sparkattention::exec::{Backend, Precision, Scalar};
use sparkattention::iomodel::{self, MhaShape};
use sparkattention::perfmodel::{self, V100};
use sparkattention::tensor::{Rng, Tensor};

fn main() {
    sparkattention::logging::init();
    print!("{}", io_report(&V100));

    // Cross-check: simulator vs closed form across a sweep (hard assert —
    // a bench that silently drifts from the model is worse than none).
    for d in [64usize, 128] {
        for n in [512usize, 2048, 16384] {
            let s = MhaShape::new(8, n, d);
            let (sim, overflow) =
                iomodel::simulate_fused_fwd(s, 128, 128, 16 << 20);
            let ana = iomodel::analytic_fused_fwd_streamed(s, 128);
            assert_eq!(sim.read_bytes, ana.read_bytes, "n={n} d={d}");
            assert!(!overflow, "VMEM overflow at n={n} d={d}");
        }
    }
    println!("simulator ⇄ closed-form cross-check: OK");

    // Masked traffic: skip-aware tiling removes dead tiles from the
    // analytic counts and the schedule simulator identically (hard
    // assert), and the table shows what each structured mask saves.
    println!("\nmasked fused traffic (bh=8, d=64, 128×128 tiles):");
    println!("{:>8} {:>10} {:>12} {:>12} {:>12} {:>8}", "n", "mask",
             "read_MB", "write_MB", "live_tiles", "saved");
    for n in [512usize, 2048, 8192] {
        let s = MhaShape::new(8, n, 64);
        let dense = iomodel::analytic_fused_fwd_masked(
            s, &Mask::Dense, 128, 128);
        for mask in [Mask::Dense, Mask::Causal,
                     Mask::SlidingWindow { w: 256 }] {
            let ana = iomodel::analytic_fused_fwd_masked(s, &mask, 128, 128);
            let (sim, overflow) = iomodel::simulate_fused_fwd_masked(
                s, &mask, 128, 128, 16 << 20);
            assert_eq!(sim.read_bytes, ana.read_bytes,
                       "masked sim ⇄ analytic reads (n={n}, mask={})",
                       mask.label());
            assert_eq!(sim.write_bytes, ana.write_bytes,
                       "masked sim ⇄ analytic writes (n={n}, mask={})",
                       mask.label());
            assert!(!overflow, "VMEM overflow at n={n}");
            let tiles = mask.tile_counts(n, 128, 128);
            let mb = |b: usize| b as f64 / (1 << 20) as f64;
            println!("{:>8} {:>10} {:>12.1} {:>12.1} {:>12} {:>7.1}%",
                     n, mask.label(), mb(ana.read_bytes),
                     mb(ana.write_bytes), 8 * tiles.live,
                     100.0 * (1.0 - ana.total_bytes() as f64
                              / dense.total_bytes() as f64));
        }
    }
    println!("masked simulator ⇄ masked closed-form cross-check: OK");

    // Where does fusion stop mattering?  Crossover scan: the fused/unfused
    // traffic ratio as d/n varies (the paper's long-sequence emphasis).
    println!("\ntraffic ratio (unfused ÷ fused) across shapes:");
    print!("{:>8}", "n\\d");
    for d in [32usize, 64, 128, 256] {
        print!("{d:>8}");
    }
    println!();
    for n in [128usize, 512, 2048, 8192] {
        print!("{n:>8}");
        for d in [32usize, 64, 128, 256] {
            let s = MhaShape::new(8, n, d);
            let r = iomodel::analytic_unfused_fwd(s).total_bytes() as f64
                / iomodel::analytic_fused_fwd(s).total_bytes() as f64;
            print!("{r:>8.1}");
        }
        println!();
    }

    // Projected end-to-end effect at paper scale.
    println!("\nV100 projected forward time (ms) at paper scale:");
    println!("{:>7} {:>10} {:>10} {:>8}", "n", "unfused", "fused", "ratio");
    for n in [512usize, 1024, 2048, 4096, 16384] {
        let s = perfmodel::paper_shape(n, 64);
        let u = perfmodel::project_unfused_fwd(&V100, s, false);
        let f = perfmodel::project_fused_fwd(&V100, s, false, 128);
        if u.seconds.is_finite() {
            println!("{n:>7} {:>10.2} {:>10.2} {:>7.2}×",
                     u.seconds * 1e3, f.seconds * 1e3,
                     u.seconds / f.seconds);
        } else {
            println!("{n:>7} {:>10} {:>10.2}     OOM→∞", "OOM",
                     f.seconds * 1e3);
        }
    }

    // Achieved host GEMM throughput per backend (the report roster —
    // scalar, blocked, simd, simd-mixed unless pinned): the measured
    // compute ceiling the host-path figures (fig10_host etc.) run
    // against.
    let opts = common::harness_options();
    let (bh, n, d) = (8usize, 512usize, 64usize);
    let mut rng = Rng::new(0x10F);
    let a = Tensor::randn(vec![bh, n, d], &mut rng);
    let b = Tensor::randn(vec![bh, n, d], &mut rng);
    let flops = 2.0 * (bh * n * n * d) as f64;
    println!("\nachieved host QKᵀ throughput ({bh}×{n}×{d}):");
    let backends = report_roster(opts);
    for be in &backends {
        let time = measure_wallclock(opts.bench, || {
            be.batch_matmul_nt(&a, &b);
            Ok(())
        }).expect("gemm measure");
        println!("  {:<16} {:>8.2} GFLOP/s", be.name(),
                 flops / time.mean() / 1e9);
    }

    // Mixed-vs-f32 numerics on that same GEMM (the §4.2.3-style
    // summary for the host path).
    if let Some(mixed) =
        backends.iter().find(|be| be.precision() == Precision::Mixed)
    {
        let f32_out = Scalar.batch_matmul_nt(&a, &b);
        let mixed_out = mixed.batch_matmul_nt(&a, &b);
        println!("mixed vs f32 on QKᵀ: max ulp {}, max abs {:.6}, \
                  mean rel {:.5}%",
                 mixed_out.max_ulp_diff(&f32_out),
                 mixed_out.max_abs_diff(&f32_out),
                 mixed_out.mean_rel_err(&f32_out, 1e-3) * 100.0);
    }
}
