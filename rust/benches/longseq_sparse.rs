//! `cargo bench --bench longseq_sparse` — the long-sequence structured-
//! attention sweep: sliding-window streaming forward vs dense, at
//! lengths far past the quadratic wall (default up to n = 16384).
//!
//! Skip-aware tiling makes sliding-window attention *linear* in n (the
//! live band is `~n·w` elements) while dense stays quadratic; this
//! bench shows that in measured wall-clock **and** in the I/O model's
//! analytic/simulated traffic, and hard-asserts the deterministic
//! parts:
//!
//! * the tile enumerator's live/skipped counts partition the grid and
//!   match a brute-force scan of `Mask::tile_live` (every n);
//! * `iomodel` analytic masked traffic ≡ the tile-level simulator, so
//!   skipped tiles are provably absent from the traffic counts
//!   (every n — this is the CI bench-smoke gate);
//! * streaming(masked) ≡ fused oracle at the smallest n (every run);
//! * traffic scaling: window ~linear, dense ~quadratic (when the sweep
//!   spans ≥ 4×);
//! * wall-clock: dense ≥ 2× slower than window at the largest n (only
//!   when nmax ≥ 4096 — tiny CI-smoke shapes are noise-dominated).
//!
//! Environment (on top of the shared `benches/common` knobs):
//!
//! * `SPARK_LONGSEQ_NS`     — lengths (default `2048,4096,8192,16384`)
//! * `SPARK_LONGSEQ_WINDOW` — sliding-window width (default 256)

mod common;

use sparkattention::attention::{self, AttnParams, Mask};
use sparkattention::bench::{measure_wallclock, Report, Row};
use sparkattention::iomodel::{self, MhaShape};
use sparkattention::tensor::{Rng, Tensor};

fn envnum(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() {
    sparkattention::logging::init();
    let ns: Vec<usize> = std::env::var("SPARK_LONGSEQ_NS")
        .unwrap_or_else(|_| "2048,4096,8192,16384".into())
        .split(',')
        .map(|s| s.trim().parse().expect("SPARK_LONGSEQ_NS"))
        .collect();
    let w = envnum("SPARK_LONGSEQ_WINDOW", 256);
    assert!(w >= 1, "SPARK_LONGSEQ_WINDOW must be ≥ 1");
    let (bh, d) = (1usize, 32usize);
    let opts = common::harness_options();
    let be = opts.exec.build();
    println!("== Long-sequence sweep: sliding-window (w={w}) vs dense \
              (bh={bh}, d={d}, backend {}) ==", be.name());
    println!("{:>8} {:>8} {:>10} {:>12} {:>12} {:>12} {:>12}", "n",
             "mask", "mean_ms", "live", "skipped", "read_MB", "write_MB");

    let mut report = Report::new(format!(
        "Long-sequence structured attention (bh={bh}, d={d}, w={w})"));
    // (n, mask-label) → (mean seconds, analytic total bytes)
    let mut cells: Vec<(usize, String, f64, usize)> = Vec::new();
    for (idx, &n) in ns.iter().enumerate() {
        // largest tile ≤ 128 that divides n (streaming needs bq | n)
        let bq = (1..=128usize.min(n)).rev().find(|b| n % b == 0)
            .unwrap_or(1);
        let s = MhaShape::new(bh, n, d);
        let mut rng = Rng::new(0x10A6 ^ n as u64);
        let q = Tensor::randn(vec![bh, n, d], &mut rng);
        let k = Tensor::randn(vec![bh, n, d], &mut rng);
        let v = Tensor::randn(vec![bh, n, d], &mut rng);
        for mask in [Mask::SlidingWindow { w }, Mask::Dense] {
            let label = mask.label();
            let tiles = mask.tile_counts(n, bq, bq);
            // the enumerator's counts partition the grid and agree with
            // a brute-force tile_live scan
            let grid = n.div_ceil(bq) * n.div_ceil(bq);
            assert_eq!(tiles.live + tiles.skipped, grid,
                       "tile counts must partition the grid (n={n})");
            let brute = (0..n).step_by(bq)
                .flat_map(|iq| (0..n).step_by(bq).map(move |ik| (iq, ik)))
                .filter(|&(iq, ik)| mask.tile_live(iq, bq, ik, bq))
                .count();
            assert_eq!(tiles.live, brute,
                       "enumerator vs brute-force tile scan (n={n}, \
                        mask={label})");
            // iomodel: skipped tiles are absent from analytic and
            // simulated traffic identically (the CI smoke gate)
            let ana = iomodel::analytic_fused_fwd_masked(s, &mask, bq, bq);
            let (sim, _) = iomodel::simulate_fused_fwd_masked(
                s, &mask, bq, bq, 16 << 20);
            assert_eq!((sim.read_bytes, sim.write_bytes),
                       (ana.read_bytes, ana.write_bytes),
                       "iomodel sim ⇄ analytic (n={n}, mask={label})");
            let p = AttnParams::with_mask(d, mask.clone())
                .expect("attn params");
            // correctness anchor at the smallest length: streaming
            // (skip-aware) ≡ fused oracle
            if idx == 0 {
                let oracle = attention::mha_forward(
                    &q, &k, &v, &p, &sparkattention::exec::Scalar);
                let got = attention::mha_forward_streaming(
                    &q, &k, &v, &p, bq, bq, be.as_ref());
                let err = got.output.max_abs_diff(&oracle.output);
                assert!(err < 1e-4,
                        "streaming deviates from oracle (n={n}, \
                         mask={label}, err={err})");
            }
            let time = measure_wallclock(opts.bench, || {
                attention::mha_forward_streaming(&q, &k, &v, &p, bq, bq,
                                                 be.as_ref());
                Ok(())
            }).expect("longseq measure");
            let mb = |b: usize| b as f64 / (1 << 20) as f64;
            println!("{:>8} {:>8} {:>10.3} {:>12} {:>12} {:>12.1} \
                      {:>12.1}", n, label, time.mean() * 1e3, tiles.live,
                     tiles.skipped, mb(ana.read_bytes),
                     mb(ana.write_bytes));
            cells.push((n, label.clone(), time.mean(),
                        ana.total_bytes()));
            report.push(Row {
                group: format!("longseq/{label}"),
                variant: be.name(),
                x: n,
                time,
                flops: attention::attention_flops_masked(bh, n, d,
                                                         &p.mask, false),
                status: "ok".into(),
            });
        }
    }
    common::emit(&report, "longseq_sparse");

    let (nmin, nmax) = (ns[0], *ns.last().expect("ns"));
    let bytes_of = |n: usize, label: &str| -> f64 {
        cells.iter()
            .find(|(cn, cl, _, _)| *cn == n && cl.as_str() == label)
            .map(|&(_, _, _, b)| b as f64).expect("cell")
    };
    let time_of = |n: usize, label: &str| -> f64 {
        cells.iter()
            .find(|(cn, cl, _, _)| *cn == n && cl.as_str() == label)
            .map(|&(_, _, t, _)| t).expect("cell")
    };
    let win = Mask::SlidingWindow { w }.label();
    if nmax >= 4 * nmin {
        // deterministic traffic scaling: bytes ∝ n^e; the window stays
        // near-linear, dense near-quadratic
        let span = (nmax as f64 / nmin as f64).ln();
        let e_win = (bytes_of(nmax, &win) / bytes_of(nmin, &win)).ln()
            / span;
        let e_dense = (bytes_of(nmax, "dense")
                       / bytes_of(nmin, "dense")).ln() / span;
        println!("traffic scaling exponents over n={nmin}..{nmax}: \
                  window {e_win:.2} (≈1 linear), dense {e_dense:.2} \
                  (≈2 quadratic)");
        assert!(e_win < 1.3,
                "window traffic must scale near-linearly (got n^{e_win:.2})");
        assert!(e_dense > 1.7,
                "dense traffic must scale near-quadratically \
                 (got n^{e_dense:.2})");
    }
    if nmax >= 4096 && w * 4 <= nmax {
        // the skip-aware win in wall-clock: at the largest length the
        // dense sweep streams ≥ nmax/w× the live band, so even a noisy
        // 2× floor is a conservative gate
        let (td, tw) = (time_of(nmax, "dense"), time_of(nmax, &win));
        println!("wall-clock at n={nmax}: dense {:.1} ms vs window \
                  {:.1} ms ({:.1}×)", td * 1e3, tw * 1e3, td / tw);
        assert!(td > 2.0 * tw,
                "dense must be ≥ 2× slower than window at n={nmax} \
                 (dense {td:.4}s, window {tw:.4}s)");
    }
    println!("longseq_sparse: all invariants OK");
}
