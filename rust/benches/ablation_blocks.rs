//! `cargo bench --bench ablation_blocks` — block-shape ablation (DESIGN.md
//! §8): the same MHA problem compiled/executed with different (block_q,
//! block_k) tiles.
//!
//! Section 1 (always runs): the **host** streaming forward across a
//! (block_q, block_k) grid under every exec backend — scalar, blocked,
//! simd, and simd-mixed side by side — block shape changes the tile
//! schedule and the per-tile working set, which is the same trade the
//! device kernel makes.  Block sizes that don't divide `n` are emitted
//! as `skipped` rows (not silently dropped), so the sweep JSON is
//! shape-complete for the autotuner.  Section 2 (runs when
//! `SPARK_EXEC_TUNING_TABLE` is set): the `exec::tune` autotuner sweeps
//! its (MC, KC) grid over the attention GEMM classes, writes the table
//! to that path, and asserts the write → reload round-trip preserves
//! the block choices.  Section 3 (needs the ablation artifact profile):
//! measured CPU time next to the static VMEM footprint and
//! MXU-occupancy estimate.

mod common;

use sparkattention::attention::{self, AttnParams};
use sparkattention::bench::{measure, measure_wallclock, skipped_row, Report,
                            Row};
use sparkattention::coordinator::inputs::synth_inputs;
use sparkattention::coordinator::report_roster;
use sparkattention::exec::{tune, BackendKind};
use sparkattention::tensor::{Rng, Tensor};

fn main() {
    sparkattention::logging::init();
    let opts = common::harness_options();

    // --- host block-shape ablation, one table per (backend, mask) --------
    let (ns, bh, d) = common::host_shape();
    let n = ns.last().copied().unwrap_or(512);
    let mut rng = Rng::new(0xAB1A);
    let q = Tensor::randn(vec![bh, n, d], &mut rng);
    let k = Tensor::randn(vec![bh, n, d], &mut rng);
    let v = Tensor::randn(vec![bh, n, d], &mut rng);
    let blocks = [16usize, 32, 64, 128];
    let masks = common::host_masks();
    let mut report = Report::new(format!(
        "Host block-shape ablation (bh={bh}, n={n}, d={d})"));
    for be in report_roster(opts) {
        for spec in &masks {
            let mask = spec.build(n).expect("SPARK_HOST_MASKS mask at n");
            let p = AttnParams::with_mask(d, mask).expect("attn params");
            // dense keeps the historical per-backend group name
            let group = if *spec == attention::MaskSpec::Dense {
                be.name()
            } else {
                format!("{}/{}", be.name(), spec.label())
            };
            println!("== Host block-shape ablation (bh={bh}, n={n}, \
                      d={d}, backend {}, mask {}) ==", be.name(),
                     spec.label());
            println!("{:>8} {:>8} {:>12} {:>10} {:>10}", "block_q",
                     "block_k", "mean_ms", "live", "skipped");
            for &bq in &blocks {
                for &bk in &blocks {
                    let variant = format!("bq{bq}_bk{bk}");
                    if n % bq != 0 || n % bk != 0 {
                        // streaming requires blocks that divide n; record
                        // the cell as skipped instead of dropping it
                        report.push(skipped_row(&group, &variant, n,
                                                "skipped"));
                        println!("{:>8} {:>8} {:>12} {:>10} {:>10}", bq,
                                 bk, "-", "-", "skipped");
                        continue;
                    }
                    let time = measure_wallclock(opts.bench, || {
                        attention::mha_forward_streaming(&q, &k, &v, &p,
                                                         bq, bk,
                                                         be.as_ref());
                        Ok(())
                    }).expect("host ablation");
                    let tiles = p.mask.tile_counts(n, bq, bk);
                    println!("{:>8} {:>8} {:>12.3} {:>10} {:>10}", bq, bk,
                             time.mean() * 1e3, bh * tiles.live,
                             bh * tiles.skipped);
                    report.push(Row {
                        group: group.clone(),
                        variant,
                        x: n,
                        time,
                        flops: 0,
                        status: "ok".into(),
                    });
                }
            }
            println!();
        }
    }
    common::emit(&report, "ablation_host");
    println!("reading: wider q-blocks amortise K/V streaming; the pool \
              parallelises over (bh × n/block_q) tiles, so tiny q-blocks \
              expose more parallelism but touch K/V more often.  Masked \
              sweeps schedule only the live tiles — the `live`/`skipped` \
              columns are the skip-aware enumeration at work.\n");

    // --- autotuner sweep + table round-trip -------------------------------
    if let Ok(path) = std::env::var("SPARK_EXEC_TUNING_TABLE") {
        // the scalar backend has no block parameters; tune simd instead
        let kind = match opts.exec.kind {
            BackendKind::Scalar => BackendKind::Simd,
            other => other,
        };
        println!("== Autotune (MC, KC) per GEMM class (backend {}, \
                  bh={bh}, d={d}) ==", kind.name());
        let (table, rows) = tune::tune_attention(
            kind, opts.exec.threads, &ns, bh, d,
            &tune::default_candidates(), opts.bench)
            .expect("tune_attention");
        for r in &rows {
            println!("({}, {}, {}) {}: best {}x{}  {:.3} ms vs default \
                      {:.3} ms ({:.2}×)",
                     r.key.m, r.key.k, r.key.n, r.key.precision.name(),
                     r.best.mc, r.best.kc, r.best_s * 1e3,
                     r.default_s * 1e3, r.speedup());
        }
        if let Some(dir) = std::path::Path::new(&path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).expect("tuning table dir");
            }
        }
        table.save(&path).expect("save tuning table");
        let reloaded = tune::TuningTable::load(&path)
            .expect("reload tuning table");
        assert_eq!(reloaded, table,
                   "tuning-table round-trip must preserve block choices");
        println!("tuning table → {path} ({} entries; reload round-trip \
                  verified)\n", table.len());
        tune::install(table);
    }

    // --- device artifact ablation ----------------------------------------
    let Some(engine) = common::engine_or_skip() else { return };
    let mut metas: Vec<_> = engine.manifest().of_kind("mha_fwd_ablation")
        .cloned().collect();
    if metas.is_empty() {
        eprintln!("SKIP: ablation profile not built \
                   (python -m compile.aot --profile ablation)");
        return;
    }
    metas.sort_by_key(|m| (m.attr_i64("block_q"), m.attr_i64("block_k")));
    println!("== Block-shape ablation (bh=4, n=1024, d=64, f32-ACC) ==");
    println!("{:>8} {:>8} {:>12} {:>10} {:>12} {:>10}",
             "block_q", "block_k", "vmem_KiB", "mxu_occ", "mean_ms",
             "grid_steps");
    for meta in &metas {
        let ins = synth_inputs(meta, 42).expect("inputs");
        let time = measure(opts.bench, || {
            Ok(engine.execute_timed(&meta.name, &ins)?.1)
        }).expect("measure");
        let bq = meta.attr_i64("block_q").unwrap_or(0);
        let bk = meta.attr_i64("block_k").unwrap_or(0);
        let n = meta.attr_i64("n").unwrap_or(0);
        let bh = meta.attr_i64("bh").unwrap_or(0);
        let steps = bh * (n / bq.max(1)) * (n / bk.max(1));
        println!("{:>8} {:>8} {:>12.1} {:>10.3} {:>12.3} {:>10}",
                 bq, bk,
                 meta.attr_i64("vmem_bytes").unwrap_or(0) as f64 / 1024.0,
                 meta.attr_f64("mxu_utilization").unwrap_or(0.0),
                 time.mean() * 1e3, steps);
    }
    println!("\nreading: VMEM grows ~quadratically with the tile while \
              staying far under the 16 MiB budget, so the default \
              (choose_blocks → 256×256) minimises grid steps at full MXU \
              occupancy — the paper's m8n8k4 tile-quantisation argument \
              at MXU scale.  Asymmetric tiles buy nothing at equal step \
              count.");
}
