//! `cargo bench --bench ablation_blocks` — block-shape ablation (DESIGN.md
//! §8): the same MHA problem compiled/executed with different (block_q,
//! block_k) tiles.
//!
//! Section 1 (always runs): the **host** streaming forward across a
//! (block_q, block_k) grid under every exec backend — scalar, blocked,
//! simd, and simd-mixed side by side — block shape changes the tile
//! schedule and the per-tile working set, which is the same trade the
//! device kernel makes.  Section 2 (needs the ablation artifact
//! profile): measured CPU time next to the static VMEM footprint and
//! MXU-occupancy estimate.

mod common;

use sparkattention::attention::{self, AttnParams};
use sparkattention::bench::{measure, measure_wallclock};
use sparkattention::coordinator::inputs::synth_inputs;
use sparkattention::coordinator::report_roster;
use sparkattention::tensor::{Rng, Tensor};

fn main() {
    sparkattention::logging::init();
    let opts = common::harness_options();

    // --- host block-shape ablation, one table per exec backend -----------
    let (ns, bh, d) = common::host_shape();
    let n = ns.last().copied().unwrap_or(512);
    let p = AttnParams::new(d, false);
    let mut rng = Rng::new(0xAB1A);
    let q = Tensor::randn(vec![bh, n, d], &mut rng);
    let k = Tensor::randn(vec![bh, n, d], &mut rng);
    let v = Tensor::randn(vec![bh, n, d], &mut rng);
    let blocks: Vec<usize> =
        [16usize, 32, 64, 128].iter().copied().filter(|b| n % b == 0)
        .collect();
    for be in report_roster(opts) {
        println!("== Host block-shape ablation (bh={bh}, n={n}, d={d}, \
                  backend {}) ==", be.name());
        println!("{:>8} {:>8} {:>12} {:>10}", "block_q", "block_k",
                 "mean_ms", "tiles");
        for &bq in &blocks {
            for &bk in &blocks {
                let time = measure_wallclock(opts.bench, || {
                    attention::mha_forward_streaming(&q, &k, &v, p, bq, bk,
                                                     be.as_ref());
                    Ok(())
                }).expect("host ablation");
                println!("{:>8} {:>8} {:>12.3} {:>10}", bq, bk,
                         time.mean() * 1e3, bh * (n / bq) * (n / bk));
            }
        }
        println!();
    }
    println!("reading: wider q-blocks amortise K/V streaming; the pool \
              parallelises over (bh × n/block_q) tiles, so tiny q-blocks \
              expose more parallelism but touch K/V more often.\n");

    // --- device artifact ablation ----------------------------------------
    let Some(engine) = common::engine_or_skip() else { return };
    let mut metas: Vec<_> = engine.manifest().of_kind("mha_fwd_ablation")
        .cloned().collect();
    if metas.is_empty() {
        eprintln!("SKIP: ablation profile not built \
                   (python -m compile.aot --profile ablation)");
        return;
    }
    metas.sort_by_key(|m| (m.attr_i64("block_q"), m.attr_i64("block_k")));
    println!("== Block-shape ablation (bh=4, n=1024, d=64, f32-ACC) ==");
    println!("{:>8} {:>8} {:>12} {:>10} {:>12} {:>10}",
             "block_q", "block_k", "vmem_KiB", "mxu_occ", "mean_ms",
             "grid_steps");
    for meta in &metas {
        let ins = synth_inputs(meta, 42).expect("inputs");
        let time = measure(opts.bench, || {
            Ok(engine.execute_timed(&meta.name, &ins)?.1)
        }).expect("measure");
        let bq = meta.attr_i64("block_q").unwrap_or(0);
        let bk = meta.attr_i64("block_k").unwrap_or(0);
        let n = meta.attr_i64("n").unwrap_or(0);
        let bh = meta.attr_i64("bh").unwrap_or(0);
        let steps = bh * (n / bq.max(1)) * (n / bk.max(1));
        println!("{:>8} {:>8} {:>12.1} {:>10.3} {:>12.3} {:>10}",
                 bq, bk,
                 meta.attr_i64("vmem_bytes").unwrap_or(0) as f64 / 1024.0,
                 meta.attr_f64("mxu_utilization").unwrap_or(0.0),
                 time.mean() * 1e3, steps);
    }
    println!("\nreading: VMEM grows ~quadratically with the tile while \
              staying far under the 16 MiB budget, so the default \
              (choose_blocks → 256×256) minimises grid steps at full MXU \
              occupancy — the paper's m8n8k4 tile-quantisation argument \
              at MXU scale.  Asymmetric tiles buy nothing at equal step \
              count.");
}
