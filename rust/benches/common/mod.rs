//! Shared scaffolding for the `cargo bench` binaries (custom harness —
//! no criterion offline).  Each bench regenerates one paper artifact via
//! the same `coordinator::harness` code the `spark` CLI uses, honouring:
//!
//! * `SPARK_ARTIFACTS`      — artifact directory (default `artifacts/`)
//! * `SPARK_BENCH_ITERS`    — measured iterations (default 3)
//! * `SPARK_BENCH_WARMUP`   — warmup iterations (default 1)
//! * `SPARK_BENCH_JSON_DIR` — JSON report directory (default
//!   `bench-results/`, always written so CI can upload it)
//! * `SPARK_EXEC_BACKEND`   — host backend: `scalar` | `blocked` | `simd`;
//!   setting it (or `SPARK_EXEC_PRECISION`) pins the host figures to
//!   scalar + that backend instead of sweeping the full roster
//! * `SPARK_EXEC_THREADS`   — host worker threads (default 8; 0 = auto)
//! * `SPARK_EXEC_PRECISION` — simd numeric mode: `f32` | `mixed`
//!   (`mixed` implies the simd backend when none is set)
//! * `SPARK_HOST_NS`        — host-path sequence lengths (default 256,512)
//! * `SPARK_HOST_BH`        — host-path batch × heads (default 8)
//! * `SPARK_HOST_D`         — host-path head dim (default 64)
//! * `SPARK_HOST_MASKS`    — host-path attention masks, comma-separated
//!   `dense | causal | window:W | block:B[:DENSITY_PCT[:SEED]]`
//!   (default `dense,causal`)
//! * `SPARK_EXEC_TUNING_TABLE` — path to a `spark tune` block-shape
//!   table; installed for the host backends when the file exists
//!   (lenient: `ablation_blocks` *writes* the table at this path, so a
//!   missing file just means default blocks this run)

// Each bench binary uses a subset of these helpers.
#![allow(dead_code)]

use sparkattention::bench::{Options, Report};
use sparkattention::coordinator::harness::HarnessOptions;
use sparkattention::exec::{BackendKind, ExecOptions, Precision};
use sparkattention::runtime::Engine;

pub fn engine_or_skip() -> Option<Engine> {
    let dir = std::env::var("SPARK_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir}; run `make artifacts`");
        return None;
    }
    Some(Engine::new(dir).expect("engine"))
}

fn envnum(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

/// Host execution backend selection from the environment.  The default is
/// the blocked backend at 8 threads — the configuration the recorded
/// speedup numbers refer to.  Setting `SPARK_EXEC_BACKEND` or
/// `SPARK_EXEC_PRECISION` explicitly pins the host figures to scalar +
/// the configured backend (see `HarnessOptions::exec_pinned`).
pub fn exec_options() -> ExecOptions {
    exec_selection().0
}

/// One derivation of both the backend selection and the "was it
/// explicitly pinned" fact (the second drives `exec_pinned`): the env
/// vars are read exactly here, so the two can never drift.
fn exec_selection() -> (ExecOptions, bool) {
    // Lenient tuning-table install: benches run before the table exists
    // (ablation_blocks is the producer), so a missing/bad file reports
    // and falls back to default blocks instead of failing the bench.
    if let Ok(path) = std::env::var("SPARK_EXEC_TUNING_TABLE") {
        match sparkattention::exec::tune::install_from_path(&path) {
            Ok(n) => eprintln!("tuning table {path}: installed {n} \
                                entries"),
            Err(e) => eprintln!("tuning table {path}: not installed \
                                 ({e:#}); running with default blocks"),
        }
    }
    let backend = std::env::var("SPARK_EXEC_BACKEND").ok();
    let precision = std::env::var("SPARK_EXEC_PRECISION").ok();
    let pinned = backend.is_some() || precision.is_some();
    let mut opts = ExecOptions {
        kind: match backend.as_deref() {
            Some(name) => {
                BackendKind::parse(name).expect("SPARK_EXEC_BACKEND")
            }
            None => BackendKind::Blocked,
        },
        threads: envnum("SPARK_EXEC_THREADS", 8),
        precision: Precision::F32,
    };
    if let Some(name) = precision.as_deref() {
        // shared "mixed implies simd" rule (ExecOptions::with_precision)
        opts = opts.with_precision(
            Precision::parse(name).expect("SPARK_EXEC_PRECISION"),
            backend.is_some());
    }
    opts.validate().expect("exec options");
    (opts, pinned)
}

pub fn harness_options() -> HarnessOptions {
    let (exec, exec_pinned) = exec_selection();
    HarnessOptions {
        bench: Options {
            warmup_iters: envnum("SPARK_BENCH_WARMUP", 1),
            iters: envnum("SPARK_BENCH_ITERS", 3),
        },
        mem_budget: envnum("SPARK_BENCH_MEM_GB", 8) << 30,
        exec,
        exec_pinned,
    }
}

/// Host-path sweep shape: (sequence lengths, bh, d).
pub fn host_shape() -> (Vec<usize>, usize, usize) {
    let ns = std::env::var("SPARK_HOST_NS")
        .unwrap_or_else(|_| "256,512".into())
        .split(',')
        .map(|s| s.trim().parse().expect("SPARK_HOST_NS"))
        .collect();
    (ns, envnum("SPARK_HOST_BH", 8), envnum("SPARK_HOST_D", 64))
}

/// Host-path mask roster from `SPARK_HOST_MASKS` (default
/// `dense,causal` — the historical figure plus the paper's causal
/// column).  Window widths must be given inline (`window:W`): benches
/// have no `--window` flag to pair a bare `window` with.
pub fn host_masks() -> Vec<sparkattention::attention::MaskSpec> {
    let text = std::env::var("SPARK_HOST_MASKS")
        .unwrap_or_else(|_| "dense,causal".into());
    let masks = sparkattention::attention::MaskSpec::parse_list(&text, None)
        .expect("SPARK_HOST_MASKS");
    assert!(!masks.is_empty(), "SPARK_HOST_MASKS selected no masks");
    masks
}

/// Print the table and write the JSON report (always — CI uploads the
/// JSON directory as its bench artifact).
pub fn emit(report: &Report, name: &str) {
    let dir = std::env::var("SPARK_BENCH_JSON_DIR")
        .unwrap_or_else(|_| "bench-results".into());
    std::fs::create_dir_all(&dir).expect("bench JSON dir");
    let json = format!("{dir}/{name}.json");
    print!("{}", report.emit(Some(&json)).expect("emit"));
    eprintln!("json → {json}");
}
