//! Shared scaffolding for the `cargo bench` binaries (custom harness —
//! no criterion offline).  Each bench regenerates one paper artifact via
//! the same `coordinator::harness` code the `spark` CLI uses, honouring:
//!
//! * `SPARK_ARTIFACTS`      — artifact directory (default `artifacts/`)
//! * `SPARK_BENCH_ITERS`    — measured iterations (default 3)
//! * `SPARK_BENCH_WARMUP`   — warmup iterations (default 1)
//! * `SPARK_BENCH_JSON_DIR` — if set, JSON reports are written there

// Each bench binary uses a subset of these helpers.
#![allow(dead_code)]

use sparkattention::bench::{Options, Report};
use sparkattention::coordinator::harness::HarnessOptions;
use sparkattention::runtime::Engine;

pub fn engine_or_skip() -> Option<Engine> {
    let dir = std::env::var("SPARK_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir}; run `make artifacts`");
        return None;
    }
    Some(Engine::new(dir).expect("engine"))
}

pub fn harness_options() -> HarnessOptions {
    let envnum = |k: &str, d: usize| std::env::var(k).ok()
        .and_then(|v| v.parse().ok()).unwrap_or(d);
    HarnessOptions {
        bench: Options {
            warmup_iters: envnum("SPARK_BENCH_WARMUP", 1),
            iters: envnum("SPARK_BENCH_ITERS", 3),
        },
        mem_budget: envnum("SPARK_BENCH_MEM_GB", 8) << 30,
    }
}

pub fn emit(report: &Report, name: &str) {
    let json = std::env::var("SPARK_BENCH_JSON_DIR").ok()
        .map(|d| format!("{d}/{name}.json"));
    print!("{}", report.emit(json.as_deref()).expect("emit"));
}
