//! `cargo bench --bench fig12_e2e` — regenerates Fig 12 (E4): single
//! encoder-layer forward latency across fusion scopes (PyTorch-JIT analog,
//! SparkAttention, FasterTransformer analog), with OOM cells from the
//! memory budget.  Opens with the projection and host-latency rows for
//! the attention sub-block (scalar/blocked/simd/simd-mixed side by
//! side), so the binary reports something useful without artifacts.
//! Honours `SPARK_EXEC_TUNING_TABLE` for autotuned (MC, KC) blocks.
//! See EXPERIMENTS.md §E4.

mod common;

use sparkattention::coordinator::{fig12_e2e, host_backend_report,
                                  projected_fig12};
use sparkattention::perfmodel::V100;

fn main() {
    sparkattention::logging::init();
    let proj = projected_fig12(&V100);
    common::emit(&proj, "fig12_projected");
    if let Some((mean, max)) =
        proj.speedup_summary("sparkattention", "pytorch_jit") {
        println!("projected V100 e2e speedup: avg {mean:.2}× (max {max:.2}×)  \
                  [paper: avg 1.80× (max 2.46×)]");
    }

    // host attention-sublayer latency (the e2e figure's hot block)
    let (ns, bh, d) = common::host_shape();
    let host = host_backend_report(&ns, bh, d, false, &common::host_masks(),
                                   common::harness_options())
        .expect("host latency report");
    common::emit(&host, "fig12_host_attention");

    let Some(engine) = common::engine_or_skip() else { return };
    let report = fig12_e2e(&engine, common::harness_options())
        .expect("fig12 harness");
    common::emit(&report, "fig12_measured");
    for (v, b) in [("sparkattention", "pytorch_jit"),
                   ("fastertransformer*", "pytorch_jit"),
                   ("sparkattention", "fastertransformer*")] {
        if let Some((mean, max)) = report.speedup_summary(v, b) {
            println!("speedup {v} vs {b}: avg {mean:.2}× (max {max:.2}×)");
        }
    }
    println!("[paper: SparkAttention vs PyTorch_JIT avg 1.80× \
              (max 2.46×)]");
}
