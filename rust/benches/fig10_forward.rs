//! `cargo bench --bench fig10_forward` — regenerates Fig 10 (E1):
//! MHA-Forward across sequence lengths, head dims, causal settings, and
//! accumulator variants, measured on the CPU PJRT backend, followed by the
//! V100 projection at paper scale.
//!
//! Shape (who wins, how the gap scales) is measured; magnitude at paper
//! scale comes from the projection.  See EXPERIMENTS.md §E1.

mod common;

use sparkattention::coordinator::{fig10_forward, projected_fig10};
use sparkattention::perfmodel::V100;

fn main() {
    sparkattention::logging::init();
    if let Some(engine) = common::engine_or_skip() {
        let report = fig10_forward(&engine, common::harness_options())
            .expect("fig10 harness");
        common::emit(&report, "fig10_measured");
        for acc in ["spark_f32acc", "spark_bf16acc"] {
            if let Some((mean, max)) =
                report.speedup_summary(acc, "pytorch_fp16") {
                println!("measured speedup {acc}: avg {mean:.2}× \
                          (max {max:.2}×)");
            }
        }
    }
    let proj = projected_fig10(&V100, false);
    common::emit(&proj, "fig10_projected");
    if let Some((mean, max)) =
        proj.speedup_summary("spark_projected", "pytorch_projected") {
        println!("projected V100 speedup: avg {mean:.2}× (max {max:.2}×)  \
                  [paper: avg 4.55× (max 9.17×)]");
    }
}
