//! `cargo bench --bench fig10_forward` — regenerates Fig 10 (E1):
//! MHA-Forward across sequence lengths, head dims, causal settings, and
//! accumulator variants, followed by the V100 projection at paper scale.
//!
//! Three sections, most portable first:
//!
//! 1. **Host backend sweep** (always runs, no artifacts needed): the
//!    pure-Rust attention forward under every exec backend side by side
//!    — `scalar` reference, parallel `blocked`, vectorized `simd`, and
//!    `simd_mixed` (the TCU-numerics emulation) — with per-backend
//!    speedups and the mixed-vs-f32 max-ULP accuracy summary in the
//!    report notes.  JSON → `fig10_host.json`.
//! 2. **Measured artifact sweep** (needs `make artifacts`).
//! 3. **V100 projection** at paper scale.
//!
//! Shape (who wins, how the gap scales) is measured; magnitude at paper
//! scale comes from the projection.  Set `SPARK_EXEC_TUNING_TABLE` to a
//! `spark tune` table to run the host sweep with autotuned (MC, KC)
//! blocks (see `benches/common`).  See EXPERIMENTS.md §E1.

mod common;

use sparkattention::coordinator::{fig10_forward, host_backend_report,
                                  projected_fig10};
use sparkattention::perfmodel::V100;

fn main() {
    sparkattention::logging::init();

    // --- host backend sweep (the execution-layer figure) ----------------
    // Per-backend speedups and the mixed-vs-f32 accuracy summary are
    // emitted as report notes (table + JSON).
    let (ns, bh, d) = common::host_shape();
    let opts = common::harness_options();
    let masks = common::host_masks();
    let host = host_backend_report(&ns, bh, d, false, &masks, opts)
        .expect("host backend report");
    common::emit(&host, "fig10_host");

    // --- measured artifact sweep ----------------------------------------
    if let Some(engine) = common::engine_or_skip() {
        let report = fig10_forward(&engine, common::harness_options())
            .expect("fig10 harness");
        common::emit(&report, "fig10_measured");
        for acc in ["spark_f32acc", "spark_bf16acc"] {
            if let Some((mean, max)) =
                report.speedup_summary(acc, "pytorch_fp16") {
                println!("measured speedup {acc}: avg {mean:.2}× \
                          (max {max:.2}×)");
            }
        }
    }

    // --- V100 projection --------------------------------------------------
    let proj = projected_fig10(&V100, false);
    common::emit(&proj, "fig10_projected");
    if let Some((mean, max)) =
        proj.speedup_summary("spark_projected", "pytorch_projected") {
        println!("projected V100 speedup: avg {mean:.2}× (max {max:.2}×)  \
                  [paper: avg 4.55× (max 9.17×)]");
    }
}
