//! Negative and end-to-end tests for PR 7's correctness tooling: the
//! static invariant analyzer (`analysis`, driving `spark check`) and
//! the exec pool's debug-build write-set race detector.
//!
//! Every rule fixture lives in a string literal, so scanning this file
//! itself (the shipped-tree test below does) trips nothing.

use std::path::Path;

use sparkattention::analysis::{self, check_source, check_tree};
// The race-detector half compiles only under debug_assertions.
#[cfg(debug_assertions)]
use sparkattention::attention;
#[cfg(debug_assertions)]
use sparkattention::exec::{self, pool, Backend, ExecOptions, Task};

/// Sorted, deduplicated rule ids that fire on `src` labelled `label`.
fn rules_hit(label: &str, src: &str) -> Vec<&'static str> {
    let mut ids: Vec<&'static str> = check_source(label, src)
        .findings
        .iter()
        .map(|f| f.rule)
        .collect();
    ids.sort();
    ids.dedup();
    ids
}

// ---------------------------------------------------------------------
// Static rules: one seeded-violation fixture per rule
// ---------------------------------------------------------------------

#[test]
fn rule_unsafe_safety_fires_and_clears() {
    let bad = "fn f(p: *const f32) -> f32 { unsafe { *p } }\n";
    assert_eq!(rules_hit("rust/src/exec/x.rs", bad),
               vec!["unsafe-safety"]);

    let good = "// SAFETY: p is valid for reads by the caller contract.\n\
                fn f(p: *const f32) -> f32 { unsafe { *p } }\n";
    assert!(rules_hit("rust/src/exec/x.rs", good).is_empty());
}

#[test]
fn rule_feature_gate_fires_and_clears() {
    let bad = "/// Kernel.\n\
               ///\n\
               /// # Safety\n\
               /// Caller guarantees AVX2.\n\
               #[target_feature(enable = \"avx2\")]\n\
               pub unsafe fn k() {}\n";
    assert_eq!(rules_hit("rust/src/exec/x.rs", bad),
               vec!["feature-gate"]);

    let good = format!(
        "{bad}fn detect() -> bool {{ \
         std::is_x86_feature_detected!(\"avx2\") }}\n");
    assert!(rules_hit("rust/src/exec/x.rs", &good).is_empty());
}

#[test]
fn rule_det_hash_fires_crate_wide() {
    let bad = "use std::collections::HashMap;\n";
    assert_eq!(rules_hit("rust/src/runtime/engine.rs", bad),
               vec!["det-hash"]);
    assert_eq!(rules_hit("rust/src/metrics/mod.rs", bad),
               vec!["det-hash"]);
    let set = "let s = std::collections::HashSet::new();\n";
    assert_eq!(rules_hit("rust/src/metrics/mod.rs", set),
               vec!["det-hash"]);
}

#[test]
fn rule_det_instant_scopes_to_result_affecting_modules() {
    let src = "use std::time::Instant;\n";
    assert_eq!(rules_hit("rust/src/exec/foo.rs", src),
               vec!["det-instant"]);
    assert_eq!(rules_hit("rust/src/attention/foo.rs", src),
               vec!["det-instant"]);
    assert_eq!(rules_hit("rust/src/tensor/foo.rs", src),
               vec!["det-instant"]);
    // wall clocks are legitimate in the bench/runtime layers
    assert!(rules_hit("rust/src/bench/mod.rs", src).is_empty());
    assert!(rules_hit("rust/src/runtime/engine.rs", src).is_empty());
}

#[test]
fn rule_det_thread_id_fires_in_exec() {
    let src = "let id = std::thread::current().id();\n";
    assert_eq!(rules_hit("rust/src/exec/foo.rs", src),
               vec!["det-thread-id"]);
    assert!(rules_hit("rust/src/logging/mod.rs", src).is_empty());
}

#[test]
fn rule_fma_confinement() {
    let src = "let y = a.mul_add(b, c);\n";
    assert_eq!(rules_hit("rust/src/tensor/mod.rs", src),
               vec!["fma-confinement"]);
    assert_eq!(rules_hit("rust/src/exec/mod.rs", src),
               vec!["fma-confinement"]);
    // the mixed-precision kernels are the one licensed home for FMA
    assert!(rules_hit("rust/src/exec/simd.rs", src).is_empty());
}

#[test]
fn rule_allow_justify() {
    let bad = "#[allow(dead_code)]\nfn f() {}\n";
    assert_eq!(rules_hit("rust/src/util.rs", bad),
               vec!["allow-justify"]);

    let good = "// retained for the next PR's serving layer\n\
                #[allow(dead_code)]\nfn f() {}\n";
    assert!(rules_hit("rust/src/util.rs", good).is_empty());
}

#[test]
fn waivers_suppress_with_reason_only() {
    let waived = "// spark-check: allow(det-hash): fixture data only\n\
                  use std::collections::HashMap;\n";
    let c = check_source("rust/src/util.rs", waived);
    assert!(c.findings.is_empty(), "waiver should suppress: {:?}",
            c.findings);
    assert_eq!(c.waived, 1);

    // a reason-less waiver reports itself AND fails to suppress
    let reasonless = "// spark-check: allow(det-hash)\n\
                      use std::collections::HashMap;\n";
    assert_eq!(rules_hit("rust/src/util.rs", reasonless),
               vec!["det-hash", "waiver-syntax"]);

    // unknown rule names are typos, not suppressions
    let unknown = "// spark-check: allow(no-such-rule): because\n";
    assert_eq!(rules_hit("rust/src/util.rs", unknown),
               vec!["waiver-syntax"]);

    // a waiver only reaches its own line and the next one
    let too_far = "// spark-check: allow(det-hash): too far away\n\
                   fn g() {}\n\
                   use std::collections::HashMap;\n";
    assert_eq!(rules_hit("rust/src/util.rs", too_far),
               vec!["det-hash"]);
}

#[test]
fn tokens_in_comments_and_strings_never_trip() {
    let src = "// unsafe HashMap Instant mul_add — commentary only\n\
               let s = \"unsafe HashMap Instant mul_add\";\n";
    assert!(rules_hit("rust/src/exec/x.rs", src).is_empty());
}

// ---------------------------------------------------------------------
// Tree-level behaviour
// ---------------------------------------------------------------------

/// The shipped tree must pass with zero findings and zero waivers —
/// the analyzer gates CI, so this is the "lands green, not pre-waived"
/// satellite made executable.
#[test]
fn shipped_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = check_tree(root).expect("scanning the repo tree");
    assert!(report.files > 20,
            "suspiciously few files scanned: {}", report.files);
    let listing: Vec<String> =
        report.findings.iter().map(|f| f.to_string()).collect();
    assert!(report.findings.is_empty(),
            "shipped tree has findings:\n{}", listing.join("\n"));
    assert_eq!(report.waived, 0, "shipped tree should need no waivers");
}

/// A seeded violation in a scratch tree must surface through
/// `check_tree` — the path the CLI and the CI bin report (and exit
/// non-zero) on.
#[test]
fn seeded_violation_fails_the_tree() {
    let scratch = std::env::temp_dir()
        .join(format!("spark-check-seeded-{}", std::process::id()));
    let src_dir = scratch.join("rust/src");
    std::fs::create_dir_all(&src_dir).expect("mkdir scratch");
    std::fs::write(src_dir.join("bad.rs"),
                   "use std::collections::HashMap;\n")
        .expect("write fixture");

    let report = check_tree(&scratch).expect("scanning scratch tree");
    std::fs::remove_dir_all(&scratch).ok();

    assert_eq!(report.files, 1);
    assert_eq!(report.findings.len(), 1);
    assert_eq!(report.findings[0].rule, "det-hash");
    assert_eq!(report.findings[0].file, "rust/src/bad.rs");
}

#[test]
fn rule_table_is_coherent() {
    // every rule id is kebab-case and unique; the table is what
    // `--list-rules` prints and what waivers validate against
    let mut seen = Vec::new();
    for r in analysis::RULES {
        assert!(!r.id.is_empty() && !r.summary.is_empty());
        assert!(r.id.chars()
                 .all(|c| c.is_ascii_lowercase() || c == '-'),
                "rule id {:?} is not kebab-case", r.id);
        assert!(!seen.contains(&r.id), "duplicate rule id {:?}", r.id);
        seen.push(r.id);
    }
}

// ---------------------------------------------------------------------
// Dynamic pass: the pool write-set race detector (debug builds)
// ---------------------------------------------------------------------

#[cfg(debug_assertions)]
mod racecheck {
    use std::panic::{catch_unwind, AssertUnwindSafe};

    use super::*;

    fn noop_tasks(n: usize) -> Vec<Task<'static>> {
        (0..n).map(|_| Box::new(|| ()) as Task<'static>).collect()
    }

    /// An injected overlapping-write task list must trip the detector
    /// before anything runs — and the panic must leave the detector
    /// clean for the next call.
    #[test]
    fn overlapping_declarations_trip_run_pool() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool::declare_task_writes(&[(0x1000, 0x2000)]);
            pool::declare_task_writes(&[(0x1800, 0x2800)]);
            exec::run_pool(2, noop_tasks(2));
        }));
        let msg = match caught {
            Ok(()) => panic!("overlapping declarations did not trip"),
            Err(p) => p
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
        };
        assert!(msg.contains("race detector"),
                "unexpected panic message: {msg}");
        assert!(msg.contains("#0") && msg.contains("#1"),
                "panic should name both tasks: {msg}");

        // the failed verify drained its state: a clean run succeeds
        pool::declare_task_writes(&[(0x1000, 0x2000)]);
        pool::declare_task_writes(&[(0x2000, 0x2800)]);
        exec::run_pool(2, noop_tasks(2));
    }

    #[test]
    fn overlapping_declarations_trip_run_scoped_and_scalar() {
        for runner in [
            (|| exec::run_scoped(2, noop_tasks(2)))
                as fn(),
            || exec::Scalar.run_tasks(noop_tasks(2)),
        ] {
            let caught = catch_unwind(AssertUnwindSafe(|| {
                pool::declare_task_writes(&[(0x100, 0x200)]);
                pool::declare_task_writes(&[(0x1f0, 0x300)]);
                runner();
            }));
            assert!(caught.is_err(),
                    "every runner entry point must verify");
        }
    }

    /// Declarations from real disjoint carves — the shape every task
    /// builder in `exec`/`attention` produces — must pass.
    #[test]
    fn disjoint_carved_tiles_pass() {
        let mut data = vec![0.0f32; 64];
        let tasks: Vec<Task<'_>> = data
            .chunks_mut(16)
            .enumerate()
            .map(|(i, c)| {
                pool::declare_task_writes(&[pool::span(&*c)]);
                Box::new(move || {
                    for x in c.iter_mut() {
                        *x = i as f32;
                    }
                }) as Task<'_>
            })
            .collect();
        exec::run_pool(4, tasks);
        assert_eq!(data[0], 0.0);
        assert_eq!(data[63], 3.0);
    }

    /// A same-task multi-range declaration (dk + dv tiles, say) is not
    /// a race; cross-task overlap of either range is.
    #[test]
    fn multi_range_declarations() {
        pool::declare_task_writes(&[(0x100, 0x200), (0x400, 0x500)]);
        pool::declare_task_writes(&[(0x200, 0x300), (0x500, 0x600)]);
        exec::run_pool(2, noop_tasks(2));

        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool::declare_task_writes(&[(0x100, 0x200), (0x400, 0x500)]);
            pool::declare_task_writes(&[(0x450, 0x480)]);
            exec::run_pool(2, noop_tasks(2));
        }));
        assert!(caught.is_err(), "second range overlap must trip");
    }

    /// The full shipped backend roster — scalar, blocked, simd f32,
    /// simd mixed — runs the streaming forward/backward witness with
    /// every write declared, under the detector.  This is the positive
    /// half of the race-detector satellite: the contract holds for
    /// everything we actually ship.
    #[test]
    fn shipped_roster_runs_clean_under_detector() {
        attention::witness_self_check(ExecOptions::blocked(4))
            .expect("roster witness under the race detector");
        exec::self_check(ExecOptions::blocked(4))
            .expect("matmul self-check under the race detector");
    }
}
