//! Property tests for the execution-backend layer:
//!
//! * `Blocked` must agree with the `Scalar` reference elementwise on
//!   randomized shapes and block sizes, and must be bitwise-identical
//!   to itself across worker-thread counts (1, 2, 8) — the determinism
//!   contract the harness and the streaming attention paths rely on.
//! * `Simd` in f32 mode must be **bitwise-identical** to `Scalar` on
//!   every flavour, shape, blocking, and thread count (the vectorized
//!   kernels preserve the per-element operation order exactly).
//! * `Simd` in mixed mode must stay inside the provable bf16 error
//!   bound: operands are quantized with relative error ≤ ε = 2⁻⁸
//!   (`bf16::EPSILON`), so each product is off by ≤ (2ε + ε²)·|aᵢbᵢ|
//!   and a k-term accumulation by ≤ ~(2ε + ε²)·Σ|aᵢbᵢ| plus f32
//!   rounding noise — we assert a 3ε·Σ|aᵢbᵢ| + 1e-5 envelope per
//!   element, and bitwise determinism across thread counts.

use sparkattention::attention::{self, AttnParams};
use sparkattention::exec::{Backend, Blocked, Precision, Scalar, Simd};
use sparkattention::proptest::{check, default_cases, Gen, OneOf, USize};
use sparkattention::tensor::{bf16, Rng, Tensor};

/// Random batched-matmul problem: shape + block sizes + threads.
#[derive(Debug, Clone)]
struct MatmulCase {
    ba: usize,
    m: usize,
    k: usize,
    n: usize,
    mc: usize,
    kc: usize,
    seed: u64,
}

struct MatmulGen;

impl Gen for MatmulGen {
    type Value = MatmulCase;

    fn generate(&self, rng: &mut Rng) -> MatmulCase {
        MatmulCase {
            ba: USize { lo: 1, hi: 3 }.generate(rng),
            m: USize { lo: 1, hi: 70 }.generate(rng),
            k: USize { lo: 1, hi: 40 }.generate(rng),
            n: USize { lo: 1, hi: 50 }.generate(rng),
            mc: OneOf(vec![1usize, 3, 8, 64]).generate(rng),
            kc: OneOf(vec![2usize, 7, 256]).generate(rng),
            seed: rng.next_u64(),
        }
    }
}

#[test]
fn blocked_matmuls_match_scalar_for_any_blocking() {
    check("blocked=scalar", &MatmulGen, default_cases(), |c| {
        let mut r = Rng::new(c.seed);
        let a_nn = Tensor::randn(vec![c.ba, c.m, c.k], &mut r);
        let b_nn = Tensor::randn(vec![c.ba, c.k, c.n], &mut r);
        let b_nt = Tensor::randn(vec![c.ba, c.n, c.k], &mut r);
        let a_tn = Tensor::randn(vec![c.ba, c.k, c.m], &mut r);
        let be = Blocked::with_blocks(2, c.mc, c.kc);
        let pairs = [
            ("nn", be.batch_matmul(&a_nn, &b_nn),
             Scalar.batch_matmul(&a_nn, &b_nn)),
            ("nt", be.batch_matmul_nt(&a_nn, &b_nt),
             Scalar.batch_matmul_nt(&a_nn, &b_nt)),
            ("tn", be.batch_matmul_tn(&a_tn, &b_nn),
             Scalar.batch_matmul_tn(&a_tn, &b_nn)),
        ];
        for (name, got, want) in &pairs {
            let err = got.max_abs_diff(want);
            if err > 1e-5 {
                return Err(format!("{name} err {err} for {c:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn blocked_matmuls_identical_across_threads() {
    check("thread-invariance", &MatmulGen, default_cases() / 2, |c| {
        let mut r = Rng::new(c.seed);
        let a = Tensor::randn(vec![c.ba, c.m, c.k], &mut r);
        let b = Tensor::randn(vec![c.ba, c.k, c.n], &mut r);
        let bt = Tensor::randn(vec![c.ba, c.n, c.k], &mut r);
        let base = Blocked::with_blocks(1, c.mc, c.kc);
        let want_nn = base.batch_matmul(&a, &b);
        let want_nt = base.batch_matmul_nt(&a, &bt);
        for threads in [2usize, 8] {
            let be = Blocked::with_blocks(threads, c.mc, c.kc);
            if be.batch_matmul(&a, &b).data() != want_nn.data() {
                return Err(format!("nn bits differ at t={threads}: {c:?}"));
            }
            if be.batch_matmul_nt(&a, &bt).data() != want_nt.data() {
                return Err(format!("nt bits differ at t={threads}: {c:?}"));
            }
        }
        Ok(())
    });
}

/// `Simd` in f32 mode: bitwise-identical to `Scalar` on all three
/// matmul flavours, for any shape/blocking, at threads ∈ {1, 2, 8}.
#[test]
fn simd_f32_bitwise_identical_to_scalar() {
    check("simd-f32-bitwise", &MatmulGen, default_cases(), |c| {
        let mut r = Rng::new(c.seed);
        let a_nn = Tensor::randn(vec![c.ba, c.m, c.k], &mut r);
        let b_nn = Tensor::randn(vec![c.ba, c.k, c.n], &mut r);
        let b_nt = Tensor::randn(vec![c.ba, c.n, c.k], &mut r);
        let a_tn = Tensor::randn(vec![c.ba, c.k, c.m], &mut r);
        let want = [
            Scalar.batch_matmul(&a_nn, &b_nn),
            Scalar.batch_matmul_nt(&a_nn, &b_nt),
            Scalar.batch_matmul_tn(&a_tn, &b_nn),
        ];
        for threads in [1usize, 2, 8] {
            let be = Simd::with_blocks(threads, Precision::F32, c.mc,
                                       c.kc);
            let got = [
                be.batch_matmul(&a_nn, &b_nn),
                be.batch_matmul_nt(&a_nn, &b_nt),
                be.batch_matmul_tn(&a_tn, &b_nn),
            ];
            for (name, g, w) in [("nn", &got[0], &want[0]),
                                 ("nt", &got[1], &want[1]),
                                 ("tn", &got[2], &want[2])] {
                if g.data() != w.data() {
                    return Err(format!(
                        "{name} bits differ at t={threads}: {c:?}"));
                }
            }
        }
        Ok(())
    });
}

/// `Simd` in mixed mode: per-element error bounded by the bf16-epsilon
/// envelope vs the f32 Scalar reference, and bitwise-deterministic
/// across thread counts.
#[test]
fn simd_mixed_error_bounded_and_thread_invariant() {
    check("simd-mixed-bound", &MatmulGen, default_cases(), |c| {
        let mut r = Rng::new(c.seed);
        let a_nn = Tensor::randn(vec![c.ba, c.m, c.k], &mut r);
        let b_nn = Tensor::randn(vec![c.ba, c.k, c.n], &mut r);
        let b_nt = Tensor::randn(vec![c.ba, c.n, c.k], &mut r);
        let a_tn = Tensor::randn(vec![c.ba, c.k, c.m], &mut r);
        let want = [
            Scalar.batch_matmul(&a_nn, &b_nn),
            Scalar.batch_matmul_nt(&a_nn, &b_nt),
            Scalar.batch_matmul_tn(&a_tn, &b_nn),
        ];
        // per-element error budget: Σ|aᵢ||bᵢ| scaled by 3·ε_bf16
        let abs = |t: &Tensor| t.clone().map(f32::abs);
        let envelope = [
            Scalar.batch_matmul(&abs(&a_nn), &abs(&b_nn)),
            Scalar.batch_matmul_nt(&abs(&a_nn), &abs(&b_nt)),
            Scalar.batch_matmul_tn(&abs(&a_tn), &abs(&b_nn)),
        ];
        let mut base: Option<[Tensor; 3]> = None;
        for threads in [1usize, 2, 8] {
            let be = Simd::with_blocks(threads, Precision::Mixed, c.mc,
                                       c.kc);
            let got = [
                be.batch_matmul(&a_nn, &b_nn),
                be.batch_matmul_nt(&a_nn, &b_nt),
                be.batch_matmul_tn(&a_tn, &b_nn),
            ];
            for (fl, (g_t, (w_t, e_t))) in
                got.iter().zip(want.iter().zip(&envelope)).enumerate()
            {
                for ((&g, &w), &bd) in g_t.data().iter()
                    .zip(w_t.data())
                    .zip(e_t.data())
                {
                    let bound = 3.0 * bf16::EPSILON * bd + 1e-5;
                    if (g - w).abs() > bound {
                        return Err(format!(
                            "flavour {fl}: |{g} − {w}| > {bound} \
                             at t={threads}: {c:?}"));
                    }
                }
            }
            if let Some(b0) = &base {
                if got.iter().zip(b0).any(|(g, b)| g.data() != b.data()) {
                    return Err(format!(
                        "mixed bits differ at t={threads}: {c:?}"));
                }
            } else {
                base = Some(got);
            }
        }
        Ok(())
    });
}

/// Random attention problem with valid streaming blocks.  `window`
/// (when set) swaps the dense/causal mask for a sliding window of that
/// width — width 0 included, which fully masks *every* row (the
/// headline-bugfix edge: zero outputs, `-inf` LSE sentinels, and the
/// bitwise contracts below must all still hold).
#[derive(Debug, Clone)]
struct AttnCase {
    bh: usize,
    n: usize,
    d: usize,
    block_q: usize,
    block_k: usize,
    causal: bool,
    window: Option<usize>,
    seed: u64,
}

impl AttnCase {
    fn params(&self) -> AttnParams {
        match self.window {
            Some(w) => AttnParams::with_mask(
                self.d, attention::Mask::SlidingWindow { w }).unwrap(),
            None => AttnParams::new(self.d, self.causal).unwrap(),
        }
    }
}

struct AttnGen;

impl Gen for AttnGen {
    type Value = AttnCase;

    fn generate(&self, rng: &mut Rng) -> AttnCase {
        let n = OneOf(vec![4usize, 8, 16, 32, 48]).generate(rng);
        let divisors: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
        let blocks = OneOf(divisors);
        AttnCase {
            bh: USize { lo: 1, hi: 3 }.generate(rng),
            n,
            d: OneOf(vec![2usize, 4, 8, 16]).generate(rng),
            block_q: blocks.generate(rng),
            block_k: blocks.generate(rng),
            causal: rng.uniform() < 0.5,
            window: if rng.uniform() < 0.4 {
                Some(USize { lo: 0, hi: n }.generate(rng))
            } else {
                None
            },
            seed: rng.next_u64(),
        }
    }
}

fn qkv(c: &AttnCase) -> (Tensor, Tensor, Tensor, Tensor) {
    let mut r = Rng::new(c.seed);
    (Tensor::randn(vec![c.bh, c.n, c.d], &mut r),
     Tensor::randn(vec![c.bh, c.n, c.d], &mut r),
     Tensor::randn(vec![c.bh, c.n, c.d], &mut r),
     Tensor::randn(vec![c.bh, c.n, c.d], &mut r))
}

/// The full attention path (oracle fwd/bwd + streamed fwd/bwd) computed
/// under `Blocked` must agree with `Scalar` — for any shape, any block
/// size, and be bitwise-stable across thread counts.
#[test]
fn attention_path_backend_parity_and_thread_invariance() {
    check("attn-backend-parity", &AttnGen, default_cases() / 2, |c| {
        let (q, k, v, dout) = qkv(&c);
        let p = &c.params();

        let fwd_s = attention::mha_forward(&q, &k, &v, p, &Scalar);
        let stream_s = attention::mha_forward_streaming(
            &q, &k, &v, p, c.block_q, c.block_k, &Scalar);
        let bwd_s = attention::mha_backward_streaming(
            &q, &k, &v, &dout, &fwd_s.lse, p, c.block_q, c.block_k,
            &Scalar);

        let mut last: Option<(Tensor, Tensor, Tensor)> = None;
        for threads in [1usize, 2, 8] {
            let be = Blocked::new(threads);
            let fwd = attention::mha_forward(&q, &k, &v, p, &be);
            if fwd.output.max_abs_diff(&fwd_s.output) > 1e-5 {
                return Err(format!("fwd mismatch t={threads}: {c:?}"));
            }
            let stream = attention::mha_forward_streaming(
                &q, &k, &v, p, c.block_q, c.block_k, &be);
            if stream.output.data() != stream_s.output.data()
                || stream.lse.data() != stream_s.lse.data()
            {
                return Err(format!(
                    "streamed fwd bits differ t={threads}: {c:?}"));
            }
            let bwd = attention::mha_backward_streaming(
                &q, &k, &v, &dout, &fwd_s.lse, p, c.block_q, c.block_k,
                &be);
            for (name, got, want) in [("dq", &bwd.dq, &bwd_s.dq),
                                      ("dk", &bwd.dk, &bwd_s.dk),
                                      ("dv", &bwd.dv, &bwd_s.dv)] {
                let err = got.max_abs_diff(want);
                if err > 1e-4 {
                    return Err(format!(
                        "{name} err {err} t={threads}: {c:?}"));
                }
            }
            if let Some((dq, dk, dv)) = &last {
                if bwd.dq.data() != dq.data() || bwd.dk.data() != dk.data()
                    || bwd.dv.data() != dv.data()
                {
                    return Err(format!(
                        "bwd bits differ across threads: {c:?}"));
                }
            }
            last = Some((bwd.dq, bwd.dk, bwd.dv));
        }
        // Simd in f32 mode joins the same bitwise contract on the
        // streamed paths (tile kernels + pool, identical op order).
        for threads in [1usize, 2, 8] {
            let be = Simd::new(threads, Precision::F32);
            let stream = attention::mha_forward_streaming(
                &q, &k, &v, p, c.block_q, c.block_k, &be);
            if stream.output.data() != stream_s.output.data()
                || stream.lse.data() != stream_s.lse.data()
            {
                return Err(format!(
                    "simd streamed fwd bits differ t={threads}: {c:?}"));
            }
            let bwd = attention::mha_backward_streaming(
                &q, &k, &v, &dout, &fwd_s.lse, p, c.block_q, c.block_k,
                &be);
            if bwd.dq.data() != bwd_s.dq.data()
                || bwd.dk.data() != bwd_s.dk.data()
                || bwd.dv.data() != bwd_s.dv.data()
            {
                return Err(format!(
                    "simd streamed bwd bits differ t={threads}: {c:?}"));
            }
        }
        Ok(())
    });
}

/// The mixed-precision streaming forward equals the f32 streaming
/// forward of bf16-quantized inputs, up to the P-tile quantization —
/// a per-element envelope of ~3·ε_bf16·max|v|, asserted here with a
/// 16·ε_bf16·(1 + max|v|) margin — and is bitwise-deterministic across
/// thread counts.
#[test]
fn simd_mixed_attention_bounded_and_thread_invariant() {
    check("simd-mixed-attention", &AttnGen, default_cases() / 2, |c| {
        let (q, k, v, _dout) = qkv(&c);
        let p = &c.params();
        let qq = q.clone().quantize_bf16();
        let kq = k.clone().quantize_bf16();
        let vq = v.clone().quantize_bf16();
        let want = attention::mha_forward_streaming(
            &qq, &kq, &vq, p, c.block_q, c.block_k, &Scalar);
        let vmax = v.data().iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let tol = 16.0 * bf16::EPSILON * (1.0 + vmax);
        let mut base: Option<Tensor> = None;
        for threads in [1usize, 2, 8] {
            let be = Simd::new(threads, Precision::Mixed);
            let got = attention::mha_forward_streaming(
                &q, &k, &v, p, c.block_q, c.block_k, &be);
            let err = got.output.max_abs_diff(&want.output);
            if err > tol {
                return Err(format!(
                    "mixed streaming err {err} > tol {tol} \
                     at t={threads}: {c:?}"));
            }
            if let Some(b0) = &base {
                if got.output.data() != b0.data() {
                    return Err(format!(
                        "mixed streaming bits differ t={threads}: {c:?}"));
                }
            } else {
                base = Some(got.output);
            }
        }
        Ok(())
    });
}
