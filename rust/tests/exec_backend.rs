//! Property tests for the execution-backend layer: the `Blocked` backend
//! must agree with the `Scalar` reference elementwise on randomized
//! shapes and block sizes, and must be bitwise-identical to itself
//! across worker-thread counts (1, 2, 8) — the determinism contract the
//! harness and the streaming attention paths rely on.

use sparkattention::attention::{self, AttnParams};
use sparkattention::exec::{Backend, Blocked, Scalar};
use sparkattention::proptest::{check, default_cases, Gen, OneOf, USize};
use sparkattention::tensor::{Rng, Tensor};

/// Random batched-matmul problem: shape + block sizes + threads.
#[derive(Debug, Clone)]
struct MatmulCase {
    ba: usize,
    m: usize,
    k: usize,
    n: usize,
    mc: usize,
    kc: usize,
    seed: u64,
}

struct MatmulGen;

impl Gen for MatmulGen {
    type Value = MatmulCase;

    fn generate(&self, rng: &mut Rng) -> MatmulCase {
        MatmulCase {
            ba: USize { lo: 1, hi: 3 }.generate(rng),
            m: USize { lo: 1, hi: 70 }.generate(rng),
            k: USize { lo: 1, hi: 40 }.generate(rng),
            n: USize { lo: 1, hi: 50 }.generate(rng),
            mc: OneOf(vec![1usize, 3, 8, 64]).generate(rng),
            kc: OneOf(vec![2usize, 7, 256]).generate(rng),
            seed: rng.next_u64(),
        }
    }
}

#[test]
fn blocked_matmuls_match_scalar_for_any_blocking() {
    check("blocked=scalar", &MatmulGen, default_cases(), |c| {
        let mut r = Rng::new(c.seed);
        let a_nn = Tensor::randn(vec![c.ba, c.m, c.k], &mut r);
        let b_nn = Tensor::randn(vec![c.ba, c.k, c.n], &mut r);
        let b_nt = Tensor::randn(vec![c.ba, c.n, c.k], &mut r);
        let a_tn = Tensor::randn(vec![c.ba, c.k, c.m], &mut r);
        let be = Blocked::with_blocks(2, c.mc, c.kc);
        let pairs = [
            ("nn", be.batch_matmul(&a_nn, &b_nn),
             Scalar.batch_matmul(&a_nn, &b_nn)),
            ("nt", be.batch_matmul_nt(&a_nn, &b_nt),
             Scalar.batch_matmul_nt(&a_nn, &b_nt)),
            ("tn", be.batch_matmul_tn(&a_tn, &b_nn),
             Scalar.batch_matmul_tn(&a_tn, &b_nn)),
        ];
        for (name, got, want) in &pairs {
            let err = got.max_abs_diff(want);
            if err > 1e-5 {
                return Err(format!("{name} err {err} for {c:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn blocked_matmuls_identical_across_threads() {
    check("thread-invariance", &MatmulGen, default_cases() / 2, |c| {
        let mut r = Rng::new(c.seed);
        let a = Tensor::randn(vec![c.ba, c.m, c.k], &mut r);
        let b = Tensor::randn(vec![c.ba, c.k, c.n], &mut r);
        let bt = Tensor::randn(vec![c.ba, c.n, c.k], &mut r);
        let base = Blocked::with_blocks(1, c.mc, c.kc);
        let want_nn = base.batch_matmul(&a, &b);
        let want_nt = base.batch_matmul_nt(&a, &bt);
        for threads in [2usize, 8] {
            let be = Blocked::with_blocks(threads, c.mc, c.kc);
            if be.batch_matmul(&a, &b).data() != want_nn.data() {
                return Err(format!("nn bits differ at t={threads}: {c:?}"));
            }
            if be.batch_matmul_nt(&a, &bt).data() != want_nt.data() {
                return Err(format!("nt bits differ at t={threads}: {c:?}"));
            }
        }
        Ok(())
    });
}

/// Random attention problem with valid streaming blocks.
#[derive(Debug, Clone)]
struct AttnCase {
    bh: usize,
    n: usize,
    d: usize,
    block_q: usize,
    block_k: usize,
    causal: bool,
    seed: u64,
}

struct AttnGen;

impl Gen for AttnGen {
    type Value = AttnCase;

    fn generate(&self, rng: &mut Rng) -> AttnCase {
        let n = OneOf(vec![4usize, 8, 16, 32, 48]).generate(rng);
        let divisors: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
        let blocks = OneOf(divisors);
        AttnCase {
            bh: USize { lo: 1, hi: 3 }.generate(rng),
            n,
            d: OneOf(vec![2usize, 4, 8, 16]).generate(rng),
            block_q: blocks.generate(rng),
            block_k: blocks.generate(rng),
            causal: rng.uniform() < 0.5,
            seed: rng.next_u64(),
        }
    }
}

fn qkv(c: &AttnCase) -> (Tensor, Tensor, Tensor, Tensor) {
    let mut r = Rng::new(c.seed);
    (Tensor::randn(vec![c.bh, c.n, c.d], &mut r),
     Tensor::randn(vec![c.bh, c.n, c.d], &mut r),
     Tensor::randn(vec![c.bh, c.n, c.d], &mut r),
     Tensor::randn(vec![c.bh, c.n, c.d], &mut r))
}

/// The full attention path (oracle fwd/bwd + streamed fwd/bwd) computed
/// under `Blocked` must agree with `Scalar` — for any shape, any block
/// size, and be bitwise-stable across thread counts.
#[test]
fn attention_path_backend_parity_and_thread_invariance() {
    check("attn-backend-parity", &AttnGen, default_cases() / 2, |c| {
        let (q, k, v, dout) = qkv(&c);
        let p = AttnParams::new(c.d, c.causal);

        let fwd_s = attention::mha_forward(&q, &k, &v, p, &Scalar);
        let stream_s = attention::mha_forward_streaming(
            &q, &k, &v, p, c.block_q, c.block_k, &Scalar);
        let bwd_s = attention::mha_backward_streaming(
            &q, &k, &v, &dout, &fwd_s.lse, p, c.block_q, c.block_k,
            &Scalar);

        let mut last: Option<(Tensor, Tensor, Tensor)> = None;
        for threads in [1usize, 2, 8] {
            let be = Blocked::new(threads);
            let fwd = attention::mha_forward(&q, &k, &v, p, &be);
            if fwd.output.max_abs_diff(&fwd_s.output) > 1e-5 {
                return Err(format!("fwd mismatch t={threads}: {c:?}"));
            }
            let stream = attention::mha_forward_streaming(
                &q, &k, &v, p, c.block_q, c.block_k, &be);
            if stream.output.data() != stream_s.output.data()
                || stream.lse.data() != stream_s.lse.data()
            {
                return Err(format!(
                    "streamed fwd bits differ t={threads}: {c:?}"));
            }
            let bwd = attention::mha_backward_streaming(
                &q, &k, &v, &dout, &fwd_s.lse, p, c.block_q, c.block_k,
                &be);
            for (name, got, want) in [("dq", &bwd.dq, &bwd_s.dq),
                                      ("dk", &bwd.dk, &bwd_s.dk),
                                      ("dv", &bwd.dv, &bwd_s.dv)] {
                let err = got.max_abs_diff(want);
                if err > 1e-4 {
                    return Err(format!(
                        "{name} err {err} t={threads}: {c:?}"));
                }
            }
            if let Some((dq, dk, dv)) = &last {
                if bwd.dq.data() != dq.data() || bwd.dk.data() != dk.data()
                    || bwd.dv.data() != dv.data()
                {
                    return Err(format!(
                        "bwd bits differ across threads: {c:?}"));
                }
            }
            last = Some((bwd.dq, bwd.dk, bwd.dv));
        }
        Ok(())
    });
}
