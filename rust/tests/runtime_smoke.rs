//! Integration: load AOT artifacts and execute them through PJRT.
//!
//! Requires `make artifacts` (or at least the accuracy profile).  Tests are
//! skipped gracefully when the artifact directory is absent so `cargo test`
//! stays green on a fresh checkout.

use sparkattention::attention;
use sparkattention::exec::Scalar;
use sparkattention::runtime::{Engine, HostValue};
use sparkattention::tensor::{Rng, Tensor};

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(
        std::env::var("SPARK_ARTIFACTS").unwrap_or_else(|_| {
            format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
        }));
    dir.join("manifest.json").exists().then_some(dir)
}

fn engine() -> Option<Engine> {
    artifact_dir().map(|d| Engine::new(d).expect("engine"))
}

#[test]
fn fused_fwd_matches_rust_oracle() {
    let Some(eng) = engine() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let name = "mha_fwd_fused_f32_d64_n256_bh2_c0_p0";
    let meta = eng.manifest().get(name).expect("accuracy artifact").clone();
    let (bh, n, d) = (2usize, 256usize, 64usize);
    let mut rng = Rng::new(42);
    let q = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let k = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let v = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let inputs = vec![
        HostValue::scalar_f32(0.0),
        HostValue::from_tensor(&q),
        HostValue::from_tensor(&k),
        HostValue::from_tensor(&v),
    ];
    let out = eng.execute(name, &inputs).expect("execute");
    assert_eq!(out.len(), meta.outputs.len());
    let o_dev = out[0].as_tensor().unwrap();

    let o_ref = attention::mha_forward(&q, &k, &v, &attention::AttnParams {
        mask: attention::Mask::Dense,
        scale: 1.0 / (d as f32).sqrt(),
    }, &Scalar).output;
    let err = o_dev.max_abs_diff(&o_ref);
    assert!(err < 0.05, "device vs oracle max err {err}");
}

#[test]
fn fused_fwd_causal_matches_rust_oracle() {
    let Some(eng) = engine() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let name = "mha_fwd_fused_f32_d64_n256_bh2_c1_p0";
    let (bh, n, d) = (2usize, 256usize, 64usize);
    let mut rng = Rng::new(7);
    let q = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let k = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let v = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let inputs = vec![
        HostValue::scalar_f32(0.0),
        HostValue::from_tensor(&q),
        HostValue::from_tensor(&k),
        HostValue::from_tensor(&v),
    ];
    let out = eng.execute(name, &inputs).expect("execute");
    let o_dev = out[0].as_tensor().unwrap();
    let o_ref = attention::mha_forward(&q, &k, &v, &attention::AttnParams {
        mask: attention::Mask::Causal,
        scale: 1.0 / (d as f32).sqrt(),
    }, &Scalar).output;
    let err = o_dev.max_abs_diff(&o_ref);
    assert!(err < 0.05, "causal device vs oracle max err {err}");
}

#[test]
fn fused_bwd_matches_rust_oracle() {
    let Some(eng) = engine() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let fwd = "mha_fwd_fused_f32_d64_n256_bh2_c0_p0";
    let bwd = "mha_bwd_fused_f32_d64_n256_bh2_c0_p0";
    let (bh, n, d) = (2usize, 256usize, 64usize);
    let mut rng = Rng::new(11);
    let q = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let k = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let v = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let dout = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let seed = HostValue::scalar_f32(0.0);

    let f = eng.execute(fwd, &[
        seed.clone(), HostValue::from_tensor(&q),
        HostValue::from_tensor(&k), HostValue::from_tensor(&v),
    ]).expect("fwd");
    let (o, lse) = (&f[0], &f[1]);

    let b = eng.execute(bwd, &[
        seed, HostValue::from_tensor(&q), HostValue::from_tensor(&k),
        HostValue::from_tensor(&v), o.clone(), lse.clone(),
        HostValue::from_tensor(&dout),
    ]).expect("bwd");
    let params = attention::AttnParams { mask: attention::Mask::Dense,
                                         scale: 1.0 / (d as f32).sqrt() };
    let grads = attention::mha_backward(&q, &k, &v, &dout, &params,
                                        &Scalar);
    for (dev, oracle, nm) in [(&b[0], &grads.dq, "dq"),
                              (&b[1], &grads.dk, "dk"),
                              (&b[2], &grads.dv, "dv")] {
        let err = dev.as_tensor().unwrap().max_abs_diff(oracle);
        assert!(err < 0.08, "{nm} device vs oracle max err {err}");
    }
}

#[test]
fn unfused_and_fused_agree() {
    let Some(eng) = engine() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let fused = "mha_fwd_fused_f32_d64_n256_bh2_c1_p0";
    let unfused = "mha_fwd_unfused_d64_n256_bh2_c1_p0";
    let (bh, n, d) = (2usize, 256usize, 64usize);
    let mut rng = Rng::new(13);
    let q = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let k = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let v = Tensor::randn_bf16(vec![bh, n, d], &mut rng);
    let inputs = vec![
        HostValue::scalar_f32(0.0),
        HostValue::from_tensor(&q),
        HostValue::from_tensor(&k),
        HostValue::from_tensor(&v),
    ];
    let of = eng.execute(fused, &inputs).unwrap()[0].as_tensor().unwrap();
    let ou = eng.execute(unfused, &inputs).unwrap()[0].as_tensor().unwrap();
    let err = of.max_abs_diff(&ou);
    assert!(err < 0.05, "fused vs unfused max err {err}");
}

#[test]
fn engine_caches_compilations() {
    let Some(eng) = engine() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let name = "mha_fwd_fused_f32_d64_n256_bh2_c0_p0";
    eng.load(name).unwrap();
    let c1 = eng.stats().compiles;
    eng.load(name).unwrap();
    assert_eq!(eng.stats().compiles, c1, "second load must hit the cache");
}
