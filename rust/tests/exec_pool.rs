//! Property tests for PR 6's execution-layer changes:
//!
//! * the **persistent worker pool** (`exec::run_pool`) must produce the
//!   same observable effects as the retired per-call scoped pool
//!   (`exec::run_scoped`) for any task set and thread count, including
//!   across pool reuse — the pool is a throughput optimisation, never a
//!   semantic change;
//! * the **autotuner** (`exec::tune`) may substitute any (MC, KC)
//!   candidate it sweeps without changing a single output bit on any
//!   backend — blocking only re-orders *iteration*, not accumulation —
//!   so a tuning table is always numerically safe to install;
//! * the tuning table survives a JSON save → load round-trip unchanged.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use sparkattention::attention::{self, AttnParams, BlockLayout, Mask};
use sparkattention::bench::Options;
use sparkattention::exec::{self, tune, Backend, BackendKind, Blocked,
                           Precision, Scalar, Simd, Task};
use sparkattention::proptest::{check, default_cases, Gen, OneOf, USize};
use sparkattention::tensor::{Rng, Tensor};

/// Random task-set: how many tasks, how many threads, and per-task
/// "work" amounts whose ordering-sensitive digest we compare.
#[derive(Debug, Clone)]
struct PoolCase {
    tasks: usize,
    threads: usize,
    seed: u64,
}

struct PoolGen;

impl Gen for PoolGen {
    type Value = PoolCase;

    fn generate(&self, rng: &mut Rng) -> PoolCase {
        PoolCase {
            tasks: USize { lo: 0, hi: 40 }.generate(rng),
            threads: OneOf(vec![1usize, 2, 3, 8, 17]).generate(rng),
            seed: rng.next_u64(),
        }
    }
}

/// Run `c.tasks` tasks through `run`, each writing a value into its own
/// slot (disjoint data, like the backends' row-tiles) and bumping a
/// shared counter.  Returns (slots, executions).
fn drive(c: &PoolCase, run: fn(usize, Vec<Task<'_>>)) -> (Vec<u64>, usize) {
    let slots: Vec<AtomicU64> =
        (0..c.tasks).map(|_| AtomicU64::new(0)).collect();
    let ran = AtomicUsize::new(0);
    let tasks: Vec<Task<'_>> = (0..c.tasks)
        .map(|i| {
            let slot = &slots[i];
            let ran = &ran;
            let seed = c.seed;
            Box::new(move || {
                // deterministic per-task payload
                let mut r = Rng::new(seed ^ (i as u64).wrapping_mul(0x9E37));
                slot.store(r.next_u64(), Ordering::Relaxed);
                ran.fetch_add(1, Ordering::Relaxed);
            }) as Task<'_>
        })
        .collect();
    run(c.threads, tasks);
    (slots.into_iter().map(AtomicU64::into_inner).collect(),
     ran.load(Ordering::Relaxed))
}

/// The persistent pool is observationally identical to the scoped
/// reference pool: every task runs exactly once with the same per-task
/// results, for any (task count, thread count), and stays so across
/// repeated reuse of the long-lived workers.
#[test]
fn persistent_pool_matches_scoped_pool_across_threads_and_reuse() {
    check("pool=scoped", &PoolGen, default_cases(), |c| {
        let (want_slots, want_ran) = drive(&c, exec::run_scoped);
        if want_ran != c.tasks {
            return Err(format!("scoped ran {want_ran}/{} tasks: {c:?}",
                               c.tasks));
        }
        // several rounds: the pool's lazily-grown workers are reused
        for round in 0..3 {
            let (slots, ran) = drive(&c, exec::run_pool);
            if ran != c.tasks {
                return Err(format!(
                    "pool ran {ran}/{} tasks (round {round}): {c:?}",
                    c.tasks));
            }
            if slots != want_slots {
                return Err(format!(
                    "pool results differ from scoped (round {round}): \
                     {c:?}"));
            }
        }
        Ok(())
    });
}

/// A panicking task surfaces as a panic at the `run_pool` call site, and
/// the shared pool remains fully usable afterwards.
#[test]
fn pool_propagates_task_panics_and_survives() {
    let boom = std::panic::catch_unwind(|| {
        let tasks: Vec<Task<'_>> = (0..8)
            .map(|i| {
                Box::new(move || {
                    if i == 5 {
                        panic!("task {i} failed");
                    }
                }) as Task<'_>
            })
            .collect();
        exec::run_pool(4, tasks);
    });
    assert!(boom.is_err(), "the task panic must reach the caller");

    // the pool is not poisoned: a follow-up run completes normally
    let ran = AtomicUsize::new(0);
    let tasks: Vec<Task<'_>> = (0..16)
        .map(|_| {
            let ran = &ran;
            Box::new(move || {
                ran.fetch_add(1, Ordering::Relaxed);
            }) as Task<'_>
        })
        .collect();
    exec::run_pool(4, tasks);
    assert_eq!(ran.load(Ordering::Relaxed), 16);
}

/// Random batched-matmul shape for the block-substitution properties.
#[derive(Debug, Clone)]
struct ShapeCase {
    ba: usize,
    m: usize,
    k: usize,
    n: usize,
    seed: u64,
}

struct ShapeGen;

impl Gen for ShapeGen {
    type Value = ShapeCase;

    fn generate(&self, rng: &mut Rng) -> ShapeCase {
        ShapeCase {
            ba: USize { lo: 1, hi: 3 }.generate(rng),
            m: USize { lo: 1, hi: 50 }.generate(rng),
            k: USize { lo: 1, hi: 33 }.generate(rng),
            n: USize { lo: 1, hi: 40 }.generate(rng),
            seed: rng.next_u64(),
        }
    }
}

fn operands(c: &ShapeCase) -> (Tensor, Tensor, Tensor) {
    let mut r = Rng::new(c.seed);
    (Tensor::randn(vec![c.ba, c.m, c.k], &mut r),
     Tensor::randn(vec![c.ba, c.k, c.n], &mut r),
     Tensor::randn(vec![c.ba, c.n, c.k], &mut r))
}

/// `nn` and `nt` outputs of one backend, as raw bit vectors.
fn outputs(be: &dyn Backend, a: &Tensor, b: &Tensor, bt: &Tensor)
           -> (Vec<f32>, Vec<f32>) {
    (be.batch_matmul(a, b).data().to_vec(),
     be.batch_matmul_nt(a, bt).data().to_vec())
}

/// Every (MC, KC) candidate the autotuner may emit is bitwise-identical
/// to the default blocking on every backend × precision — the guarantee
/// that makes installing a tuning table numerically free.
#[test]
fn any_tuner_candidate_blocks_are_bitwise_identical_to_defaults() {
    check("tuner-candidates-bitwise", &ShapeGen, default_cases() / 4, |c| {
        let (a, b, bt) = operands(&c);
        let dfl = tune::Blocks::default_blocks();
        let reference = [
            outputs(&Blocked::with_blocks(2, dfl.mc, dfl.kc), &a, &b, &bt),
            outputs(&Simd::with_blocks(2, Precision::F32, dfl.mc, dfl.kc),
                    &a, &b, &bt),
            outputs(&Simd::with_blocks(2, Precision::Mixed, dfl.mc, dfl.kc),
                    &a, &b, &bt),
        ];
        for cand in tune::default_candidates() {
            let got = [
                outputs(&Blocked::with_blocks(2, cand.mc, cand.kc),
                        &a, &b, &bt),
                outputs(&Simd::with_blocks(2, Precision::F32, cand.mc,
                                           cand.kc), &a, &b, &bt),
                outputs(&Simd::with_blocks(2, Precision::Mixed, cand.mc,
                                           cand.kc), &a, &b, &bt),
            ];
            for (which, (g, w)) in got.iter().zip(&reference).enumerate() {
                if g != w {
                    return Err(format!(
                        "backend #{which} bits differ at blocks \
                         {}x{}: {c:?}", cand.mc, cand.kc));
                }
            }
        }
        Ok(())
    });
}

/// Installing a tuning table changes which blocks `Blocked::new` /
/// `Simd::new` pick, but never the bits they produce.
#[test]
fn installed_tuning_table_never_changes_bits() {
    let c = ShapeCase { ba: 2, m: 33, k: 21, n: 18, seed: 0xB175 };
    let (a, b, bt) = operands(&c);
    let backends: Vec<Box<dyn Backend>> = vec![
        Box::new(Blocked::new(2)),
        Box::new(Simd::new(2, Precision::F32)),
        Box::new(Simd::new(2, Precision::Mixed)),
    ];
    let before: Vec<_> = backends.iter()
        .map(|be| outputs(be.as_ref(), &a, &b, &bt))
        .collect();

    // remap exactly this problem class (both `nn` and `nt` reduce over
    // the same k, so they share the key) to odd little blocks
    let mut table = tune::TuningTable::default();
    for precision in [Precision::F32, Precision::Mixed] {
        table.insert(
            tune::ProblemKey { m: c.m, k: c.k, n: c.n, precision },
            tune::Blocks { mc: 5, kc: 3 });
    }
    tune::install(table);
    let after: Vec<_> = backends.iter()
        .map(|be| outputs(be.as_ref(), &a, &b, &bt))
        .collect();
    tune::uninstall();

    assert_eq!(before, after,
               "tuned block substitution must be bitwise invisible");
}

/// `tune_attention` output survives save → load exactly, end to end
/// (the same invariant `ablation_blocks` asserts in CI).
#[test]
fn tuner_round_trips_through_json() {
    let candidates = [tune::Blocks::default_blocks(),
                      tune::Blocks { mc: 8, kc: 4 }];
    let opts = Options { warmup_iters: 0, iters: 1 };
    let (table, rows) = tune::tune_attention(
        BackendKind::Blocked, 2, &[16], 1, 8, &candidates, opts)
        .expect("tune_attention");
    assert!(!table.is_empty(), "tuning produced no entries");
    assert_eq!(table.len(), rows.len());
    for r in &rows {
        assert!(candidates.contains(&r.best),
                "winner {:?} is not a candidate", r.best);
        assert!(r.best_s > 0.0 && r.default_s > 0.0);
    }

    let path = format!("{}/spark-exec-pool-tune-{}.json",
                       std::env::temp_dir().display(), std::process::id());
    table.save(&path).expect("save");
    let reloaded = tune::TuningTable::load(&path).expect("load");
    std::fs::remove_file(&path).ok();
    assert_eq!(reloaded, table, "JSON round-trip must preserve the table");
}

/// The skip-aware streaming task builders (fwd and bwd) run under the
/// debug write-set race detector: every `run_tasks` call on a pooled
/// backend first drains the builders' declared byte ranges through
/// `verify_declared_disjoint`.  A builder that packed a dead tile, or
/// declared a write set it doesn't own, would panic here.  Masks are
/// chosen to stress the skip logic: a narrow sliding window (most
/// tiles dead), the fully-masked `w = 0` degenerate, and a block-
/// sparse grid with a fully dead block-row and a single-live-tile
/// row.  Results must also stay bitwise equal to the scalar inline
/// path — skipping tiles may change the task set, never the bits.
#[test]
fn masked_streaming_builders_pass_write_set_race_detector() {
    let (bh, n, d) = (2usize, 32usize, 8usize);
    let mut rng = Rng::new(0x8A5E);
    let q = Tensor::randn(vec![bh, n, d], &mut rng);
    let k = Tensor::randn(vec![bh, n, d], &mut rng);
    let v = Tensor::randn(vec![bh, n, d], &mut rng);
    let dout = Tensor::randn(vec![bh, n, d], &mut rng);

    let layout = BlockLayout::new(8, 4, vec![
        true,  false, false, false,
        false, false, false, false, // queries 8..16: fully masked rows
        true,  false, false, false, // single live tile
        false, true,  true,  true,
    ]).expect("layout");
    let masks = [Mask::SlidingWindow { w: 3 },
                 Mask::SlidingWindow { w: 0 },
                 Mask::BlockSparse { layout }];
    for mask in masks {
        let p = AttnParams::with_mask(d, mask).expect("params");
        let want = attention::mha_forward_streaming(&q, &k, &v, &p, 8, 8,
                                                    &Scalar);
        let gw = attention::mha_backward_streaming(&q, &k, &v, &dout,
                                                   &want.lse, &p, 8, 8,
                                                   &Scalar);
        for threads in [2usize, 8] {
            let be = Blocked::new(threads);
            let got = attention::mha_forward_streaming(&q, &k, &v, &p,
                                                       8, 8, &be);
            assert_eq!(got.output.data(), want.output.data(),
                       "fwd output bits (threads={threads}, {:?})",
                       p.mask);
            assert_eq!(got.lse.data(), want.lse.data(),
                       "fwd lse bits (threads={threads}, {:?})", p.mask);
            let gb = attention::mha_backward_streaming(&q, &k, &v, &dout,
                                                       &got.lse, &p, 8, 8,
                                                       &be);
            for (g, w, nm) in [(&gb.dq, &gw.dq, "dq"),
                               (&gb.dk, &gw.dk, "dk"),
                               (&gb.dv, &gw.dv, "dv")] {
                assert_eq!(g.data(), w.data(),
                           "bwd {nm} bits (threads={threads}, {:?})",
                           p.mask);
            }
        }
    }
}
