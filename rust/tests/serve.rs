//! Integration: the `spark serve` continuous-batching layer.
//!
//! Pins the three serving guarantees end to end, at soak scale:
//!
//! 1. **Batching-independent identity** — every request's decode
//!    fingerprint equals the non-batched single-request oracle,
//!    bitwise, under admission reordering and mid-step eviction.
//! 2. **Resource hygiene** — the paged KV-cache free list is fully
//!    restored after the drain (zero block leaks at 1000 requests).
//! 3. **Transport transparency** — the TCP front-end returns the same
//!    fingerprints over a real socket that the scheduler computes
//!    in-process.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use sparkattention::coordinator::serve::{
    single_request_fingerprint, Scheduler, ServeConfig,
};
use sparkattention::coordinator::{Request, TcpServer};
use sparkattention::exec::ExecOptions;
use sparkattention::jsonio;
use sparkattention::tensor::Rng;

/// A deliberately starved pool: `max_batch` full-length sequences need
/// `4 · 4 = 16` blocks against a pool of 6, so the soak run must evict
/// (while a lone sequence still fits: `16 / 4 = 4 ≤ 6`).
fn pressure_cfg() -> ServeConfig {
    ServeConfig {
        heads: 2,
        d: 8,
        block_tokens: 4,
        pool_blocks: 6,
        max_batch: 4,
        max_gen_len: 16,
        exec: ExecOptions::scalar(),
        ..ServeConfig::default()
    }
}

/// Reconstruct the `(seed, gen_len)` that `run_synthetic` assigns to
/// request `i` — seeds are drawn sequentially from `Rng::new(base)`.
fn synthetic_requests(n: usize, base_seed: u64, max_gen: usize)
                      -> Vec<Request> {
    let mut seeder = Rng::new(base_seed);
    (0..n as u64)
        .map(|i| {
            let seed = seeder.next_u64();
            let gen_len = 1 + (seed % max_gen as u64) as usize;
            Request { id: i, seed, gen_len }
        })
        .collect()
}

#[test]
fn soak_1000_requests_under_pressure() {
    let cfg = pressure_cfg();
    let n = 1000;
    let base_seed = 0xBEE5;
    let mut sched = Scheduler::new(cfg.clone()).expect("scheduler");
    let responses = sched.run_synthetic(n, base_seed).expect("drain");
    assert_eq!(responses.len(), n);

    // The starved pool forced real continuous-batching behaviour:
    // evictions happened, and every admission is visible in metrics.
    assert!(sched.metrics.counter("evicted") > 0,
            "pressure config never evicted — the soak is not \
             exercising the eviction path");
    assert!(sched.metrics.counter("admitted") >= n as u64);
    assert_eq!(sched.metrics.counter("completed"), n as u64);

    // Zero cache-block leaks after the drain.
    assert_eq!(sched.free_blocks(), sched.capacity_blocks());

    // Finite tail latencies over the full population.
    let lat = sched.metrics.series("request_latency").expect("series");
    assert_eq!(lat.count(), n);
    assert!(lat.p50().is_finite() && lat.p99().is_finite(),
            "non-finite latency percentiles: p50 {} p99 {}",
            lat.p50(), lat.p99());

    // Every response — batched, reordered, possibly evicted and
    // retried — carries the bitwise fingerprint of the same request
    // run alone through the non-batched oracle.
    let expected = synthetic_requests(n, base_seed, cfg.max_gen_len);
    let by_id: BTreeMap<u64, _> =
        responses.iter().map(|r| (r.id, r)).collect();
    assert_eq!(by_id.len(), n, "duplicate response ids");
    for req in &expected {
        let r = by_id[&req.id];
        assert_eq!(r.steps, req.gen_len,
                   "request {} ran {} of {} steps", req.id, r.steps,
                   req.gen_len);
        let solo = single_request_fingerprint(&cfg, req)
            .expect("oracle fingerprint");
        assert_eq!(r.fingerprint, solo,
                   "request {} fingerprint diverged from the \
                    single-request path (evictions: {})",
                   req.id, r.evictions);
    }
}

#[test]
fn soak_reruns_are_bitwise_identical() {
    let cfg = pressure_cfg();
    let run = |_: usize| {
        let mut sched = Scheduler::new(cfg.clone()).expect("scheduler");
        sched.run_synthetic(300, 7).expect("drain").iter()
            .map(|r| (r.id, r.ticket, r.fingerprint, r.steps,
                      r.evictions))
            .collect::<Vec<_>>()
    };
    assert_eq!(run(0), run(1),
               "scheduling is keyed on arrival order only — two \
                identical runs must make identical decisions");
}

#[test]
fn tcp_round_trip_matches_single_request_oracle() {
    let cfg = ServeConfig {
        heads: 2,
        d: 4,
        block_tokens: 4,
        pool_blocks: 8,
        max_batch: 4,
        max_gen_len: 12,
        exec: ExecOptions::scalar(),
        ..ServeConfig::default()
    };
    let srv = TcpServer::spawn(cfg.clone(), 0).expect("spawn server");
    let requests = [
        Request { id: 1, seed: 42, gen_len: 6 },
        Request { id: 2, seed: 7, gen_len: 12 },
        Request { id: 3, seed: 42, gen_len: 6 },
    ];

    let stream = TcpStream::connect(("127.0.0.1", srv.port))
        .expect("connect");
    let mut writer = stream.try_clone().expect("clone stream");
    let mut reader = BufReader::new(stream);
    for r in &requests {
        writeln!(writer,
                 "{{\"id\": {}, \"seed\": {}, \"gen_len\": {}}}",
                 r.id, r.seed, r.gen_len)
            .expect("send request");
    }
    writer.flush().expect("flush");

    let mut got: BTreeMap<u64, u64> = BTreeMap::new();
    let mut line = String::new();
    while got.len() < requests.len() {
        line.clear();
        assert!(reader.read_line(&mut line).expect("read response") > 0,
                "server closed early with {} of {} responses",
                got.len(), requests.len());
        let v = jsonio::parse(line.trim()).expect("response json");
        assert!(v.get("error").is_none(), "server error: {line}");
        let id = v.get("id").and_then(|x| x.as_i64()).expect("id")
            as u64;
        let fp = v.get("fingerprint").and_then(|x| x.as_str())
            .expect("fingerprint");
        let fp = u64::from_str_radix(fp, 16).expect("hex fingerprint");
        assert!(got.insert(id, fp).is_none(), "duplicate id {id}");
    }
    drop(writer);
    drop(reader);

    let metrics = srv.stop().expect("server metrics");
    assert_eq!(metrics.counter("completed"), requests.len() as u64);

    for r in &requests {
        let solo = single_request_fingerprint(&cfg, r).expect("oracle");
        assert_eq!(got[&r.id], solo,
                   "request {} fingerprint diverged over TCP", r.id);
    }
    // Same (seed, gen_len) ⟹ same fingerprint, independent of id.
    assert_eq!(got[&1], got[&3]);
}
