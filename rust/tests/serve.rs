//! Integration: the `spark serve` continuous-batching layer.
//!
//! Pins the serving guarantees end to end, at soak scale:
//!
//! 1. **Batching-independent identity** — every request's fingerprint
//!    (prompt phase + decode steps) equals the non-batched
//!    single-request oracle, bitwise, under admission reordering,
//!    mid-step eviction, and mid-*prefill* eviction.
//! 2. **Resource hygiene** — the paged KV-cache free list is fully
//!    restored after the drain (zero block leaks at 1000 requests).
//! 3. **Transport transparency** — the TCP front-end returns the same
//!    fingerprints over a real socket that the scheduler computes
//!    in-process.
//! 4. **Backpressure** — the bounded inbox never grows past its cap;
//!    overflow requests get a named `busy` response, nothing is
//!    silently dropped, and the server's `shed` counter equals the
//!    busy responses the client saw.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use sparkattention::coordinator::serve::{
    single_request_fingerprint, synthetic_requests, Scheduler,
    ServeConfig,
};
use sparkattention::coordinator::{Request, TcpServer};
use sparkattention::exec::ExecOptions;
use sparkattention::jsonio;

/// A deliberately starved pool: `max_batch` full-length sequences need
/// `4 · ceil((8 + 16)/4) = 24` blocks against a pool of 6, so the soak
/// run must evict (while a lone sequence still fits: `24 / 4 = 6 ≤ 6`).
fn pressure_cfg() -> ServeConfig {
    ServeConfig {
        heads: 2,
        d: 8,
        block_tokens: 4,
        pool_blocks: 6,
        max_batch: 4,
        max_gen_len: 16,
        max_prompt_len: 8,
        default_gen_len: 16,
        exec: ExecOptions::scalar(),
        ..ServeConfig::default()
    }
}

#[test]
fn soak_1000_requests_under_pressure() {
    let cfg = pressure_cfg();
    let n = 1000;
    let base_seed = 0xBEE5;
    let mut sched = Scheduler::new(cfg.clone()).expect("scheduler");
    let responses = sched.run_synthetic(n, base_seed).expect("drain");
    assert_eq!(responses.len(), n);

    // The starved pool forced real continuous-batching behaviour:
    // evictions happened — some of them mid-prefill — prompts were
    // actually ingested in chunks, and every admission is visible.
    assert!(sched.metrics.counter("evicted") > 0,
            "pressure config never evicted — the soak is not \
             exercising the eviction path");
    assert!(sched.metrics.counter("evicted_prefill") > 0,
            "no eviction landed mid-prefill — the soak is not \
             exercising prompt restarts");
    assert!(sched.metrics.counter("prefill_chunks") > 0,
            "the mixed workload ingested no prompt chunks");
    assert!(sched.metrics.counter("admitted") >= n as u64);
    assert_eq!(sched.metrics.counter("completed"), n as u64);

    // Zero cache-block leaks after the drain.
    assert_eq!(sched.free_blocks(), sched.capacity_blocks());

    // Finite tail latencies over the full population.
    let lat = sched.metrics.series("request_latency").expect("series");
    assert_eq!(lat.count(), n);
    assert!(lat.p50().is_finite() && lat.p99().is_finite(),
            "non-finite latency percentiles: p50 {} p99 {}",
            lat.p50(), lat.p99());

    // Every response — batched, reordered, possibly evicted and
    // retried mid-prompt — carries the bitwise fingerprint of the
    // same request run alone through the prompt-aware oracle.
    let expected = synthetic_requests(&cfg, n, base_seed);
    assert!(expected.iter().any(|r| r.prompt_len > 0)
                && expected.iter().any(|r| r.prompt_len == 0),
            "soak workload must mix prefill and pure-decode requests");
    let by_id: BTreeMap<u64, _> =
        responses.iter().map(|r| (r.id, r)).collect();
    assert_eq!(by_id.len(), n, "duplicate response ids");
    for req in &expected {
        let r = by_id[&req.id];
        assert_eq!(r.steps, req.gen_len,
                   "request {} ran {} of {} steps", req.id, r.steps,
                   req.gen_len);
        assert_eq!(r.prompt_len, req.prompt_len,
                   "request {} prompt length mismatch", req.id);
        let solo = single_request_fingerprint(&cfg, req)
            .expect("oracle fingerprint");
        assert_eq!(r.fingerprint, solo,
                   "request {} fingerprint diverged from the \
                    single-request path (evictions: {})",
                   req.id, r.evictions);
    }
}

#[test]
fn soak_reruns_are_bitwise_identical() {
    let cfg = pressure_cfg();
    let run = |_: usize| {
        let mut sched = Scheduler::new(cfg.clone()).expect("scheduler");
        sched.run_synthetic(300, 7).expect("drain").iter()
            .map(|r| (r.id, r.ticket, r.fingerprint, r.steps,
                      r.evictions))
            .collect::<Vec<_>>()
    };
    assert_eq!(run(0), run(1),
               "scheduling is keyed on arrival order only — two \
                identical runs must make identical decisions");
}

#[test]
fn tcp_round_trip_matches_single_request_oracle() {
    let cfg = ServeConfig {
        heads: 2,
        d: 4,
        block_tokens: 4,
        pool_blocks: 8,
        max_batch: 4,
        max_gen_len: 12,
        max_prompt_len: 8,
        default_gen_len: 12,
        exec: ExecOptions::scalar(),
        ..ServeConfig::default()
    };
    let srv = TcpServer::spawn(cfg.clone(), 0).expect("spawn server");
    let requests = [
        Request { id: 1, seed: 42, gen_len: 6, prompt_len: 0,
                  prompt_seed: 0 },
        Request { id: 2, seed: 7, gen_len: 12, prompt_len: 0,
                  prompt_seed: 0 },
        Request { id: 3, seed: 42, gen_len: 6, prompt_len: 0,
                  prompt_seed: 0 },
        // a prompted request rides the same socket: 6 tokens is two
        // chunks at block_tokens = 4, the second mid-block
        Request { id: 4, seed: 11, gen_len: 5, prompt_len: 6,
                  prompt_seed: 99 },
    ];

    let stream = TcpStream::connect(("127.0.0.1", srv.port))
        .expect("connect");
    let mut writer = stream.try_clone().expect("clone stream");
    let mut reader = BufReader::new(stream);
    for r in &requests {
        writeln!(writer,
                 "{{\"id\": {}, \"seed\": {}, \"gen_len\": {}, \
                  \"prompt_len\": {}, \"prompt_seed\": {}}}",
                 r.id, r.seed, r.gen_len, r.prompt_len, r.prompt_seed)
            .expect("send request");
    }
    writer.flush().expect("flush");

    let mut got: BTreeMap<u64, (u64, usize)> = BTreeMap::new();
    let mut line = String::new();
    while got.len() < requests.len() {
        line.clear();
        assert!(reader.read_line(&mut line).expect("read response") > 0,
                "server closed early with {} of {} responses",
                got.len(), requests.len());
        let v = jsonio::parse(line.trim()).expect("response json");
        assert!(v.get("error").is_none(), "server error: {line}");
        assert!(v.get("busy").is_none(),
                "unexpected shed under the default inbox cap: {line}");
        let id = v.get("id").and_then(|x| x.as_i64()).expect("id")
            as u64;
        let fp = v.get("fingerprint").and_then(|x| x.as_str())
            .expect("fingerprint");
        let fp = u64::from_str_radix(fp, 16).expect("hex fingerprint");
        let plen = v.get("prompt_len").and_then(|x| x.as_i64())
            .expect("prompt_len") as usize;
        assert!(got.insert(id, (fp, plen)).is_none(),
                "duplicate id {id}");
    }
    drop(writer);
    drop(reader);

    let metrics = srv.stop().expect("server metrics");
    assert_eq!(metrics.counter("completed"), requests.len() as u64);
    assert!(metrics.counter("prefill_chunks") >= 2,
            "the 6-token prompt must have been ingested in chunks");
    assert_eq!(metrics.counter("shed"), 0);

    for r in &requests {
        let solo = single_request_fingerprint(&cfg, r).expect("oracle");
        let (fp, plen) = got[&r.id];
        assert_eq!(fp, solo,
                   "request {} fingerprint diverged over TCP", r.id);
        assert_eq!(plen, r.prompt_len,
                   "request {} prompt_len not echoed", r.id);
    }
    // Same (seed, gen_len, prompt) ⟹ same fingerprint, id-independent.
    assert_eq!(got[&1], got[&3]);
}

#[test]
fn bounded_inbox_sheds_with_busy_and_drops_nothing() {
    // cap 1 against a pipelined burst: the client writes every
    // request before reading a byte, so the burst lands while the
    // serve loop is parked (or mid-step) and the inbox must shed.
    let cfg = ServeConfig {
        heads: 4,
        d: 16,
        block_tokens: 4,
        pool_blocks: 16,
        max_batch: 2,
        max_gen_len: 16,
        max_prompt_len: 8,
        default_gen_len: 16,
        inbox_cap: 1,
        exec: ExecOptions::scalar(),
        ..ServeConfig::default()
    };
    let total: u64 = 200;
    let srv = TcpServer::spawn(cfg.clone(), 0).expect("spawn server");
    let stream = TcpStream::connect(("127.0.0.1", srv.port))
        .expect("connect");
    let mut writer = stream.try_clone().expect("clone stream");
    let mut reader = BufReader::new(stream);
    for id in 0..total {
        writeln!(writer,
                 "{{\"id\": {id}, \"seed\": {id}, \"gen_len\": 16, \
                  \"prompt_len\": 4}}")
            .expect("send request");
    }
    writer.flush().expect("flush");

    // every request is answered exactly once: a fingerprint or a
    // named busy line — never silence, never an error
    let mut completed: BTreeMap<u64, u64> = BTreeMap::new();
    let mut busy: Vec<u64> = Vec::new();
    let mut line = String::new();
    while (completed.len() + busy.len()) < total as usize {
        line.clear();
        assert!(reader.read_line(&mut line).expect("read response") > 0,
                "server closed with {} fingerprints + {} busy of {}",
                completed.len(), busy.len(), total);
        let v = jsonio::parse(line.trim()).expect("response json");
        assert!(v.get("error").is_none(), "server error: {line}");
        let id = v.get("id").and_then(|x| x.as_i64()).expect("id")
            as u64;
        if let Some(b) = v.get("busy") {
            let b = b.as_str().expect("busy is a string");
            assert!(b.contains("inbox full (cap 1)"),
                    "busy response must name the cap: {line}");
            busy.push(id);
        } else {
            let fp = v.get("fingerprint").and_then(|x| x.as_str())
                .expect("fingerprint");
            let fp = u64::from_str_radix(fp, 16).expect("hex");
            assert!(completed.insert(id, fp).is_none(),
                    "duplicate completion for id {id}");
        }
    }
    drop(writer);
    drop(reader);
    let metrics = srv.stop().expect("server metrics");

    assert_eq!(completed.len() + busy.len(), total as usize);
    assert!(!busy.is_empty(),
            "a 200-request pipelined burst against cap 1 must shed");
    assert!(!completed.is_empty(),
            "the first offer against an empty inbox must be accepted");
    assert_eq!(metrics.counter("shed"), busy.len() as u64,
               "server shed counter must equal the busy lines sent");
    assert_eq!(metrics.counter("completed"), completed.len() as u64);

    // completions are still bitwise the single-request oracle
    for (&id, &fp) in &completed {
        let req = Request { id, seed: id, gen_len: 16, prompt_len: 4,
                            prompt_seed: id };
        let solo = single_request_fingerprint(&cfg, &req)
            .expect("oracle");
        assert_eq!(fp, solo,
                   "request {id} fingerprint diverged under shedding");
    }
}
