//! Integration: the full training coordinator over real artifacts.
//!
//! Skipped gracefully when `make artifacts` hasn't run.

use sparkattention::config::TrainConfig;
use sparkattention::coordinator::checkpoint::Checkpoint;
use sparkattention::coordinator::Trainer;
use sparkattention::runtime::Engine;

fn artifact_dir() -> Option<String> {
    let dir = std::env::var("SPARK_ARTIFACTS").unwrap_or_else(
        |_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")));
    std::path::Path::new(&dir).join("manifest.json").exists().then_some(dir)
}

#[test]
fn short_training_run_reduces_loss() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::new(&dir).unwrap();
    if engine.manifest().get("train_step").is_err() {
        eprintln!("skipping: train profile not built");
        return;
    }
    let ckpt_dir = std::env::temp_dir().join("spark-train-test");
    let cfg = TrainConfig {
        artifact_dir: dir,
        steps: 12,
        seed: 3,
        log_every: 0,
        checkpoint_every: 10,
        checkpoint_dir: ckpt_dir.to_string_lossy().into_owned(),
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(&engine, cfg);
    let out = trainer.run().expect("training run");
    assert_eq!(out.losses.len(), 12);
    assert!(out.losses.iter().all(|l| l.is_finite()));
    // 12 Adam steps on the tiny LM reliably cut the loss from ~ln(256).
    assert!(out.first_loss() > 4.5,
            "initial loss should be near ln(256)≈5.55, got {}",
            out.first_loss());
    assert!(out.last_loss() < out.first_loss() - 0.5,
            "loss must decrease: {} → {}", out.first_loss(),
            out.last_loss());
    // checkpoint landed and round-trips
    let ck_path = ckpt_dir.join("step000010.ckpt");
    assert!(ck_path.exists(), "checkpoint file missing");
    let ck = Checkpoint::load(&ck_path).expect("load checkpoint");
    assert_eq!(ck.step, 10);
    assert!(!ck.buffers.is_empty());
    assert!(ck.buffers[0].0.starts_with("p/"));

    // trainer metrics recorded each step
    assert_eq!(trainer.metrics.counter("steps"), 12);
    assert!(trainer.metrics.series("train_step").unwrap().count() == 12);
}

#[test]
fn training_is_deterministic_per_seed() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::new(&dir).unwrap();
    if engine.manifest().get("train_step").is_err() {
        eprintln!("skipping: train profile not built");
        return;
    }
    let run = |seed: u64| {
        let cfg = TrainConfig {
            artifact_dir: dir.clone(),
            steps: 4,
            seed,
            log_every: 0,
            ..TrainConfig::default()
        };
        Trainer::new(&engine, cfg).run().unwrap().losses
    };
    let a = run(11);
    let b = run(11);
    let c = run(12);
    assert_eq!(a, b, "same seed → identical loss trajectory");
    assert_ne!(a, c, "different seed → different trajectory");
}

#[test]
fn lm_init_output_matches_train_step_inputs() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::new(&dir).unwrap();
    let (Ok(init), Ok(step)) = (engine.manifest().get("lm_init"),
                                engine.manifest().get("train_step")) else {
        eprintln!("skipping: train profile not built");
        return;
    };
    // contract: init outputs = the state prefix of train_step's inputs
    assert_eq!(init.outputs.len() + 3, step.inputs.len());
    for (o, i) in init.outputs.iter().zip(&step.inputs) {
        assert_eq!(o.shape, i.shape,
                   "state buffer shape mismatch: {} vs {}", o.name, i.name);
    }
    // and train_step outputs = same state + loss
    assert_eq!(step.outputs.len(), init.outputs.len() + 1);
}
