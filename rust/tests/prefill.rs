//! Prefill/decode equivalence suite: chunked prompt ingestion
//! ([`prefill_chunk`]) must be *bitwise* interchangeable with the
//! streaming forward it re-tiles, and prefill + decode chains must be
//! bitwise-identical to one streaming pass over the concatenated
//! sequence:
//!
//! * block-aligned prompts equal `mha_forward_streaming` with
//!   `block_k = block_tokens` for **every** mask variant, f32 and
//!   simd-mixed,
//! * the finalized outputs are invariant to the chunk schedule,
//! * prefill followed by per-token `decode_step`s equals streaming
//!   over the whole (prompt + generated) sequence for causal-type
//!   masks, including prompts that end mid-block,
//! * ragged prompt lengths the streaming tiling cannot represent
//!   still match the fused oracle to tolerance.

use sparkattention::attention::{decode_step, mha_forward,
                                mha_forward_streaming, prefill_chunk,
                                AttnParams, BlockLayout, Mask,
                                PrefillState};
use sparkattention::exec::{Blocked, ExecOptions, Precision, Scalar};
use sparkattention::tensor::paged::{KvCache, SeqKv};
use sparkattention::tensor::{Rng, Tensor};

/// Masks exercised by the equivalence tests at sequence length `n`
/// (`BlockSparse` only when a 4-wide block grid tiles `n` exactly —
/// its layout is pinned to one sequence length).
fn mask_roster(n: usize) -> Vec<Mask> {
    let mut roster = vec![
        Mask::Dense,
        Mask::Causal,
        Mask::SlidingWindow { w: 1 },
        Mask::SlidingWindow { w: 3 },
        Mask::SlidingWindow { w: n },
    ];
    if n % 4 == 0 {
        roster.push(Mask::BlockSparse {
            layout: BlockLayout::random(n / 4, 4, 30, 7).unwrap(),
        });
    }
    roster
}

/// Masks whose live set for row `i` never reaches past key `i` — the
/// ones for which a prompt's rows are final the moment the prompt is
/// cached, so prefill + decode can chain bitwise into streaming.
fn causal_roster(n: usize) -> Vec<Mask> {
    vec![
        Mask::Causal,
        Mask::SlidingWindow { w: 1 },
        Mask::SlidingWindow { w: 3 },
        Mask::SlidingWindow { w: n },
    ]
}

/// Flattened `(heads·d)` row `t` of a `(heads, n, d)` tensor.
fn flat_row(x: &Tensor, t: usize, heads: usize, d: usize, n: usize)
            -> Vec<f32> {
    let mut row = vec![0.0f32; heads * d];
    for h in 0..heads {
        let base = (h * n + t) * d;
        row[h * d..(h + 1) * d]
            .copy_from_slice(&x.data()[base..base + d]);
    }
    row
}

/// Random `(heads, n, d)` Q/K/V triple.
fn qkv(heads: usize, n: usize, d: usize, seed: u64)
       -> (Tensor, Tensor, Tensor) {
    let mut rng = Rng::new(seed);
    (Tensor::randn(vec![heads, n, d], &mut rng),
     Tensor::randn(vec![heads, n, d], &mut rng),
     Tensor::randn(vec![heads, n, d], &mut rng))
}

/// Ingest the first `sum(chunks)` prompt rows through a fresh paged
/// cache with the given chunk schedule and return the finalized
/// row-major `(out, lse)` plus the cache/sequence for chained decoding.
#[allow(clippy::too_many_arguments)]
fn run_prefill(q: &Tensor, k: &Tensor, v: &Tensor, p: &AttnParams,
               heads: usize, d: usize, n: usize, bt: usize,
               chunks: &[usize], mixed: bool)
               -> (Vec<f32>, Vec<f32>, KvCache, SeqKv) {
    let width = heads * d;
    let prompt: usize = chunks.iter().sum();
    let mut cache =
        KvCache::new(n.div_ceil(bt) + 1, bt, heads, d);
    let mut seq = SeqKv::new();
    let mut st = PrefillState::new(heads, d, prompt);
    let mut done = 0usize;
    for &c in chunks {
        for t in done..done + c {
            cache.append(&mut seq, &flat_row(k, t, heads, d, n),
                         &flat_row(v, t, heads, d, n)).unwrap();
        }
        let mut qc = Vec::with_capacity(c * width);
        for t in done..done + c {
            qc.extend(flat_row(q, t, heads, d, n));
        }
        prefill_chunk(&mut st, &qc, &cache.blocks(&seq), p, mixed);
        done += c;
        assert_eq!(st.rows(), done);
    }
    let mut out = vec![0.0f32; prompt * width];
    let mut lse = vec![0.0f32; prompt * heads];
    st.finalize(&mut out, &mut lse);
    (out, lse, cache, seq)
}

/// Assert prefill's row-major output equals rows `0..rows` of a
/// head-major `(heads, n, d)` streaming result, bitwise.
fn assert_rows_bitwise(out: &[f32], lse: &[f32],
                       want: &sparkattention::attention::ForwardResult,
                       rows: usize, heads: usize, d: usize, n: usize,
                       ctx: &str) {
    for r in 0..rows {
        for h in 0..heads {
            let grow = &out[(r * heads + h) * d
                            ..(r * heads + h + 1) * d];
            let wrow = &want.output.data()
                [(h * n + r) * d..(h * n + r + 1) * d];
            for (i, (a, b)) in grow.iter().zip(wrow).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(),
                           "{ctx}: row {r} head {h} elem {i}: \
                            {a} vs {b}");
            }
            let wl = want.lse.data()[h * n + r];
            assert_eq!(lse[r * heads + h].to_bits(), wl.to_bits(),
                       "{ctx}: lse row {r} head {h}");
        }
    }
}

// Block-aligned prompts: chunked prefill is bitwise-identical to one
// streaming pass over the whole prompt, for every mask variant and
// every block-multiple chunk schedule — and the f32 streaming result
// is backend-invariant, so one prefill output pins them all.
#[test]
fn aligned_prefill_is_bitwise_streaming_every_mask() {
    let (heads, d, n, bt) = (2usize, 4usize, 8usize, 4usize);
    let (q, k, v) = qkv(heads, n, d, 0x9E117);
    for mask in mask_roster(n) {
        let p = AttnParams::with_mask(d, mask).unwrap();
        let want = mha_forward_streaming(&q, &k, &v, &p, bt, bt,
                                         &Scalar);
        for chunks in [vec![4, 4], vec![8]] {
            let (out, lse, _, _) =
                run_prefill(&q, &k, &v, &p, heads, d, n, bt, &chunks,
                            false);
            assert_rows_bitwise(
                &out, &lse, &want, n, heads, d, n,
                &format!("mask {} chunks {chunks:?}",
                         p.mask.label()));
        }
        // cross-backend: the multithreaded f32 backends share the
        // scalar bit pattern, so prefill matches them too
        for threads in [1usize, 3] {
            let be = Blocked::new(threads);
            let wb = mha_forward_streaming(&q, &k, &v, &p, bt, bt,
                                           &be);
            let (out, lse, _, _) =
                run_prefill(&q, &k, &v, &p, heads, d, n, bt,
                            &[bt, bt], false);
            assert_rows_bitwise(
                &out, &lse, &wb, n, heads, d, n,
                &format!("mask {} blocked×{threads}",
                         p.mask.label()));
        }
    }
}

// A second, odd shape (3 heads, d = 5, 2-token blocks) walks the same
// contract so nothing silently specialises to the power-of-two case.
#[test]
fn aligned_prefill_is_bitwise_streaming_odd_shape() {
    let (heads, d, n, bt) = (3usize, 5usize, 6usize, 2usize);
    let (q, k, v) = qkv(heads, n, d, 0x0DD5);
    for mask in mask_roster(n) {
        let p = AttnParams::with_mask(d, mask).unwrap();
        let want = mha_forward_streaming(&q, &k, &v, &p, bt, bt,
                                         &Scalar);
        for chunks in [vec![2, 2, 2], vec![4, 2], vec![6]] {
            let (out, lse, _, _) =
                run_prefill(&q, &k, &v, &p, heads, d, n, bt, &chunks,
                            false);
            assert_rows_bitwise(
                &out, &lse, &want, n, heads, d, n,
                &format!("mask {} chunks {chunks:?}",
                         p.mask.label()));
        }
    }
}

// Mixed precision: prefill's quantize-at-ingest (queries) +
// quantize-at-read (cached K/V) equals streaming's quantize-at-entry
// bitwise, because bf16 quantization is idempotent.
#[test]
fn mixed_prefill_is_bitwise_mixed_streaming() {
    let (heads, d, n, bt) = (2usize, 4usize, 8usize, 4usize);
    let (q, k, v) = qkv(heads, n, d, 0xB16);
    for mask in [Mask::Dense, Mask::Causal,
                 Mask::SlidingWindow { w: 3 }] {
        let p = AttnParams::with_mask(d, mask).unwrap();
        let be = ExecOptions::simd(2, Precision::Mixed).build();
        let want = mha_forward_streaming(&q, &k, &v, &p, bt, bt,
                                         be.as_ref());
        let (out, lse, _, _) =
            run_prefill(&q, &k, &v, &p, heads, d, n, bt, &[4, 4],
                        true);
        assert_rows_bitwise(&out, &lse, &want, n, heads, d, n,
                            &format!("mixed mask {}", p.mask.label()));
    }
}

// The chunk partition moves *when* a row starts its tile walk, never
// the walk itself: every legal schedule (block-multiple chunks plus a
// ragged tail) finalizes to the same bits, for every mask — including
// the non-causal ones whose rows keep folding later chunks.
#[test]
fn prefill_is_chunk_schedule_invariant() {
    let (heads, d, bt) = (2usize, 4usize, 4usize);
    for n in [12usize, 10] {
        let (q, k, v) = qkv(heads, n, d, 0x5C4ED);
        let schedules: Vec<Vec<usize>> = if n == 12 {
            vec![vec![4, 4, 4], vec![8, 4], vec![4, 8], vec![12]]
        } else {
            vec![vec![4, 4, 2], vec![8, 2], vec![4, 6], vec![10]]
        };
        for mask in mask_roster(n) {
            let p = AttnParams::with_mask(d, mask).unwrap();
            let (base_out, base_lse, _, _) =
                run_prefill(&q, &k, &v, &p, heads, d, n, bt,
                            &schedules[0], false);
            for sched in &schedules[1..] {
                let (out, lse, _, _) =
                    run_prefill(&q, &k, &v, &p, heads, d, n, bt,
                                sched, false);
                assert!(out.iter().zip(&base_out)
                            .all(|(a, b)| a.to_bits() == b.to_bits())
                        && lse.iter().zip(&base_lse)
                            .all(|(a, b)| a.to_bits() == b.to_bits()),
                        "mask {} n {n}: schedule {sched:?} diverged \
                         from {:?}", p.mask.label(), schedules[0]);
            }
        }
    }
}

// Ragged prompt lengths (a partial tail block) are outside the
// streaming tiling entirely; prefill still matches the fused oracle
// to tolerance for dense and sparse masks alike.
#[test]
fn ragged_prefill_matches_fused_oracle() {
    let (heads, d, bt) = (2usize, 4usize, 4usize);
    for n in [5usize, 7, 10] {
        let (q, k, v) = qkv(heads, n, d, 0x4A66ED);
        for mask in [Mask::Dense, Mask::Causal,
                     Mask::SlidingWindow { w: 3 }] {
            let p = AttnParams::with_mask(d, mask).unwrap();
            let want = mha_forward(&q, &k, &v, &p, &Scalar);
            let mut sched = vec![bt; n / bt];
            if n % bt != 0 {
                sched.push(n % bt);
            }
            let (out, _, _, _) =
                run_prefill(&q, &k, &v, &p, heads, d, n, bt, &sched,
                            false);
            for r in 0..n {
                for h in 0..heads {
                    let grow = &out[(r * heads + h) * d
                                    ..(r * heads + h + 1) * d];
                    let wrow = &want.output.data()
                        [(h * n + r) * d..(h * n + r + 1) * d];
                    for (a, b) in grow.iter().zip(wrow) {
                        assert!((a - b).abs() < 1e-5,
                                "mask {} n {n} row {r} head {h}: \
                                 {a} vs {b}", p.mask.label());
                    }
                }
            }
        }
    }
}

// The serving contract end to end: chunked prefill of the prompt, then
// one `decode_step` per generated token, is bitwise-identical to a
// single streaming pass over the concatenated sequence — for every
// causal-type mask, prompts both block-aligned and mid-block, and
// every chunk schedule.  (A prompt row only sees keys `≤` its own
// position, so its finalized value cannot change once cached; masked
// tail keys are exact no-ops in the online update.)
#[test]
fn prefill_then_decode_chain_is_bitwise_streaming() {
    let (heads, d, n, bt) = (2usize, 4usize, 12usize, 4usize);
    let width = heads * d;
    let (q, k, v) = qkv(heads, n, d, 0xC4A1);
    for mask in causal_roster(n) {
        let p = AttnParams::with_mask(d, mask).unwrap();
        let want = mha_forward_streaming(&q, &k, &v, &p, bt, bt,
                                         &Scalar);
        // prompt 8 is block-aligned; 6 ends mid-block
        for (prompt, chunks) in
            [(8usize, vec![4usize, 4]), (6, vec![4, 2])]
        {
            let (out, lse, mut cache, mut seq) =
                run_prefill(&q, &k, &v, &p, heads, d, n, bt, &chunks,
                            false);
            let ctx = format!("mask {} prompt {prompt}",
                              p.mask.label());
            assert_rows_bitwise(&out, &lse, &want, prompt, heads, d,
                                n, &ctx);
            // decode the remaining tokens one cache append at a time
            for pos in prompt..n {
                cache.append(&mut seq, &flat_row(&k, pos, heads, d, n),
                             &flat_row(&v, pos, heads, d, n)).unwrap();
                let mut dout = vec![0.0f32; width];
                let mut dlse = vec![0.0f32; heads];
                decode_step(&flat_row(&q, pos, heads, d, n),
                            &cache.blocks(&seq), heads, d, pos, &p,
                            false, &mut dout, &mut dlse);
                for h in 0..heads {
                    let wrow = &want.output.data()
                        [(h * n + pos) * d..(h * n + pos + 1) * d];
                    for (a, b) in dout[h * d..(h + 1) * d].iter()
                        .zip(wrow)
                    {
                        assert_eq!(a.to_bits(), b.to_bits(),
                                   "{ctx}: decode pos {pos} head {h}");
                    }
                    assert_eq!(dlse[h].to_bits(),
                               want.lse.data()[h * n + pos].to_bits(),
                               "{ctx}: decode lse pos {pos} head {h}");
                }
            }
        }
    }
}

// Fully-masked prompt rows (window 0) finalize to exact zeros with
// the -inf LSE sentinel, matching the streaming contract.
#[test]
fn fully_masked_prefill_rows_are_zero_with_sentinel() {
    let (heads, d, n, bt) = (2usize, 3usize, 4usize, 2usize);
    let (q, k, v) = qkv(heads, n, d, 0x0);
    let p = AttnParams::with_mask(
        d, Mask::SlidingWindow { w: 0 }).unwrap();
    let (out, lse, _, _) =
        run_prefill(&q, &k, &v, &p, heads, d, n, bt, &[2, 2], false);
    assert!(out.iter().all(|x| x.to_bits() == 0));
    assert!(lse.iter().all(|x| *x == f32::NEG_INFINITY));
}
