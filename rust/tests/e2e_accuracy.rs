//! Integration: §4.2.3 accuracy protocol + Fig 12 artifact consistency.

use sparkattention::coordinator::{accuracy_report, harness::HarnessOptions,
                                  fig12_e2e};
use sparkattention::coordinator::inputs::synth_inputs;
use sparkattention::runtime::Engine;

fn engine() -> Option<Engine> {
    let dir = std::env::var("SPARK_ARTIFACTS").unwrap_or_else(
        |_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")));
    std::path::Path::new(&dir).join("manifest.json").exists()
        .then(|| Engine::new(dir).expect("engine"))
}

#[test]
fn accuracy_within_paper_band() {
    let Some(eng) = engine() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let rows = accuracy_report(&eng).expect("accuracy report");
    assert!(!rows.is_empty(), "accuracy profile artifacts missing");
    for r in &rows {
        // The paper reports ≤ 0.76% average relative error for its least
        // precise variant; bf16 has less mantissa than fp16, so grant a
        // proportionally wider band — but catastrophic error means the
        // kernel is wrong.
        assert!(r.mean_rel_err < 0.05,
                "{}: mean rel err {:.4}% too high", r.name,
                r.mean_rel_err * 100.0);
        assert!(r.mean_abs_err < 0.02,
                "{}: mean abs err {} too high", r.name, r.mean_abs_err);
    }
    // FP32-ACC must beat BF16-ACC on average (the paper's §4.2.1 claim).
    let avg = |needle: &str| {
        let v: Vec<f64> = rows.iter()
            .filter(|r| r.name.contains(needle) && !r.name.contains('/'))
            .map(|r| r.mean_rel_err).collect();
        v.iter().sum::<f64>() / v.len().max(1) as f64
    };
    let f32acc = avg("fused_f32");
    let bf16acc = avg("fused_bf16");
    assert!(f32acc <= bf16acc * 1.5 + 1e-6,
            "f32-ACC ({f32acc}) should not be much worse than bf16-ACC \
             ({bf16acc})");
}

#[test]
fn encoder_variants_agree_numerically() {
    let Some(eng) = engine() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    // same (n, d_model, heads) triple across impls must agree closely —
    // they compute the same function through different fusion scopes.
    let metas: Vec<_> = eng.manifest().of_kind("encoder_fwd")
        .filter(|m| m.attr_i64("n") == Some(128)
                && m.attr_i64("num_heads") == Some(8)
                && m.attr_f64("dropout") == Some(0.0))
        .cloned().collect();
    if metas.len() < 2 {
        eprintln!("skipping: e2e profile not built");
        return;
    }
    let mut outputs = Vec::new();
    for meta in &metas {
        // synth weights are N(0,1); scale to Xavier-like magnitude so the
        // bf16 FFN stays in a numerically sane regime (like trained nets).
        let mut ins = synth_inputs(meta, 7).unwrap();
        for (hv, spec) in ins.iter_mut().zip(&meta.inputs).skip(2) {
            if let sparkattention::runtime::HostValue::F32 { data, .. } = hv {
                let s = 1.0 / (spec.shape.last().copied().unwrap_or(1) as f32)
                    .sqrt();
                for x in data.iter_mut() {
                    *x = sparkattention::tensor::bf16::quantize(*x * s);
                }
            }
        }
        let out = eng.execute(&meta.name, &ins).unwrap();
        outputs.push((meta.attr_str("impl").unwrap_or("?").to_string(),
                      out[0].as_tensor().unwrap()));
    }
    let (base_name, base) = &outputs[0];
    let scale = base.data().iter().fold(0f32, |a, &x| a.max(x.abs()))
        .max(1e-6);
    for (name, t) in &outputs[1..] {
        let err = base.max_abs_diff(t) / scale;
        assert!(err < 0.05,
                "encoder {base_name} vs {name}: rel err {err} (scale {scale})");
    }
}

#[test]
fn fig12_reports_all_variants() {
    let Some(eng) = engine() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    if eng.manifest().of_kind("encoder_fwd").next().is_none() {
        eprintln!("skipping: e2e profile not built");
        return;
    }
    let opts = HarnessOptions {
        bench: sparkattention::bench::Options { warmup_iters: 0, iters: 1 },
        mem_budget: 8 << 30,
        ..HarnessOptions::default()
    };
    let report = fig12_e2e(&eng, opts).expect("fig12");
    let variants: std::collections::BTreeSet<&str> =
        report.rows.iter().map(|r| r.variant.as_str()).collect();
    assert!(variants.contains("pytorch_jit"));
    assert!(variants.contains("sparkattention"));
    assert!(variants.contains("fastertransformer*"));
    assert!(report.rows.iter().all(|r| r.status == "ok" || r.status == "oom"));
}
