//! Property tests: online-softmax algebra, I/O model, and coordinator
//! invariants over randomized inputs (seeded; see `proptest` module docs).

use sparkattention::attention::{self, AttnParams, BlockLayout, Mask};
use sparkattention::data::Batcher;
use sparkattention::exec::{Backend, Blocked, Precision, Scalar, Simd};
use sparkattention::iomodel::{self, MhaShape};
use sparkattention::proptest::{check, default_cases, Gen, OneOf, USize};
use sparkattention::tensor::{bf16, Rng, Tensor};

/// Random MHA case: shape + blocks + flags.
#[derive(Debug, Clone)]
struct MhaCase {
    bh: usize,
    n: usize,
    d: usize,
    block_q: usize,
    block_k: usize,
    causal: bool,
    seed: u64,
}

struct MhaGen;

impl Gen for MhaGen {
    type Value = MhaCase;

    fn generate(&self, rng: &mut Rng) -> MhaCase {
        let n_choices = OneOf(vec![4usize, 8, 16, 32, 64]);
        let n = n_choices.generate(rng);
        let divisors: Vec<usize> =
            (1..=n).filter(|b| n % b == 0).collect();
        let blocks = OneOf(divisors);
        MhaCase {
            bh: USize { lo: 1, hi: 3 }.generate(rng),
            n,
            d: OneOf(vec![2usize, 4, 8, 16]).generate(rng),
            block_q: blocks.generate(rng),
            block_k: blocks.generate(rng),
            causal: rng.uniform() < 0.5,
            seed: rng.next_u64(),
        }
    }
}

fn qkv(c: &MhaCase) -> (Tensor, Tensor, Tensor) {
    let mut r = Rng::new(c.seed);
    (Tensor::randn(vec![c.bh, c.n, c.d], &mut r),
     Tensor::randn(vec![c.bh, c.n, c.d], &mut r),
     Tensor::randn(vec![c.bh, c.n, c.d], &mut r))
}

/// The paper's Equation-3 claim: block-streamed online softmax computes the
/// same attention as the monolithic softmax, for *any* block partition.
#[test]
fn streaming_equals_oracle_for_any_blocks() {
    check("streaming=oracle", &MhaGen, default_cases(), |c| {
        let (q, k, v) = qkv(&c);
        let p = AttnParams::new(c.d, c.causal).unwrap();
        let a = attention::mha_forward(&q, &k, &v, &p, &Scalar);
        let b = attention::mha_forward_streaming(
            &q, &k, &v, &p, c.block_q, c.block_k, &Scalar);
        let err = a.output.max_abs_diff(&b.output);
        if err > 1e-3 {
            return Err(format!("output err {err} for {c:?}"));
        }
        let lse_err = a.lse.max_abs_diff(&b.lse);
        if lse_err > 1e-3 {
            return Err(format!("lse err {lse_err} for {c:?}"));
        }
        Ok(())
    });
}

/// Attention output rows are convex combinations of V rows (no dropout):
/// each output coordinate is bounded by the min/max of that V column.
#[test]
fn output_within_v_hull() {
    check("output-in-hull", &MhaGen, default_cases(), |c| {
        let (q, k, v) = qkv(&c);
        let p = AttnParams::new(c.d, c.causal).unwrap();
        let o = attention::mha_forward(&q, &k, &v, &p, &Scalar).output;
        for b in 0..c.bh {
            for col in 0..c.d {
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for i in 0..c.n {
                    let x = v.at(&[b, i, col]);
                    lo = lo.min(x);
                    hi = hi.max(x);
                }
                for i in 0..c.n {
                    let x = o.at(&[b, i, col]);
                    if x < lo - 1e-4 || x > hi + 1e-4 {
                        return Err(format!(
                            "o[{b},{i},{col}]={x} outside [{lo},{hi}]"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Causal masking: output row i must not depend on K/V rows > i.
#[test]
fn causal_ignores_future() {
    check("causal-no-future", &MhaGen, default_cases() / 2, |mut c| {
        c.causal = true;
        let (q, k, v) = qkv(&c);
        let p = AttnParams::new(c.d, true).unwrap();
        let o1 = attention::mha_forward(&q, &k, &v, &p, &Scalar).output;
        // perturb the last K/V row; everything before must be unchanged
        let mut k2 = k.clone();
        let mut v2 = v.clone();
        for col in 0..c.d {
            for b in 0..c.bh {
                k2.set(&[b, c.n - 1, col], 9.0);
                v2.set(&[b, c.n - 1, col], -9.0);
            }
        }
        let o2 = attention::mha_forward(&q, &k2, &v2, &p, &Scalar).output;
        for b in 0..c.bh {
            for i in 0..c.n - 1 {
                for col in 0..c.d {
                    let d = (o1.at(&[b, i, col]) - o2.at(&[b, i, col])).abs();
                    if d > 1e-5 {
                        return Err(format!(
                            "row {i} changed by future perturbation ({d})"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Gradient structure: if dO = 0 then all grads are 0.
#[test]
fn zero_cotangent_zero_grads() {
    check("zero-dO", &MhaGen, default_cases() / 2, |c| {
        let (q, k, v) = qkv(&c);
        let p = AttnParams::new(c.d, c.causal).unwrap();
        let dout = Tensor::zeros(vec![c.bh, c.n, c.d]);
        let g = attention::mha_backward(&q, &k, &v, &dout, &p, &Scalar);
        for (nm, t) in [("dq", &g.dq), ("dk", &g.dk), ("dv", &g.dv)] {
            if t.data().iter().any(|&x| x != 0.0) {
                return Err(format!("{nm} nonzero under zero cotangent"));
            }
        }
        Ok(())
    });
}

/// Random *masked* MHA case: every `Mask` variant, with the edge cases
/// the fully-masked-row bugfix exists for — a zero-width window (every
/// row fully masked), a width-1 window (exactly one live element per
/// row), and a hand-built block-sparse layout with one fully-dead
/// block row and one single-live-tile row.
#[derive(Debug, Clone)]
struct MaskedCase {
    bh: usize,
    n: usize,
    d: usize,
    block_q: usize,
    block_k: usize,
    mask: Mask,
    seed: u64,
}

struct MaskedGen;

impl Gen for MaskedGen {
    type Value = MaskedCase;

    fn generate(&self, rng: &mut Rng) -> MaskedCase {
        let n = OneOf(vec![8usize, 16, 32]).generate(rng);
        let divisors: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
        let blocks = OneOf(divisors);
        let mask = match rng.below(6) {
            0 => Mask::Dense,
            1 => Mask::Causal,
            2 => Mask::SlidingWindow { w: 0 },
            3 => Mask::SlidingWindow { w: 1 },
            4 => Mask::SlidingWindow {
                w: USize { lo: 1, hi: n }.generate(rng),
            },
            _ => {
                // 4×4 block grid: row 1 fully dead (empty-row edge),
                // row 2 a single live tile, rest random-ish
                let b = n / 4;
                let mut live = vec![false; 16];
                for (bi, row) in live.chunks_mut(4).enumerate() {
                    match bi {
                        1 => {}
                        2 => row[0] = true,
                        _ => {
                            for (bj, cell) in row.iter_mut().enumerate() {
                                *cell = bj <= bi || rng.uniform() < 0.4;
                            }
                        }
                    }
                }
                Mask::BlockSparse {
                    layout: BlockLayout::new(b, 4, live).unwrap(),
                }
            }
        };
        MaskedCase {
            bh: USize { lo: 1, hi: 2 }.generate(rng),
            n,
            d: OneOf(vec![2usize, 4, 8]).generate(rng),
            block_q: blocks.generate(rng),
            block_k: blocks.generate(rng),
            mask,
            seed: rng.next_u64(),
        }
    }
}

/// Masked streaming ≡ masked oracle for every `Mask` variant, with the
/// fully-masked-row contract (exact-zero output rows, `-inf` LSE
/// sentinel in both paths) and bitwise determinism across the
/// f32 backend roster and thread counts.
#[test]
fn masked_streaming_matches_oracle_across_backends() {
    check("masked-streaming", &MaskedGen, default_cases() / 2, |c| {
        let mut r = Rng::new(c.seed);
        let q = Tensor::randn(vec![c.bh, c.n, c.d], &mut r);
        let k = Tensor::randn(vec![c.bh, c.n, c.d], &mut r);
        let v = Tensor::randn(vec![c.bh, c.n, c.d], &mut r);
        let dout = Tensor::randn(vec![c.bh, c.n, c.d], &mut r);
        let p = AttnParams::with_mask(c.d, c.mask.clone()).unwrap();
        let oracle = attention::mha_forward(&q, &k, &v, &p, &Scalar);
        let want = attention::mha_forward_streaming(
            &q, &k, &v, &p, c.block_q, c.block_k, &Scalar);
        let err = want.output.max_abs_diff(&oracle.output);
        if err > 1e-3 {
            return Err(format!("output err {err} for {c:?}"));
        }
        // per-row contract (element-wise: -inf sentinels poison
        // max_abs_diff, so lse is checked row by row)
        for b in 0..c.bh {
            for i in 0..c.n {
                let row_live = (0..c.n).any(|j| p.mask.live(i, j));
                let (lo, ls) = (oracle.lse.at(&[b, i]),
                                want.lse.at(&[b, i]));
                if row_live {
                    if !lo.is_finite() || (lo - ls).abs() > 1e-3 {
                        return Err(format!(
                            "live row {i}: lse {lo} vs {ls} for {c:?}"));
                    }
                } else {
                    if lo != f32::NEG_INFINITY || ls != f32::NEG_INFINITY {
                        return Err(format!(
                            "masked row {i}: lse {lo}/{ls}, want -inf \
                             for {c:?}"));
                    }
                    for col in 0..c.d {
                        let (a, s) = (oracle.output.at(&[b, i, col]),
                                      want.output.at(&[b, i, col]));
                        if a.to_bits() != 0 || s.to_bits() != 0 {
                            return Err(format!(
                                "masked row {i} output {a}/{s} ≠ +0.0 \
                                 for {c:?}"));
                        }
                    }
                }
            }
        }
        // bitwise determinism across f32 backends and thread counts
        let bwd_s = attention::mha_backward_streaming(
            &q, &k, &v, &dout, &oracle.lse, &p, c.block_q, c.block_k,
            &Scalar);
        for threads in [1usize, 2, 8] {
            let backends: Vec<Box<dyn Backend>> = vec![
                Box::new(Blocked::new(threads)),
                Box::new(Simd::new(threads, Precision::F32)),
            ];
            for be in &backends {
                let got = attention::mha_forward_streaming(
                    &q, &k, &v, &p, c.block_q, c.block_k, be.as_ref());
                if got.output.data() != want.output.data()
                    || got.lse.data() != want.lse.data()
                {
                    return Err(format!(
                        "{} t={threads}: streamed fwd bits differ \
                         for {c:?}", be.name()));
                }
                let bwd = attention::mha_backward_streaming(
                    &q, &k, &v, &dout, &oracle.lse, &p, c.block_q,
                    c.block_k, be.as_ref());
                if bwd.dq.data() != bwd_s.dq.data()
                    || bwd.dk.data() != bwd_s.dk.data()
                    || bwd.dv.data() != bwd_s.dv.data()
                {
                    return Err(format!(
                        "{} t={threads}: streamed bwd bits differ \
                         for {c:?}", be.name()));
                }
            }
        }
        Ok(())
    });
}

/// I/O model invariants: fused traffic ≤ unfused for every shape, and the
/// simulator agrees with the closed form.
#[test]
fn io_model_invariants() {
    struct ShapeGen;
    impl Gen for ShapeGen {
        type Value = (MhaShape, usize);

        fn generate(&self, rng: &mut Rng) -> (MhaShape, usize) {
            let n = OneOf(vec![128usize, 256, 512, 1024]).generate(rng);
            let bq = OneOf(vec![32usize, 64, 128]).generate(rng);
            (MhaShape::new(USize { lo: 1, hi: 32 }.generate(rng), n,
                           OneOf(vec![32usize, 64, 128]).generate(rng)), bq)
        }
    }
    check("io-invariants", &ShapeGen, default_cases(), |(s, bq)| {
        let u = iomodel::analytic_unfused_fwd(s);
        let f = iomodel::analytic_fused_fwd(s);
        if f.total_bytes() >= u.total_bytes() {
            return Err(format!("fused ≥ unfused at {s:?}"));
        }
        let (sim, _) = iomodel::simulate_fused_fwd(s, bq, bq, 16 << 20);
        let ana = iomodel::analytic_fused_fwd_streamed(s, bq);
        if sim.read_bytes != ana.read_bytes
            || sim.write_bytes != ana.write_bytes {
            return Err(format!(
                "simulator {sim:?} != analytic {ana:?} at {s:?} bq={bq}"));
        }
        // masked variants: the skip-aware simulator must agree with the
        // tile-count closed form for every mask, including a zero-width
        // window (all tiles skipped → zero traffic)
        for mask in [Mask::Dense, Mask::Causal,
                     Mask::SlidingWindow { w: s.n / 4 },
                     Mask::SlidingWindow { w: 0 },
                     Mask::BlockSparse {
                         layout: BlockLayout::random(s.n / 4, 4, 30, 7)
                             .unwrap(),
                     }] {
            let (ms, _) = iomodel::simulate_fused_fwd_masked(
                s, &mask, bq, bq, 16 << 20);
            let ma = iomodel::analytic_fused_fwd_masked(s, &mask, bq, bq);
            if ms.read_bytes != ma.read_bytes
                || ms.write_bytes != ma.write_bytes {
                return Err(format!(
                    "masked simulator {ms:?} != analytic {ma:?} at {s:?} \
                     bq={bq} mask={}", mask.label()));
            }
            if mask == (Mask::SlidingWindow { w: 0 })
                && ms.total_bytes() != 0 {
                return Err(format!(
                    "w=0 must produce zero traffic, got {ms:?}"));
            }
        }
        Ok(())
    });
}

/// bf16 quantisation is idempotent and monotone (order-preserving).
#[test]
fn bf16_quantize_properties() {
    struct VecGen;
    impl Gen for VecGen {
        type Value = Vec<f32>;

        fn generate(&self, rng: &mut Rng) -> Vec<f32> {
            (0..64).map(|_| (rng.normal() * 100.0)).collect()
        }
    }
    check("bf16-props", &VecGen, default_cases(), |xs| {
        for &x in &xs {
            let q = bf16::quantize(x);
            if bf16::quantize(q) != q {
                return Err(format!("not idempotent at {x}"));
            }
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let qs: Vec<f32> = sorted.iter().map(|&x| bf16::quantize(x)).collect();
        if qs.windows(2).any(|w| w[0] > w[1]) {
            return Err("quantisation broke ordering".into());
        }
        Ok(())
    });
}

/// Batcher invariants: windows always in-range, contiguous, full coverage
/// of batch shape — the coordinator's data-routing contract.
#[test]
fn batcher_invariants() {
    struct BatchGen;
    impl Gen for BatchGen {
        type Value = (usize, usize, usize, u64);

        fn generate(&self, rng: &mut Rng) -> (usize, usize, usize, u64) {
            let seq = OneOf(vec![4usize, 8, 16]).generate(rng);
            let batch = USize { lo: 1, hi: 4 }.generate(rng);
            let tokens = (seq + 1) * batch * (2 + rng.below(8));
            (tokens, batch, seq, rng.next_u64())
        }
    }
    check("batcher-invariants", &BatchGen, default_cases(),
          |(tokens, batch, seq, seed)| {
        let data: Vec<i32> = (0..tokens as i32).collect();
        let mut b = Batcher::new(data, batch, seq, seed);
        for _ in 0..5 {
            let blk = b.next_batch();
            if blk.len() != batch * (seq + 1) {
                return Err(format!("bad block len {}", blk.len()));
            }
            for row in blk.chunks_exact(seq + 1) {
                if row.windows(2).any(|w| w[1] != w[0] + 1) {
                    return Err("window not contiguous".into());
                }
                if row[0] < 0 || row[seq] as usize >= tokens {
                    return Err("window out of range".into());
                }
            }
        }
        Ok(())
    });
}
