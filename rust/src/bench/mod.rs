//! Criterion-style benchmark harness (no `criterion` offline): warmup +
//! fixed-iteration measurement, exact percentiles, table/CSV/JSON emission.
//!
//! Every paper figure is regenerated through this harness — `cargo bench`
//! binaries and the `spark bench-*` subcommands share it, so the numbers in
//! EXPERIMENTS.md come from one code path.

use std::time::Instant;

use crate::jsonio::{self, Value};
use crate::metrics::Series;

/// Measurement policy.
#[derive(Debug, Clone, Copy)]
pub struct Options {
    /// Unrecorded runs before measurement starts.
    pub warmup_iters: usize,
    /// Recorded runs per configuration.
    pub iters: usize,
}

impl Default for Options {
    fn default() -> Self {
        Options { warmup_iters: 1, iters: 3 }
    }
}

/// One measured configuration (a row of a paper figure).
#[derive(Debug, Clone)]
pub struct Row {
    /// Grouping key, e.g. "d64/causal" (a subplot of Fig 10).
    pub group: String,
    /// Variant name, e.g. "fused_f32acc" / "pytorch_fp16".
    pub variant: String,
    /// X-axis value (sequence length).
    pub x: usize,
    /// Timing stats over the measured iterations (seconds).
    pub time: Series,
    /// Useful-work FLOPs for TFLOP/s derivation (0 = latency-only row).
    pub flops: u64,
    /// Status: "ok", "oom", "ns" (not supported) — Fig 12's cell states.
    pub status: String,
}

impl Row {
    /// Achieved TFLOP/s from the mean time (0 for latency-only rows).
    pub fn tflops(&self) -> f64 {
        let m = self.time.mean();
        if m <= 0.0 || self.flops == 0 {
            0.0
        } else {
            self.flops as f64 / m / 1e12
        }
    }

    /// One row as a JSON object (a `rows` element of the report JSON).
    pub fn to_json(&self) -> Value {
        jsonio::obj(vec![
            ("group", jsonio::s(self.group.clone())),
            ("variant", jsonio::s(self.variant.clone())),
            ("x", jsonio::num(self.x as f64)),
            ("status", jsonio::s(self.status.clone())),
            ("mean_s", jsonio::num(self.time.mean())),
            ("p50_s", jsonio::num(self.time.p50())),
            ("p95_s", jsonio::num(self.time.p95())),
            ("tflops", jsonio::num(self.tflops())),
            ("flops", jsonio::num(self.flops as f64)),
        ])
    }
}

/// Measure a closure: `warmup` unrecorded runs, then `iters` recorded runs.
///
/// The closure returns the *measured* seconds for one iteration (so callers
/// can exclude input staging, e.g. `Engine::execute_timed`), or an `Err`
/// to mark the row failed.
pub fn measure<F>(opts: Options, mut f: F) -> anyhow::Result<Series>
where
    F: FnMut() -> anyhow::Result<f64>,
{
    for _ in 0..opts.warmup_iters {
        f()?;
    }
    let mut s = Series::default();
    for _ in 0..opts.iters {
        s.record(f()?);
    }
    Ok(s)
}

/// Measure wallclock of a closure that doesn't self-time.
pub fn measure_wallclock<F>(opts: Options, mut f: F) -> anyhow::Result<Series>
where
    F: FnMut() -> anyhow::Result<()>,
{
    measure(opts, || {
        let t0 = Instant::now();
        f()?;
        Ok(t0.elapsed().as_secs_f64())
    })
}

/// A figure/table in progress: rows + summary notes + emitters.
#[derive(Debug, Default)]
pub struct Report {
    /// Figure/table heading.
    pub title: String,
    /// Measured configurations.
    pub rows: Vec<Row>,
    /// Free-form `(label, value)` summary lines — speedup summaries,
    /// mixed-vs-f32 accuracy numbers — rendered after the table and
    /// included in the JSON under `"notes"`.
    pub notes: Vec<(String, f64)>,
}

impl Report {
    /// Empty report with a title.
    pub fn new(title: impl Into<String>) -> Self {
        Report { title: title.into(), rows: Vec::new(), notes: Vec::new() }
    }

    /// Append a measured row.
    pub fn push(&mut self, row: Row) {
        self.rows.push(row);
    }

    /// Attach a `(label, value)` summary note.
    pub fn note(&mut self, label: impl Into<String>, value: f64) {
        self.notes.push((label.into(), value));
    }

    /// Human-readable table, grouped like the paper's subplots.
    pub fn table(&self) -> String {
        let mut out = format!("== {} ==\n", self.title);
        let mut groups: Vec<&str> =
            self.rows.iter().map(|r| r.group.as_str()).collect();
        groups.dedup();
        let mut seen = std::collections::BTreeSet::new();
        for g in groups {
            if !seen.insert(g) {
                continue;
            }
            out.push_str(&format!("-- {g} --\n"));
            out.push_str(&format!(
                "{:<22} {:>8} {:>12} {:>12} {:>10}  {}\n",
                "variant", "x", "mean_ms", "p95_ms", "TFLOP/s", "status"));
            for r in self.rows.iter().filter(|r| r.group == g) {
                out.push_str(&format!(
                    "{:<22} {:>8} {:>12.3} {:>12.3} {:>10.3}  {}\n",
                    r.variant, r.x, r.time.mean() * 1e3,
                    r.time.p95() * 1e3, r.tflops(), r.status));
            }
        }
        if !self.notes.is_empty() {
            out.push_str("-- notes --\n");
            for (label, value) in &self.notes {
                out.push_str(&format!("{label:<52} {value:.6}\n"));
            }
        }
        out
    }

    /// Per-x speedup of `variant` over `baseline` within each group.
    pub fn speedups(&self, variant: &str, baseline: &str)
                    -> Vec<(String, usize, f64)> {
        let mut out = Vec::new();
        for r in self.rows.iter().filter(|r| r.variant == variant
                                         && r.status == "ok") {
            if let Some(b) = self.rows.iter().find(|b| {
                b.group == r.group && b.x == r.x && b.variant == baseline
                    && b.status == "ok"
            }) {
                let m = r.time.mean();
                if m > 0.0 {
                    out.push((r.group.clone(), r.x, b.time.mean() / m));
                }
            }
        }
        out
    }

    /// Mean/max speedup summary (the paper's "average X× (up to Y×)").
    pub fn speedup_summary(&self, variant: &str, baseline: &str)
                           -> Option<(f64, f64)> {
        let sp = self.speedups(variant, baseline);
        if sp.is_empty() {
            return None;
        }
        let mean = sp.iter().map(|(_, _, s)| s).sum::<f64>() / sp.len() as f64;
        let max = sp.iter().map(|(_, _, s)| *s).fold(0.0, f64::max);
        Some((mean, max))
    }

    /// Whole report as a JSON value (what `emit` writes to disk).
    pub fn to_json(&self) -> Value {
        jsonio::obj(vec![
            ("title", jsonio::s(self.title.clone())),
            ("rows", Value::Arr(self.rows.iter().map(Row::to_json)
                                .collect())),
            ("notes", Value::Arr(self.notes.iter().map(|(label, value)| {
                jsonio::obj(vec![
                    ("label", jsonio::s(label.clone())),
                    ("value", jsonio::num(*value)),
                ])
            }).collect())),
        ])
    }

    /// Rows as CSV (one header + one line per row; notes are omitted).
    pub fn csv(&self) -> String {
        let mut out = String::from(
            "group,variant,x,status,mean_s,p50_s,p95_s,tflops\n");
        for r in &self.rows {
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{}\n",
                r.group, r.variant, r.x, r.status, r.time.mean(),
                r.time.p50(), r.time.p95(), r.tflops()));
        }
        out
    }

    /// Write JSON (and return the table) — the standard bench epilogue.
    pub fn emit(&self, json_path: Option<&str>) -> anyhow::Result<String> {
        if let Some(p) = json_path {
            std::fs::write(p, jsonio::to_string(&self.to_json()))?;
        }
        Ok(self.table())
    }
}

/// Convenience: a skipped row (OOM / not-supported), zero timings.
pub fn skipped_row(group: &str, variant: &str, x: usize, status: &str)
                   -> Row {
    Row {
        group: group.into(),
        variant: variant.into(),
        x,
        time: Series::default(),
        flops: 0,
        status: status.into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(group: &str, variant: &str, x: usize, secs: f64, flops: u64)
           -> Row {
        let mut time = Series::default();
        time.record(secs);
        Row { group: group.into(), variant: variant.into(), x, time, flops,
              status: "ok".into() }
    }

    #[test]
    fn measure_counts_iters() {
        let mut calls = 0;
        let s = measure(Options { warmup_iters: 2, iters: 5 }, || {
            calls += 1;
            Ok(0.001)
        }).unwrap();
        assert_eq!(calls, 7);
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn measure_propagates_errors() {
        let r = measure(Options::default(), || {
            anyhow::bail!("boom")
        });
        assert!(r.is_err());
    }

    #[test]
    fn tflops_derivation() {
        let r = row("g", "v", 1, 0.5, 1_000_000_000_000);
        assert!((r.tflops() - 2.0).abs() < 1e-9);
        assert_eq!(skipped_row("g", "v", 1, "oom").tflops(), 0.0);
    }

    #[test]
    fn speedups_align_group_and_x() {
        let mut rep = Report::new("t");
        rep.push(row("d64", "ours", 512, 1.0, 0));
        rep.push(row("d64", "base", 512, 4.0, 0));
        rep.push(row("d64", "ours", 1024, 1.0, 0));
        rep.push(row("d64", "base", 1024, 8.0, 0));
        rep.push(row("d128", "ours", 512, 1.0, 0)); // no baseline → skipped
        let sp = rep.speedups("ours", "base");
        assert_eq!(sp.len(), 2);
        let (mean, max) = rep.speedup_summary("ours", "base").unwrap();
        assert!((mean - 6.0).abs() < 1e-9);
        assert!((max - 8.0).abs() < 1e-9);
    }

    #[test]
    fn oom_rows_excluded_from_speedups() {
        let mut rep = Report::new("t");
        rep.push(row("g", "ours", 512, 1.0, 0));
        rep.push(skipped_row("g", "base", 512, "oom"));
        assert!(rep.speedup_summary("ours", "base").is_none());
    }

    #[test]
    fn emitters_contain_rows() {
        let mut rep = Report::new("Fig X");
        rep.push(row("d64", "fused", 256, 0.002, 1 << 30));
        let table = rep.table();
        assert!(table.contains("Fig X"));
        assert!(table.contains("fused"));
        assert!(!table.contains("-- notes --"), "no notes section yet");
        let csv = rep.csv();
        assert!(csv.lines().count() == 2);
        let j = rep.to_json();
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn notes_render_and_serialize() {
        let mut rep = Report::new("Fig Y");
        rep.push(row("g", "v", 1, 0.5, 0));
        rep.note("speedup simd_t8 vs scalar (mean)", 2.5);
        rep.note("simd_t8_mixed vs f32 max_ulp", 12345.0);
        let table = rep.table();
        assert!(table.contains("-- notes --"));
        assert!(table.contains("speedup simd_t8 vs scalar"));
        let j = rep.to_json();
        let notes = j.get("notes").unwrap().as_arr().unwrap();
        assert_eq!(notes.len(), 2);
        assert_eq!(notes[0].get("label").unwrap().as_str().unwrap(),
                   "speedup simd_t8 vs scalar (mean)");
        assert_eq!(notes[1].get("value").unwrap().as_f64().unwrap(),
                   12345.0);
    }
}
