//! `spark` — the SparkAttention coordinator CLI.
//!
//! Subcommands map 1:1 to the paper's evaluation (DESIGN.md §5):
//!
//! ```text
//! spark train              train the LM end-to-end (E7)
//! spark serve              continuous-batching inference server
//! spark load               load generator against a running server
//! spark bench-forward      Fig 10 sweep (E1)
//! spark bench-backward     Fig 11 sweep (E2)
//! spark bench-e2e          Fig 12 encoder latency (E4)
//! spark bench-host         host attention path: scalar/blocked/simd backends
//! spark tune               autotune (MC, KC) block shapes per GEMM class
//! spark accuracy           §4.2.3 error table (E3)
//! spark io-report          §2.3 HBM traffic claim (E5)
//! spark project            V100-projected Fig 10/11 at paper scale
//! spark inspect-artifacts  manifest + compile stats
//! spark check              static invariant analysis (DESIGN.md §7)
//! ```

use anyhow::{bail, Result};
use log::info;
use sparkattention::attention::MaskSpec;
use sparkattention::bench::Options;
use sparkattention::cli::{Command, Parsed};
use sparkattention::config::TrainConfig;
use sparkattention::coordinator::{self, harness::HarnessOptions, Trainer};
use sparkattention::exec::{self, BackendKind, ExecOptions, Precision};
use sparkattention::jsonio;
use sparkattention::perfmodel::V100;
use sparkattention::runtime::Engine;

fn main() {
    sparkattention::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn top_usage() -> String {
    format!(
        "spark {} — SparkAttention coordinator\n\n\
         commands:\n\
         \x20 train              train the LM on a synthetic corpus (E7)\n\
         \x20 serve              continuous-batching inference server \
         (paged KV-cache)\n\
         \x20 load               drive a running server with synthetic \
         requests\n\
         \x20 bench-forward      Fig 10: MHA-Forward sweep (E1)\n\
         \x20 bench-backward     Fig 11: MHA-Backward sweep (E2)\n\
         \x20 bench-e2e          Fig 12: encoder-forward latency (E4)\n\
         \x20 bench-host         host attention: exec-backend comparison\n\
         \x20 tune               autotune (MC, KC) block shapes per GEMM \
         class\n\
         \x20 accuracy           §4.2.3 accuracy table (E3)\n\
         \x20 io-report          §2.3 HBM traffic model (E5)\n\
         \x20 project            V100-projected figures at paper scale\n\
         \x20 inspect-artifacts  list artifacts + engine stats\n\
         \x20 check              static invariant analysis of the \
         sources\n\n\
         run `spark <command> --help` for flags",
        sparkattention::VERSION)
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        println!("{}", top_usage());
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "serve" => cmd_serve(rest),
        "load" => cmd_load(rest),
        "bench-forward" => cmd_bench(rest, Figure::Forward),
        "bench-backward" => cmd_bench(rest, Figure::Backward),
        "bench-e2e" => cmd_bench(rest, Figure::E2e),
        "bench-host" => cmd_bench_host(rest),
        "tune" => cmd_tune(rest),
        "accuracy" => cmd_accuracy(rest),
        "io-report" => cmd_io_report(rest),
        "project" => cmd_project(rest),
        "inspect-artifacts" => cmd_inspect(rest),
        "check" => cmd_check(rest),
        "--help" | "-h" | "help" => {
            println!("{}", top_usage());
            Ok(())
        }
        "--version" => {
            println!("spark {}", sparkattention::VERSION);
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{}", top_usage()),
    }
}

/// Apply `--backend` / `--threads` / `--precision` overrides on top of
/// a base selection.  `base_backend_explicit` says the base's backend
/// was deliberately chosen (a config file's `[exec] backend` key):
/// `--precision mixed` then never silently overrides it (that stays a
/// `validate` error), while against an unchosen default it implies the
/// simd backend (`ExecOptions::with_precision`).
fn exec_from_flags(p: &Parsed, base: ExecOptions,
                   base_backend_explicit: bool) -> Result<ExecOptions> {
    let mut e = base;
    let backend_explicit =
        base_backend_explicit || p.get("backend").is_some();
    if let Some(b) = p.get("backend") {
        e.kind = BackendKind::parse(b)?;
    }
    if let Some(t) = p.get_usize("threads")? {
        e.threads = t;
    }
    if let Some(pr) = p.get("precision") {
        e = e.with_precision(Precision::parse(pr)?, backend_explicit);
    }
    e.validate()?;
    // commands that declare --tuning-table get the table installed
    // process-wide here (undeclared lookups just return None)
    if let Some(path) = p.get("tuning-table") {
        let n = exec::tune::install_from_path(path)?;
        info!("tuning table {path}: installed {n} entries");
    }
    Ok(e)
}

/// Resolve `--mask` / `--window` into a [`MaskSpec`] override, if any.
/// A bare `--window W` with no `--mask` means `window:W`; `--window 0`
/// is rejected here (a zero-width window masks every key) so the error
/// names the flag, not an internal invariant.
fn mask_from_flags(p: &Parsed) -> Result<Option<MaskSpec>> {
    let window = match p.get_usize("window")? {
        Some(0) => bail!("--window must be ≥ 1 (width 0 would mask \
                          every key)"),
        w => w,
    };
    match (p.get("mask"), window) {
        (Some(text), w) => Ok(Some(MaskSpec::parse(text, w)?)),
        (None, Some(w)) => Ok(Some(MaskSpec::SlidingWindow { w })),
        (None, None) => Ok(None),
    }
}

fn cmd_train(args: &[String]) -> Result<()> {
    let cmd = Command::new("train", "train the LM via the train_step artifact")
        .flag("config", "TOML config path", None)
        .flag("artifacts", "artifact directory", Some("artifacts"))
        .flag("steps", "training steps", None)
        .flag("seed", "run seed", None)
        .flag("checkpoint-every", "steps between checkpoints (0 = off)", None)
        .flag("metrics-out", "write metrics JSON here", None)
        .flag("mask", "attention mask: dense | causal | window[:W] | \
                       block:B[:DENSITY_PCT[:SEED]]", None)
        .flag("window", "sliding-window width (pairs with --mask window)",
              None)
        .flag("backend", "host exec backend: scalar | blocked | simd", None)
        .flag("threads", "host exec worker threads (0 = auto)", None)
        .flag("precision", "simd numeric mode: f32 | mixed \
                            (mixed implies --backend simd)", None)
        .flag("tuning-table", "install a `spark tune` table for the \
                               host backends", None);
    let p = cmd.parse(args)?;
    let (mut cfg, backend_in_config) = match p.get("config") {
        Some(path) => {
            let doc = sparkattention::config::Document::load(path)?;
            let explicit = sparkattention::config::exec_backend_explicit(
                &doc);
            (TrainConfig::from_doc(&doc)?, explicit)
        }
        None => (TrainConfig::default(), false),
    };
    if let Some(dir) = p.get("artifacts") {
        cfg.artifact_dir = dir.to_string();
    }
    if let Some(steps) = p.get_usize("steps")? {
        cfg.steps = steps;
    }
    if let Some(seed) = p.get_usize("seed")? {
        cfg.seed = seed as u64;
    }
    if let Some(ck) = p.get_usize("checkpoint-every")? {
        cfg.checkpoint_every = ck;
    }
    if let Some(m) = p.get("metrics-out") {
        cfg.metrics_out = Some(m.to_string());
    }
    if let Some(spec) = mask_from_flags(&p)? {
        cfg.attn.mask = spec;
    }
    cfg.exec = exec_from_flags(&p, cfg.exec, backend_in_config)?;

    // Training compute runs inside the device artifacts; the host
    // backend serves the surrounding oracle/witness paths.  Exercise the
    // whole backend roster end-to-end up front (pairwise matmul
    // cross-check + the full streaming attention witness) so a broken
    // or diverging backend aborts here, not mid-evaluation.
    exec::self_check(cfg.exec)?;
    sparkattention::attention::witness_self_check(cfg.exec)?;
    sparkattention::attention::configured_mask_self_check(
        cfg.attn.mask, cfg.attn.block_q, cfg.attn.block_k, cfg.exec)?;
    let backend = cfg.exec.build();
    info!("host exec backend {} ({} threads): pairwise matmul self-check \
           and attention witness passed", backend.name(),
          backend.threads());
    info!("attention mask {} (streaming blocks {}×{}): configured-mask \
           witness passed", cfg.attn.mask.label(), cfg.attn.block_q,
          cfg.attn.block_k);

    let engine = Engine::new(&cfg.artifact_dir)?;
    let metrics_out = cfg.metrics_out.clone();
    let mut trainer = Trainer::new(&engine, cfg);
    let outcome = trainer.run()?;
    println!("steps: {}", outcome.steps);
    println!("loss: {:.4} → {:.4} (tail-10 mean {:.4})",
             outcome.first_loss(), outcome.last_loss(),
             outcome.tail_mean(10));
    println!("throughput: {:.0} tokens/s",
             outcome.tokens_per_step as f64 / outcome.mean_step_seconds);
    if let Some(path) = metrics_out {
        std::fs::write(&path,
                       jsonio::to_string(&trainer.metrics.to_json()))?;
        println!("metrics → {path}");
    }
    Ok(())
}

/// Build a `ServeConfig` from the shared serve/load flag set.
fn serve_cfg_from_flags(p: &Parsed)
                        -> Result<coordinator::serve::ServeConfig> {
    let mut cfg = coordinator::serve::ServeConfig {
        heads: p.get_usize("heads")?.unwrap_or(4),
        d: p.get_usize("d")?.unwrap_or(32),
        block_tokens: p.get_usize("block-tokens")?.unwrap_or(16),
        pool_blocks: p.get_usize("blocks")?.unwrap_or(64),
        max_batch: p.get_usize("max-batch")?.unwrap_or(8),
        max_gen_len: p.get_usize("gen-len")?.unwrap_or(64),
        max_prompt_len: p.get_usize("max-prompt-len")?.unwrap_or(64),
        inbox_cap: p.get_usize("inbox-cap")?.unwrap_or(1024),
        ..coordinator::serve::ServeConfig::default()
    };
    // --default-gen-len falls back to the --gen-len ceiling so a plain
    // `spark serve --gen-len N` keeps its PR-9 meaning.
    cfg.default_gen_len = p.get_usize("default-gen-len")?
        .unwrap_or(cfg.max_gen_len);
    if let Some(spec) = mask_from_flags(p)? {
        cfg.mask = spec;
    }
    cfg.exec = exec_from_flags(p, ExecOptions::default(), false)?;
    cfg.validate()?;
    Ok(cfg)
}

/// Print the serving tail-latency summary and fail on non-finite
/// percentiles (a NaN-poisoned latency series is a serving bug, not a
/// reporting detail — the repaired `metrics::Series` keeps the report
/// alive so this check can run at all).
fn serve_latency_summary(metrics: &sparkattention::metrics::Registry)
                         -> Result<()> {
    let Some(lat) = metrics.series("request_latency") else {
        bail!("no request completed: request_latency series is empty");
    };
    let (p50, p99) = (lat.p50(), lat.p99());
    println!("requests: {} completed, {} admitted, {} evicted",
             metrics.counter("completed"), metrics.counter("admitted"),
             metrics.counter("evicted"));
    println!("prefill: {} chunks ingested ({} mid-prefill evictions); \
              inbox shed {}",
             metrics.counter("prefill_chunks"),
             metrics.counter("evicted_prefill"),
             metrics.counter("shed"));
    println!("latency: p50 {:.3} ms, p99 {:.3} ms, max {:.3} ms",
             p50 * 1e3, p99 * 1e3, lat.max() * 1e3);
    if !p50.is_finite() || !p99.is_finite() {
        bail!("non-finite latency percentiles (p50 {p50}, p99 {p99})");
    }
    Ok(())
}

/// `spark serve` — the continuous-batching inference server.  With
/// `--synthetic N` it drives N deterministic requests through the
/// scheduler in-process (the CI smoke path: asserts full completion,
/// finite tail latencies, and zero cache-block leaks); otherwise it
/// listens for line-JSON requests on `--port` until killed.
fn cmd_serve(args: &[String]) -> Result<()> {
    let cmd = Command::new("serve",
                           "continuous-batching inference server")
        .flag("port", "TCP port to listen on (0 = ephemeral)",
              Some("4100"))
        .flag("synthetic", "run N synthetic requests in-process and \
                            exit (0 = serve TCP)", Some("0"))
        .flag("seed", "synthetic workload seed", Some("1"))
        .flag("heads", "attention heads per request", Some("4"))
        .flag("d", "head dimension", Some("32"))
        .flag("block-tokens", "tokens per KV-cache block", Some("16"))
        .flag("blocks", "KV-cache pool size in blocks", Some("64"))
        .flag("max-batch", "max sequences decoding concurrently",
              Some("8"))
        .flag("gen-len", "max decode steps per request", Some("64"))
        .flag("max-prompt-len", "max prompt tokens per request (0 = \
                                 decode-only)", Some("64"))
        .flag("default-gen-len", "gen_len for requests that omit it \
                                  (defaults to --gen-len)", None)
        .flag("inbox-cap", "bounded-inbox high-water mark: queued \
                            requests beyond this are shed with a \
                            `busy` response", Some("1024"))
        .flag("mask", "attention mask: dense | causal | window[:W] | \
                       block:B[:DENSITY_PCT[:SEED]]", None)
        .flag("window", "sliding-window width (pairs with --mask \
                         window)", None)
        .flag("backend", "host exec backend: scalar | blocked | simd",
              None)
        .flag("threads", "host exec worker threads (0 = auto)", None)
        .flag("precision", "simd numeric mode: f32 | mixed (mixed \
                            implies --backend simd)", None)
        .flag("tuning-table", "install a `spark tune` table for the \
                               host backends", None)
        .flag("metrics-out", "write metrics JSON here", None);
    let p = cmd.parse(args)?;
    let cfg = serve_cfg_from_flags(&p)?;
    let n = p.get_usize("synthetic")?.unwrap_or(0);
    if n > 0 {
        let seed = p.get_usize("seed")?.unwrap_or(1) as u64;
        let mut sched = coordinator::serve::Scheduler::new(cfg)?;
        let t = std::time::Instant::now();
        let responses = sched.run_synthetic(n, seed)?;
        let wall = t.elapsed().as_secs_f64();
        let tokens = sched.metrics.counter("decode_tokens");
        println!("synthetic run: {} requests drained in {:.2} s \
                  ({:.0} decode tokens/s)",
                 responses.len(), wall, tokens as f64 / wall);
        serve_latency_summary(&sched.metrics)?;
        println!("cache: {}/{} blocks free after drain (no leaks)",
                 sched.free_blocks(), sched.capacity_blocks());
        if let Some(path) = p.get("metrics-out") {
            std::fs::write(path,
                           jsonio::to_string(&sched.metrics.to_json()))?;
            println!("metrics → {path}");
        }
        return Ok(());
    }
    let port = p.get_usize("port")?.unwrap_or(4100) as u16;
    let srv = coordinator::serve::TcpServer::spawn(cfg, port)?;
    println!("spark serve listening on 127.0.0.1:{}", srv.port);
    println!("send line-JSON requests, e.g. \
              {{\"id\": 1, \"seed\": 7, \"gen_len\": 32, \
              \"prompt_len\": 16}} — or run \
              `spark load --port {}`", srv.port);
    let metrics = srv.join()?;
    if let Some(path) = p.get("metrics-out") {
        std::fs::write(path, jsonio::to_string(&metrics.to_json()))?;
    }
    Ok(())
}

/// `spark load` — the load generator: opens `--connections` sockets to
/// a running `spark serve`, pipelines `--requests` synthetic requests
/// across them, and reports client-side p50/p99 latency + throughput.
fn cmd_load(args: &[String]) -> Result<()> {
    let cmd = Command::new("load",
                           "drive a running server with synthetic \
                            requests")
        .flag("host", "server host", Some("127.0.0.1"))
        .flag("port", "server port", Some("4100"))
        .flag("requests", "total requests to send", Some("1000"))
        .flag("connections", "concurrent connections", Some("8"))
        .flag("gen-len", "decode steps per request", Some("32"))
        .flag("prompt-len", "prompt tokens per request (0 = pure \
                             decode)", Some("0"))
        .flag("seed", "workload seed base", Some("1"));
    let p = cmd.parse(args)?;
    let host = p.get("host").unwrap_or("127.0.0.1").to_string();
    let port = p.get_usize("port")?.unwrap_or(4100) as u16;
    let total = p.get_usize("requests")?.unwrap_or(1000);
    let conns = p.get_usize("connections")?.unwrap_or(8).max(1);
    let gen_len = p.get_usize("gen-len")?.unwrap_or(32);
    let prompt_len = p.get_usize("prompt-len")?.unwrap_or(0);
    let seed = p.get_usize("seed")?.unwrap_or(1) as u64;
    if total == 0 {
        bail!("--requests must be ≥ 1");
    }
    let t_run = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..conns {
        let host = host.clone();
        // connection c owns request ids c, c+conns, c+2·conns, …
        let ids: Vec<u64> = (0..total).skip(c).step_by(conns)
            .map(|i| i as u64).collect();
        handles.push(std::thread::spawn(
            move || -> Result<(Vec<f64>, u64)> {
            use std::io::{BufRead, BufReader, Write};
            if ids.is_empty() {
                return Ok((Vec::new(), 0));
            }
            let stream =
                std::net::TcpStream::connect((host.as_str(), port))?;
            let mut writer = stream.try_clone()?;
            let mut reader = BufReader::new(stream);
            let mut sent = std::collections::BTreeMap::new();
            for &id in &ids {
                writeln!(writer,
                         "{{\"id\": {id}, \"seed\": {}, \
                          \"gen_len\": {gen_len}, \
                          \"prompt_len\": {prompt_len}, \
                          \"prompt_seed\": {}}}",
                         seed.wrapping_add(id),
                         seed.wrapping_add(id).rotate_left(17))?;
                sent.insert(id, std::time::Instant::now());
            }
            writer.flush()?;
            let mut latencies = Vec::with_capacity(ids.len());
            let mut busy = 0u64;
            let mut line = String::new();
            while !sent.is_empty() {
                line.clear();
                if reader.read_line(&mut line)? == 0 {
                    bail!("server closed with {} of {} responses",
                          ids.len() - sent.len(), ids.len());
                }
                let v = jsonio::parse(line.trim()).map_err(
                    |e| anyhow::anyhow!("bad response line: {e}"))?;
                if let Some(err) = v.get("error") {
                    bail!("server error: {:?}", err.as_str());
                }
                let id = v.get("id").and_then(|x| x.as_i64())
                    .ok_or_else(|| anyhow::anyhow!(
                        "response missing id: {line}"))? as u64;
                sent.remove(&id).map_or_else(
                    || Err(anyhow::anyhow!("unexpected response id \
                                            {id}")),
                    |t0| {
                        // a shed request is answered, not completed —
                        // count it, keep it out of the latency series
                        if v.get("busy").is_some() {
                            busy += 1;
                        } else {
                            latencies.push(t0.elapsed().as_secs_f64());
                        }
                        Ok(())
                    })?;
            }
            Ok((latencies, busy))
        }));
    }
    let mut series = sparkattention::metrics::Series::default();
    let mut shed = 0u64;
    for h in handles {
        let (lats, busy) = h.join()
            .map_err(|_| anyhow::anyhow!("load connection panicked"))??;
        for l in lats {
            series.record(l);
        }
        shed += busy;
    }
    let wall = t_run.elapsed().as_secs_f64();
    println!("{} requests over {conns} connections in {:.2} s \
              ({:.1} req/s); {shed} shed by the server's inbox",
             series.count(), wall, series.count() as f64 / wall);
    if series.count() == 0 {
        bail!("every request was shed — raise --inbox-cap on the \
               server or send fewer requests");
    }
    println!("latency: p50 {:.3} ms, p95 {:.3} ms, p99 {:.3} ms, \
              max {:.3} ms",
             series.p50() * 1e3, series.p95() * 1e3,
             series.p99() * 1e3, series.max() * 1e3);
    if !series.p50().is_finite() || !series.p99().is_finite() {
        bail!("non-finite latency percentiles");
    }
    Ok(())
}

enum Figure {
    Forward,
    Backward,
    E2e,
}

fn bench_flags(name: &'static str, about: &'static str) -> Command {
    Command::new(name, about)
        .flag("artifacts", "artifact directory", Some("artifacts"))
        .flag("iters", "measured iterations", Some("3"))
        .flag("warmup", "warmup iterations", Some("1"))
        .flag("mem-budget-gb", "host memory admission budget", Some("8"))
        .flag("json-out", "write JSON report here", None)
        .switch("csv", "also print CSV rows")
}

fn cmd_bench(args: &[String], fig: Figure) -> Result<()> {
    let cmd = match fig {
        Figure::Forward => bench_flags("bench-forward",
                                       "Fig 10: MHA-Forward sweep"),
        Figure::Backward => bench_flags("bench-backward",
                                        "Fig 11: MHA-Backward sweep"),
        Figure::E2e => bench_flags("bench-e2e",
                                   "Fig 12: encoder-forward latency"),
    };
    let p = cmd.parse(args)?;
    let engine = Engine::new(p.get("artifacts").unwrap_or("artifacts"))?;
    let opts = HarnessOptions {
        bench: Options {
            warmup_iters: p.get_usize("warmup")?.unwrap_or(1),
            iters: p.get_usize("iters")?.unwrap_or(3),
        },
        mem_budget: (p.get_usize("mem-budget-gb")?.unwrap_or(8)) << 30,
        // The artifact sweeps execute on the device engine; the host
        // backend only matters for `bench-host` and the bench binaries'
        // host sections, so no --backend/--threads flags here.
        exec: ExecOptions::default(),
        exec_pinned: false,
    };
    let report = match fig {
        Figure::Forward => coordinator::fig10_forward(&engine, opts)?,
        Figure::Backward => coordinator::fig11_backward(&engine, opts)?,
        Figure::E2e => coordinator::fig12_e2e(&engine, opts)?,
    };
    print!("{}", report.emit(p.get("json-out"))?);
    if p.switch("csv") {
        print!("{}", report.csv());
    }
    let pairs: &[(&str, &str)] = match fig {
        Figure::Forward => &[("spark_f32acc", "pytorch_fp16"),
                             ("spark_bf16acc", "pytorch_fp16")],
        Figure::Backward => &[("spark_bf16acc", "pytorch_fp16")],
        Figure::E2e => &[("sparkattention", "pytorch_jit"),
                         ("fastertransformer*", "pytorch_jit")],
    };
    for (v, b) in pairs {
        if let Some((mean, max)) = report.speedup_summary(v, b) {
            println!("speedup {v} vs {b}: avg {mean:.2}× (max {max:.2}×)");
        }
    }
    Ok(())
}

/// `spark bench-host` — the artifact-free figure: the pure-Rust
/// attention path under every execution backend (scalar reference,
/// blocked, simd, simd-mixed) side by side, with a mixed-vs-f32
/// accuracy summary.
fn cmd_bench_host(args: &[String]) -> Result<()> {
    let cmd = Command::new("bench-host",
                           "host attention path: exec-backend comparison")
        .flag("ns", "comma-separated sequence lengths", Some("256,512"))
        .flag("bh", "batch × heads", Some("8"))
        .flag("d", "head dimension", Some("64"))
        .flag("mask", "comma-separated masks: dense | causal | \
                       window[:W] | block:B[:DENSITY_PCT[:SEED]]",
              Some("dense,causal"))
        .flag("window", "sliding-window width for bare `window` specs",
              None)
        .flag("iters", "measured iterations", Some("3"))
        .flag("warmup", "warmup iterations", Some("1"))
        .flag("backend", "pin the figure to scalar + this backend \
                          (scalar | blocked | simd; default: sweep all)",
              None)
        .flag("threads", "host exec worker threads (0 = auto)", None)
        .flag("precision", "simd numeric mode: f32 | mixed (mixed \
                            implies --backend simd; pins like --backend)",
              None)
        .flag("tuning-table", "install a `spark tune` table for the \
                               host backends", None)
        .flag("json-out", "write JSON report here", None)
        .switch("backward", "bench the backward pass instead");
    let p = cmd.parse(args)?;
    let ns = p.get("ns").unwrap_or("256,512").split(',')
        .map(|s| s.trim().parse::<usize>().map_err(
            |_| anyhow::anyhow!("--ns expects integers, got {s:?}")))
        .collect::<Result<Vec<_>>>()?;
    let opts = HarnessOptions {
        bench: Options {
            warmup_iters: p.get_usize("warmup")?.unwrap_or(1),
            iters: p.get_usize("iters")?.unwrap_or(3),
        },
        exec: exec_from_flags(&p, ExecOptions::default(), false)?,
        // an explicit --backend/--precision pins the figure to
        // scalar + that backend; otherwise sweep the full roster
        exec_pinned: p.get("backend").is_some()
            || p.get("precision").is_some(),
        ..HarnessOptions::default()
    };
    let window = match p.get_usize("window")? {
        Some(0) => bail!("--window must be ≥ 1 (width 0 would mask \
                          every key)"),
        w => w,
    };
    let masks = MaskSpec::parse_list(
        p.get("mask").unwrap_or("dense,causal"), window)?;
    if masks.is_empty() {
        bail!("--mask selected no masks");
    }
    let report = coordinator::host_backend_report(
        &ns, p.get_usize("bh")?.unwrap_or(8),
        p.get_usize("d")?.unwrap_or(64), p.switch("backward"), &masks,
        opts)?;
    // speedup + accuracy summaries are part of the report notes
    print!("{}", report.emit(p.get("json-out"))?);
    Ok(())
}

/// `spark tune` — sweep the (MC, KC) candidate grid over the attention
/// layer's GEMM classes (QKᵀ and P·V per sequence length) and write the
/// winners as a tuning table the backends consult when it is installed
/// via `--tuning-table`, `[exec] tuning_table`, or
/// `SPARK_EXEC_TUNING_TABLE`.
fn cmd_tune(args: &[String]) -> Result<()> {
    let cmd = Command::new("tune",
                           "autotune (MC, KC) block shapes per GEMM class")
        .flag("ns", "comma-separated sequence lengths", Some("256,512"))
        .flag("bh", "batch × heads", Some("8"))
        .flag("d", "head dimension", Some("64"))
        .flag("backend", "backend to tune: blocked | simd", Some("simd"))
        .flag("threads", "host exec worker threads (0 = auto)", Some("0"))
        .flag("iters", "measured iterations per candidate", Some("3"))
        .flag("warmup", "warmup iterations per candidate", Some("1"))
        .flag("out", "write the tuning table here",
              Some("bench-results/tuning.json"));
    let p = cmd.parse(args)?;
    let ns = p.get("ns").unwrap_or("256,512").split(',')
        .map(|s| s.trim().parse::<usize>().map_err(
            |_| anyhow::anyhow!("--ns expects integers, got {s:?}")))
        .collect::<Result<Vec<_>>>()?;
    let kind = BackendKind::parse(p.get("backend").unwrap_or("simd"))?;
    if kind == BackendKind::Scalar {
        bail!("the scalar backend has no block parameters to tune \
               (pick --backend blocked or simd)");
    }
    let threads = p.get_usize("threads")?.unwrap_or(0);
    let opts = Options {
        warmup_iters: p.get_usize("warmup")?.unwrap_or(1),
        iters: p.get_usize("iters")?.unwrap_or(3).max(1),
    };
    let candidates = exec::tune::default_candidates();
    let bh = p.get_usize("bh")?.unwrap_or(8);
    let d = p.get_usize("d")?.unwrap_or(64);
    println!("sweeping {} (mc, kc) candidates per GEMM class \
              (backend {}, bh={bh}, d={d})",
             candidates.len(), kind.name());
    let (table, rows) = exec::tune::tune_attention(
        kind, threads, &ns, bh, d, &candidates, opts)?;
    println!("{:<26} {:>9} {:>12} {:>12} {:>8}",
             "class (m, k, n) prec", "best", "best_ms", "default_ms",
             "speedup");
    for r in &rows {
        println!("{:<26} {:>9} {:>12.3} {:>12.3} {:>7.2}×",
                 format!("({}, {}, {}) {}", r.key.m, r.key.k, r.key.n,
                         r.key.precision.name()),
                 format!("{}x{}", r.best.mc, r.best.kc),
                 r.best_s * 1e3, r.default_s * 1e3, r.speedup());
    }
    let out = p.get("out").unwrap_or("bench-results/tuning.json");
    if let Some(dir) = std::path::Path::new(out).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    table.save(out)?;
    let reloaded = exec::tune::TuningTable::load(out)?;
    if reloaded != table {
        bail!("tuning table round-trip mismatch: {out} did not reload \
               to identical block choices");
    }
    println!("tuning table → {out} ({} entries; reload round-trip \
              verified)", table.len());
    println!("enable it with `--tuning-table {out}`, \
              `[exec] tuning_table = \"{out}\"`, or \
              SPARK_EXEC_TUNING_TABLE={out}");
    Ok(())
}

fn cmd_accuracy(args: &[String]) -> Result<()> {
    let cmd = Command::new("accuracy", "§4.2.3 accuracy vs the f32 oracle")
        .flag("artifacts", "artifact directory", Some("artifacts"))
        .flag("json-out", "write JSON rows here", None);
    let p = cmd.parse(args)?;
    let engine = Engine::new(p.get("artifacts").unwrap_or("artifacts"))?;
    let rows = coordinator::accuracy_report(&engine)?;
    print!("{}", coordinator::harness::accuracy_table(&rows));
    if let Some(path) = p.get("json-out") {
        let arr = jsonio::Value::Arr(rows.iter().map(|r| jsonio::obj(vec![
            ("name", jsonio::s(r.name.clone())),
            ("mean_rel_err", jsonio::num(r.mean_rel_err)),
            ("mean_abs_err", jsonio::num(r.mean_abs_err)),
            ("max_abs_err", jsonio::num(r.max_abs_err)),
        ])).collect());
        std::fs::write(path, jsonio::to_string(&arr))?;
    }
    // paper-style summary: averages per variant family
    let avg = |pred: &dyn Fn(&str) -> bool| {
        let v: Vec<&coordinator::harness::AccuracyRow> =
            rows.iter().filter(|r| pred(&r.name)).collect();
        if v.is_empty() {
            (0.0, 0.0)
        } else {
            (v.iter().map(|r| r.mean_rel_err).sum::<f64>() / v.len() as f64,
             v.iter().map(|r| r.mean_abs_err).sum::<f64>() / v.len() as f64)
        }
    };
    let (rel, abs) = avg(&|n| n.contains("fused_f32"));
    println!("\nFP32-ACC forward: avg rel {:.4}%, avg abs {:.6}",
             rel * 100.0, abs);
    let (rel, abs) = avg(&|n| n.contains("fused_bf16") && !n.contains('/'));
    println!("BF16-ACC forward: avg rel {:.4}%, avg abs {:.6}",
             rel * 100.0, abs);
    let (rel, abs) = avg(&|n| n.contains('/'));
    println!("backward (dq/dk/dv): avg rel {:.4}%, avg abs {:.6}",
             rel * 100.0, abs);
    Ok(())
}

fn cmd_io_report(args: &[String]) -> Result<()> {
    let cmd = Command::new("io-report", "§2.3 HBM traffic model");
    cmd.parse(args)?;
    print!("{}", coordinator::io_report(&V100));
    Ok(())
}

fn cmd_project(args: &[String]) -> Result<()> {
    let cmd = Command::new("project",
                           "V100 roofline projection at paper scale")
        .switch("backward", "project the backward pass (Fig 11)")
        .switch("e2e", "project the encoder end-to-end (Fig 12)");
    let p = cmd.parse(args)?;
    if p.switch("e2e") {
        let report = coordinator::projected_fig12(&V100);
        print!("{}", report.table());
        if let Some((mean, max)) =
            report.speedup_summary("sparkattention", "pytorch_jit") {
            println!("projected e2e speedup: avg {mean:.2}× (max {max:.2}×) \
                      [paper: avg 1.80× (max 2.46×)]");
        }
        return Ok(());
    }
    let report = coordinator::projected_fig10(&V100, p.switch("backward"));
    print!("{}", report.table());
    if let Some((mean, max)) =
        report.speedup_summary("spark_projected", "pytorch_projected") {
        println!("projected speedup: avg {mean:.2}× (max {max:.2}×)  \
                  [paper: {}]",
                 if p.switch("backward") {
                     "avg 3.44× (max 7.91×)"
                 } else {
                     "avg 4.55× (max 9.17×)"
                 });
    }
    Ok(())
}

/// `spark check` — run the static invariant analyzer over the repo's
/// own first-party sources (rules and waiver syntax: DESIGN.md §7).
/// Prints every surviving finding and exits non-zero if any exist, so
/// the command doubles as the local mirror of the CI `spark-check`
/// job (`tools/spark_check.rs`).
fn cmd_check(args: &[String]) -> Result<()> {
    let cmd = Command::new("check",
                           "static invariant analysis of the sources")
        .flag("root", "repository checkout to scan", Some("."))
        .switch("list-rules", "print the rule set and exit");
    let p = cmd.parse(args)?;
    if p.switch("list-rules") {
        for r in sparkattention::analysis::RULES {
            println!("{:<16} {}", r.id, r.summary);
        }
        return Ok(());
    }
    let root = std::path::PathBuf::from(p.get("root").unwrap_or("."));
    let report = sparkattention::analysis::check_tree(&root)?;
    for f in &report.findings {
        println!("{f}");
    }
    println!("spark check: {} files scanned, {} findings, {} waived",
             report.files, report.findings.len(), report.waived);
    if !report.findings.is_empty() {
        bail!("spark check: {} invariant violations (waive only with \
               `// spark-check: allow(rule): reason`)",
              report.findings.len());
    }
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let cmd = Command::new("inspect-artifacts", "manifest summary")
        .flag("artifacts", "artifact directory", Some("artifacts"))
        .switch("compile-all", "compile every artifact and time it");
    let p = cmd.parse(args)?;
    let engine = Engine::new(p.get("artifacts").unwrap_or("artifacts"))?;
    println!("platform: {}", engine.platform());
    println!("artifacts: {}", engine.manifest().len());
    let mut by_kind = std::collections::BTreeMap::new();
    for a in engine.manifest().iter() {
        *by_kind.entry(a.kind.clone()).or_insert(0usize) += 1;
    }
    for (k, c) in by_kind {
        println!("  {k:<16} ×{c}");
    }
    if p.switch("compile-all") {
        let names: Vec<String> =
            engine.manifest().iter().map(|a| a.name.clone()).collect();
        for n in &names {
            engine.load(n)?;
        }
        let st = engine.stats();
        println!("compiled {} modules in {:.1} ms total",
                 st.compiles, st.compile_ms);
    }
    Ok(())
}
