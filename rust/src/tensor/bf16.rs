//! Software bfloat16 — the interchange dtype of every attention artifact.
//!
//! The paper's kernels take FP16 inputs; our TPU-style port standardises on
//! bfloat16 (the MXU-native input type).  The PJRT boundary moves raw bf16
//! bytes; the Rust side computes in f32 and converts at the edges with
//! round-to-nearest-even, exactly matching XLA's `convert` semantics so
//! host-side oracles agree bit-for-bit with device-side casts.

/// Upper bound on the relative error of one round-to-nearest-even bf16
/// quantization of a normal f32: half a ulp at 8 significand bits,
/// i.e. 2⁻⁸.  Mixed-precision tolerance derivations (the exec
/// self-check and the property tests) scale from this constant.
pub const EPSILON: f32 = 0.00390625;

/// Convert f32 → bf16 bits with round-to-nearest-even.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Preserve a quiet NaN; avoid collapsing to Inf via rounding.
        return ((bits >> 16) as u16) | 0x0040;
    }
    // Round to nearest even on the truncated 16 bits.
    let round_bit = (bits >> 16) & 1;
    ((bits.wrapping_add(0x7FFF + round_bit)) >> 16) as u16
}

/// Convert bf16 bits → f32 (exact).
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Round-trip an f32 through bf16 (the precision an artifact input has).
#[inline]
pub fn quantize(x: f32) -> f32 {
    bf16_to_f32(f32_to_bf16(x))
}

/// Encode an f32 slice as little-endian bf16 bytes (PJRT literal payload).
pub fn encode(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 2);
    for &x in xs {
        out.extend_from_slice(&f32_to_bf16(x).to_le_bytes());
    }
    out
}

/// Decode little-endian bf16 bytes into f32s.
pub fn decode(bytes: &[u8]) -> Vec<f32> {
    assert!(bytes.len() % 2 == 0, "bf16 payload must be even-length");
    bytes
        .chunks_exact(2)
        .map(|c| bf16_to_f32(u16::from_le_bytes([c[0], c[1]])))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 256.0, -65280.0] {
            assert_eq!(quantize(x), x, "{x} should be bf16-exact");
        }
    }

    #[test]
    fn rounds_to_nearest_even() {
        // 1.0 + 2^-8 is exactly halfway between bf16(1.0) and the next
        // representable value 1.0078125; ties-to-even keeps 1.0.
        let half_ulp = 1.0 + 2f32.powi(-8);
        assert_eq!(quantize(half_ulp), 1.0);
        // Just above the midpoint must round up.
        assert_eq!(quantize(1.0 + 2f32.powi(-8) + 2f32.powi(-12)), 1.0078125);
    }

    #[test]
    fn specials() {
        assert!(quantize(f32::NAN).is_nan());
        assert_eq!(quantize(f32::INFINITY), f32::INFINITY);
        assert_eq!(quantize(f32::NEG_INFINITY), f32::NEG_INFINITY);
        // Large-but-finite must not round to Inf unless it exceeds bf16 max.
        assert!(quantize(3.38e38).is_finite());
        // f32::MAX is beyond bf16 max + ½ulp: rounds to Inf.
        assert_eq!(quantize(f32::MAX), f32::INFINITY);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let xs: Vec<f32> = (0..256).map(|i| (i as f32 - 128.0) * 0.37).collect();
        let decoded = decode(&encode(&xs));
        for (a, b) in xs.iter().zip(&decoded) {
            assert_eq!(quantize(*a), *b);
        }
    }

    #[test]
    fn relative_error_bounded() {
        // bf16 has 8 significand bits → rel err ≤ 2^-8 for normal values.
        assert_eq!(EPSILON, 2f32.powi(-8));
        let mut x = 1.1e-30f32;
        while x < 1.0e30 {
            let q = quantize(x);
            assert!(((q - x) / x).abs() <= EPSILON, "x={x} q={q}");
            x *= 3.7;
        }
    }
}
