//! Deterministic PRNG — substrate for data generation, property tests, and
//! benchmark inputs (no `rand` crate in the offline registry).
//!
//! xoshiro256++ seeded via SplitMix64: fast, well-distributed, and stable
//! across runs so every experiment in EXPERIMENTS.md is reproducible from
//! its recorded seed.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (any u64 works, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire-style, bias negligible for our n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Fill a Vec with standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Zipf-distributed rank in [0, n) with exponent `s` (synthetic-corpus
    /// token distribution; natural text is ≈ Zipf(1)).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF on the normalised harmonic weights would cost O(n);
        // use rejection-free approximation via the integral of x^-s.
        debug_assert!(n > 0);
        if s <= 0.0 {
            return self.below(n);
        }
        let u = self.uniform();
        if (s - 1.0).abs() < 1e-9 {
            let h = ((n + 1) as f64).ln();
            return (((u * h).exp() - 1.0) as usize).min(n - 1);
        }
        let p = 1.0 - s;
        let top = ((n + 1) as f64).powf(p) - 1.0;
        (((u * top + 1.0).powf(1.0 / p) - 1.0) as usize).min(n - 1)
    }

    /// Derive an independent child stream (for per-worker determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range_and_centered() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Rng::new(9);
        let n = 1000;
        let mut counts = vec![0usize; n];
        for _ in 0..50_000 {
            counts[r.zipf(n, 1.0)] += 1;
        }
        // Head rank must dominate the tail decisively under Zipf(1).
        assert!(counts[0] > counts[100] * 5,
                "head={} r100={}", counts[0], counts[100]);
        assert!(counts[0] > 1000);
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(1);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
