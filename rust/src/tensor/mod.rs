//! CPU tensor substrate: shapes, batched linear algebra, dtype conversion.
//!
//! This is not a deep-learning framework — it is the minimal, well-tested
//! host-side tensor the coordinator needs for (a) the pure-Rust attention
//! oracle/baseline in `attention/`, (b) building PJRT literal payloads, and
//! (c) verifying artifact outputs.  Values are held in f32; `bf16` handles
//! the device interchange precision.

pub mod bf16;
pub mod paged;
pub mod rng;

pub use rng::Rng;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from shape + data (length must match the shape product).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} needs {n} elements, got {}",
                   data.len());
        Tensor { shape, data }
    }

    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Constant-filled tensor of the given shape.
    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    /// Standard-normal entries from a deterministic stream.
    pub fn randn(shape: Vec<usize>, rng: &mut Rng) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: rng.normal_vec(n) }
    }

    /// Normal entries quantised to bf16 precision (what a device artifact
    /// actually receives — keeps host oracle and device bit-aligned).
    pub fn randn_bf16(shape: Vec<usize>, rng: &mut Rng) -> Self {
        let mut t = Self::randn(shape, rng);
        for x in &mut t.data {
            *x = bf16::quantize(*x);
        }
        t
    }

    /// Dimension sizes, outermost first.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row-major element storage.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable row-major element storage.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume the tensor, keeping only its element storage.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reshape (same element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {shape:?}", self.shape);
        self.shape = shape;
        self
    }

    /// Row-major linear index.
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    /// Write one element by multi-dimensional index.
    pub fn set(&mut self, idx: &[usize], v: f32) {
        let o = self.offset(idx);
        self.data[o] = v;
    }

    fn offset(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.shape.len());
        let mut o = 0;
        for (i, (&x, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            assert!(x < dim, "index {idx:?} out of bounds {:?} at axis {i}",
                    self.shape);
            o = o * dim + x;
        }
        o
    }

    /// Quantise every element to bf16 precision in place.
    pub fn quantize_bf16(mut self) -> Self {
        for x in &mut self.data {
            *x = bf16::quantize(*x);
        }
        self
    }

    /// Elementwise map.
    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Self {
        for x in &mut self.data {
            *x = f(*x);
        }
        self
    }

    /// Elementwise binary op (shapes must match).
    pub fn zip(mut self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Self {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a = f(*a, *b);
        }
        self
    }

    /// Multiply every element by `s`.
    pub fn scale(self, s: f32) -> Self {
        self.map(|x| x * s)
    }

    /// Elementwise sum (shapes must match).
    pub fn add(self, other: &Tensor) -> Self {
        self.zip(other, |a, b| a + b)
    }

    /// Elementwise difference (shapes must match).
    pub fn sub(self, other: &Tensor) -> Self {
        self.zip(other, |a, b| a - b)
    }

    /// Max |a - b| between two same-shaped tensors.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Mean |a - b|.
    pub fn mean_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        if self.data.is_empty() {
            return 0.0;
        }
        let s: f32 = self.data.iter().zip(&other.data)
            .map(|(a, b)| (a - b).abs()).sum();
        s / self.data.len() as f32
    }

    /// Maximum ULP distance between two same-shaped tensors: f32 bit
    /// patterns mapped to a sign-magnitude integer line (so +0 and −0
    /// coincide and adjacent floats differ by 1), then compared.  The
    /// mixed-vs-f32 accuracy summaries of the bench reports use this —
    /// it is the resolution-independent way to state "how many
    /// representable values apart" two backends landed.  Inputs are
    /// expected to be finite (NaNs order arbitrarily far away).
    pub fn max_ulp_diff(&self, other: &Tensor) -> u64 {
        assert_eq!(self.shape, other.shape);
        fn ordered(x: f32) -> i64 {
            let b = x.to_bits() as i32 as i64;
            if b < 0 { (i32::MIN as i64) - b } else { b }
        }
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (ordered(a) - ordered(b)).unsigned_abs())
            .fold(0, u64::max)
    }

    /// Mean relative error |a−b| / max(|b|, eps) — the paper's §4.2.3 metric
    /// with the reference implementation as `other`.
    pub fn mean_rel_err(&self, other: &Tensor, eps: f32) -> f32 {
        assert_eq!(self.shape, other.shape);
        if self.data.is_empty() {
            return 0.0;
        }
        let s: f32 = self.data.iter().zip(&other.data)
            .map(|(a, b)| (a - b).abs() / b.abs().max(eps)).sum();
        s / self.data.len() as f32
    }
}

/// Batched matmul: (b, m, k) × (b, k, n) → (b, m, n).
///
/// Cache-aware ikj loop order; this is the workhorse of the pure-Rust
/// baseline so it must not be naive-ijk slow.
pub fn batch_matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (ba, m, ka) = dims3(a);
    let (bb, kb, n) = dims3(b);
    assert_eq!(ba, bb, "batch mismatch");
    assert_eq!(ka, kb, "inner dim mismatch");
    let mut out = vec![0.0f32; ba * m * n];
    let ad = a.data();
    let bd = b.data();
    for bi in 0..ba {
        let ao = bi * m * ka;
        let bo = bi * ka * n;
        let oo = bi * m * n;
        for i in 0..m {
            let arow = &ad[ao + i * ka..ao + (i + 1) * ka];
            let orow = &mut out[oo + i * n..oo + (i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &bd[bo + kk * n..bo + (kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
    Tensor::new(vec![ba, m, n], out)
}

/// Batched matmul with B transposed: (b, m, k) × (b, n, k) → (b, m, n).
pub fn batch_matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (ba, m, ka) = dims3(a);
    let (bb, n, kb) = dims3(b);
    assert_eq!(ba, bb, "batch mismatch");
    assert_eq!(ka, kb, "inner dim mismatch");
    let mut out = vec![0.0f32; ba * m * n];
    let ad = a.data();
    let bd = b.data();
    for bi in 0..ba {
        let ao = bi * m * ka;
        let bo = bi * n * ka;
        let oo = bi * m * n;
        for i in 0..m {
            let arow = &ad[ao + i * ka..ao + (i + 1) * ka];
            for j in 0..n {
                let brow = &bd[bo + j * ka..bo + (j + 1) * ka];
                let mut s = 0.0;
                for (x, y) in arow.iter().zip(brow) {
                    s += x * y;
                }
                out[oo + i * n + j] = s;
            }
        }
    }
    Tensor::new(vec![ba, m, n], out)
}

/// Batched matmul with A transposed: (b, k, m) × (b, k, n) → (b, m, n).
pub fn batch_matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (ba, ka, m) = dims3(a);
    let (bb, kb, n) = dims3(b);
    assert_eq!(ba, bb, "batch mismatch");
    assert_eq!(ka, kb, "inner dim mismatch");
    let mut out = vec![0.0f32; ba * m * n];
    let ad = a.data();
    let bd = b.data();
    for bi in 0..ba {
        let ao = bi * ka * m;
        let bo = bi * ka * n;
        let oo = bi * m * n;
        for kk in 0..ka {
            let arow = &ad[ao + kk * m..ao + (kk + 1) * m];
            let brow = &bd[bo + kk * n..bo + (kk + 1) * n];
            for (i, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let orow = &mut out[oo + i * n..oo + (i + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
    Tensor::new(vec![ba, m, n], out)
}

/// Row-wise softmax over the last axis of a (b, m, n) tensor, in place.
pub fn softmax_lastdim(t: &mut Tensor) {
    let shape = t.shape().to_vec();
    let n = *shape.last().expect("softmax needs rank ≥ 1");
    for row in t.data_mut().chunks_exact_mut(n) {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for x in row.iter_mut() {
            *x = (*x - m).exp();
            sum += *x;
        }
        for x in row.iter_mut() {
            *x /= sum;
        }
    }
}

/// Destructure a rank-3 shape (shared with the `exec` backends).
pub(crate) fn dims3(t: &Tensor) -> (usize, usize, usize) {
    match *t.shape() {
        [a, b, c] => (a, b, c),
        ref s => panic!("expected rank-3 tensor, got {s:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], data: &[f32]) -> Tensor {
        Tensor::new(shape.to_vec(), data.to_vec())
    }

    #[test]
    fn construct_and_index() {
        let x = t(&[2, 3], &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(x.at(&[0, 0]), 1.0);
        assert_eq!(x.at(&[1, 2]), 6.0);
        assert_eq!(x.shape(), &[2, 3]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn index_oob_panics() {
        t(&[2, 2], &[0.; 4]).at(&[2, 0]);
    }

    #[test]
    #[should_panic(expected = "needs")]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn matmul_identity() {
        let a = t(&[1, 2, 2], &[1., 2., 3., 4.]);
        let eye = t(&[1, 2, 2], &[1., 0., 0., 1.]);
        assert_eq!(batch_matmul(&a, &eye), a);
    }

    #[test]
    fn matmul_known() {
        let a = t(&[1, 2, 3], &[1., 2., 3., 4., 5., 6.]);
        let b = t(&[1, 3, 2], &[7., 8., 9., 10., 11., 12.]);
        let c = batch_matmul(&a, &b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_batched_independent() {
        let mut r = Rng::new(1);
        let a = Tensor::randn(vec![3, 4, 5], &mut r);
        let b = Tensor::randn(vec![3, 5, 6], &mut r);
        let c = batch_matmul(&a, &b);
        // batch 1 alone must equal the slice-wise product
        let a1 = t(&[1, 4, 5], &a.data()[20..40]);
        let b1 = t(&[1, 5, 6], &b.data()[30..60]);
        let c1 = batch_matmul(&a1, &b1);
        assert_eq!(&c.data()[24..48], c1.data());
    }

    #[test]
    fn nt_matches_explicit_transpose() {
        let mut r = Rng::new(2);
        let a = Tensor::randn(vec![2, 3, 4], &mut r);
        let b = Tensor::randn(vec![2, 5, 4], &mut r);
        let got = batch_matmul_nt(&a, &b);
        // transpose b manually
        let mut bt = Tensor::zeros(vec![2, 4, 5]);
        for bi in 0..2 {
            for i in 0..5 {
                for j in 0..4 {
                    bt.set(&[bi, j, i], b.at(&[bi, i, j]));
                }
            }
        }
        let want = batch_matmul(&a, &bt);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn tn_matches_explicit_transpose() {
        let mut r = Rng::new(3);
        let a = Tensor::randn(vec![2, 4, 3], &mut r);
        let b = Tensor::randn(vec![2, 4, 5], &mut r);
        let got = batch_matmul_tn(&a, &b);
        let mut at = Tensor::zeros(vec![2, 3, 4]);
        for bi in 0..2 {
            for i in 0..4 {
                for j in 0..3 {
                    at.set(&[bi, j, i], a.at(&[bi, i, j]));
                }
            }
        }
        let want = batch_matmul(&at, &b);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut r = Rng::new(4);
        let mut x = Tensor::randn(vec![2, 3, 8], &mut r);
        softmax_lastdim(&mut x);
        for row in x.data().chunks_exact(8) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(row.iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let mut a = t(&[1, 1, 4], &[1., 2., 3., 4.]);
        let mut b = t(&[1, 1, 4], &[101., 102., 103., 104.]);
        softmax_lastdim(&mut a);
        softmax_lastdim(&mut b);
        assert!(a.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn softmax_handles_extremes() {
        let mut x = t(&[1, 1, 3], &[-1e30, 0.0, -1e30]);
        softmax_lastdim(&mut x);
        assert!((x.at(&[0, 0, 1]) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn error_metrics() {
        let a = t(&[1, 4], &[1.0, 2.0, 3.0, 4.0]);
        let b = t(&[1, 4], &[1.1, 2.0, 3.0, 4.0]);
        assert!((a.max_abs_diff(&b) - 0.1).abs() < 1e-6);
        assert!((a.mean_abs_diff(&b) - 0.025).abs() < 1e-6);
        assert!(a.mean_rel_err(&b, 1e-6) > 0.0);
    }

    #[test]
    fn ulp_distance_basics() {
        let a = t(&[3], &[1.0, 0.0, -1.0]);
        assert_eq!(a.max_ulp_diff(&a), 0);
        let b = t(&[3], &[1.0, -0.0, -1.0]);
        assert_eq!(a.max_ulp_diff(&b), 0, "+0 and -0 coincide");
        let next = f32::from_bits(1.0f32.to_bits() + 1);
        let c = t(&[3], &[next, 0.0, -1.0]);
        assert_eq!(a.max_ulp_diff(&c), 1);
        let prev_neg = f32::from_bits((-1.0f32).to_bits() + 1);
        let d = t(&[3], &[1.0, 0.0, prev_neg]);
        assert_eq!(a.max_ulp_diff(&d), 1, "negative side is symmetric");
    }

    #[test]
    fn randn_bf16_is_quantized() {
        let mut r = Rng::new(5);
        let x = Tensor::randn_bf16(vec![64], &mut r);
        for &v in x.data() {
            assert_eq!(v, bf16::quantize(v));
        }
    }
}
