//! Paged KV-cache: a fixed-size block pool over a flat `Tensor` arena.
//!
//! Serving keeps per-sequence key/value history in fixed-size token
//! blocks handed out from one preallocated arena (the vLLM paging idea
//! at host scale): a sequence owns a *block table* — an ordered list of
//! block ids — and appends one token's K/V rows per decode step,
//! allocating a fresh block only at block boundaries.  Freed blocks go
//! back on a LIFO free list, so allocation order is a pure function of
//! the alloc/free history and never of wall-clock or map iteration
//! order — the same scheduler trace always produces the same block
//! placement (this module is inside `tensor/`, so the `det-*` analyzer
//! rules apply in full).
//!
//! Block layout: each block is `2 · block_tokens · width` f32s — the K
//! half then the V half, each half `block_tokens` rows of `width =
//! heads · d` (the `(bh, d)`-flattened row the decode kernel consumes).

use crate::tensor::Tensor;

/// Append failed: the pool has no free block for the incoming token.
/// The cache and sequence are untouched — the caller may evict another
/// sequence and retry, or requeue this one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheFull;

impl std::fmt::Display for CacheFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "kv cache has no free block")
    }
}

/// One sequence's handle into the cache: its block table + token count.
/// Created empty via [`SeqKv::new`]; only [`KvCache`] methods mutate it.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SeqKv {
    /// Ordered block ids; block `i` holds tokens
    /// `[i · block_tokens, (i+1) · block_tokens)` of this sequence.
    blocks: Vec<u32>,
    /// Tokens appended so far.
    len: usize,
}

impl SeqKv {
    /// Empty handle (no blocks, zero tokens).
    pub fn new() -> Self {
        Self::default()
    }

    /// Tokens appended so far.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no tokens have been appended.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of cache blocks this sequence currently owns.
    pub fn block_count(&self) -> usize {
        self.blocks.len()
    }
}

/// Read-only view of one cached block: contiguous K and V row slices
/// plus the token span they cover within the sequence.
#[derive(Debug, Clone, Copy)]
pub struct KvBlockView<'a> {
    /// `tokens · width` key values, token-major.
    pub k: &'a [f32],
    /// `tokens · width` value values, token-major.
    pub v: &'a [f32],
    /// Sequence position of this block's first token.
    pub start: usize,
    /// Valid tokens in this block (= `block_tokens` except the tail).
    pub tokens: usize,
}

/// The paged KV-cache: arena + free list + per-block ownership bits.
#[derive(Debug)]
pub struct KvCache {
    /// Flat arena, shape `[blocks, 2 · block_tokens · width]`.
    arena: Tensor,
    /// LIFO free list.  Seeded so the first pops hand out block 0, 1, …
    /// and a freed block is the next one reused — fully deterministic.
    free: Vec<u32>,
    /// Ownership bit per block; double-free is a caller bug and panics.
    in_use: Vec<bool>,
    block_tokens: usize,
    width: usize,
}

impl KvCache {
    /// Pool of `blocks` blocks of `block_tokens` tokens, each token a
    /// K row + V row of `heads · d` f32s.  All dimensions must be
    /// nonzero (asserted, matching `Tensor::new`'s contract style).
    pub fn new(blocks: usize, block_tokens: usize, heads: usize,
               d: usize) -> Self {
        assert!(blocks > 0 && block_tokens > 0 && heads > 0 && d > 0,
                "kv cache dims must be nonzero: blocks={blocks} \
                 block_tokens={block_tokens} heads={heads} d={d}");
        assert!(blocks <= u32::MAX as usize, "block id overflows u32");
        let width = heads * d;
        KvCache {
            arena: Tensor::zeros(vec![blocks, 2 * block_tokens * width]),
            free: (0..blocks as u32).rev().collect(),
            in_use: vec![false; blocks],
            block_tokens,
            width,
        }
    }

    /// Tokens per block.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Per-token row width (`heads · d`).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Total blocks in the pool.
    pub fn capacity_blocks(&self) -> usize {
        self.in_use.len()
    }

    /// Blocks currently on the free list.  A drained server must see
    /// this return to `capacity_blocks()` — anything less is a leak.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Append one token's K and V rows (each `width` f32s) to `seq`,
    /// allocating a block when `seq.len` crosses a block boundary.
    /// On a full pool returns `Err(CacheFull)` with *nothing* mutated,
    /// so eviction-and-retry replays from a clean state.
    pub fn append(&mut self, seq: &mut SeqKv, k_row: &[f32],
                  v_row: &[f32]) -> Result<(), CacheFull> {
        assert_eq!(k_row.len(), self.width, "k row width mismatch");
        assert_eq!(v_row.len(), self.width, "v row width mismatch");
        if seq.len % self.block_tokens == 0 {
            let Some(&b) = self.free.last() else {
                return Err(CacheFull);
            };
            self.free.pop();
            debug_assert!(!self.in_use[b as usize]);
            self.in_use[b as usize] = true;
            seq.blocks.push(b);
        }
        let b = *seq.blocks.last().expect("block table nonempty") as usize;
        let slot = seq.len % self.block_tokens;
        let half = self.block_tokens * self.width;
        let base = b * 2 * half + slot * self.width;
        let data = self.arena.data_mut();
        data[base..base + self.width].copy_from_slice(k_row);
        let vbase = base + half;
        data[vbase..vbase + self.width].copy_from_slice(v_row);
        seq.len += 1;
        Ok(())
    }

    /// Append a whole chunk of tokens — `rows · width` K values and
    /// the matching V values, token-major — atomically: either every
    /// row lands or `Err(CacheFull)` with *nothing* mutated.  The
    /// block demand is checked up front (unlike repeated [`Self::append`],
    /// which could run out halfway and leave a partial chunk the
    /// caller would have to unwind), so a prefill chunk under cache
    /// pressure is a clean evict-and-retry like any single append.
    pub fn append_rows(&mut self, seq: &mut SeqKv, k_rows: &[f32],
                       v_rows: &[f32]) -> Result<(), CacheFull> {
        assert_eq!(k_rows.len(), v_rows.len(), "k/v chunk mismatch");
        assert!(!k_rows.is_empty() && k_rows.len() % self.width == 0,
                "chunk must be a nonzero multiple of width");
        let rows = k_rows.len() / self.width;
        let need = (seq.len + rows).div_ceil(self.block_tokens)
            - seq.blocks.len();
        if need > self.free.len() {
            return Err(CacheFull);
        }
        for r in 0..rows {
            let w = self.width;
            self.append(seq, &k_rows[r * w..(r + 1) * w],
                        &v_rows[r * w..(r + 1) * w])
                .expect("block demand prechecked");
        }
        Ok(())
    }

    /// Return all of `seq`'s blocks to the free list (reverse table
    /// order, so re-allocating the same sequence reuses the same
    /// blocks in the same order) and reset the handle to empty.
    /// Panics on a block not currently owned (double release).
    pub fn release(&mut self, seq: &mut SeqKv) {
        for &b in seq.blocks.iter().rev() {
            assert!(self.in_use[b as usize],
                    "double free of kv block {b}");
            self.in_use[b as usize] = false;
            self.free.push(b);
        }
        seq.blocks.clear();
        seq.len = 0;
    }

    /// Views over `seq`'s cached tokens in sequence order.  Each view
    /// exposes only the valid prefix of its block (`tokens · width`
    /// values per half), so concatenating the views is exactly the
    /// K/V history of the sequence.
    pub fn blocks<'a>(&'a self, seq: &SeqKv) -> Vec<KvBlockView<'a>> {
        let half = self.block_tokens * self.width;
        let data = self.arena.data();
        seq.blocks.iter().enumerate().map(|(i, &b)| {
            let start = i * self.block_tokens;
            let tokens = (seq.len - start).min(self.block_tokens);
            let base = b as usize * 2 * half;
            KvBlockView {
                k: &data[base..base + tokens * self.width],
                v: &data[base + half..base + half + tokens * self.width],
                start,
                tokens,
            }
        }).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(tag: f32, width: usize) -> Vec<f32> {
        (0..width).map(|i| tag + i as f32 / 100.0).collect()
    }

    #[test]
    fn alloc_is_deterministic_and_lifo_reuse() {
        let mut c = KvCache::new(4, 2, 1, 3);
        let mut a = SeqKv::new();
        let mut b = SeqKv::new();
        // First allocations hand out blocks 0, 1, 2 in order.
        for t in 0..3 {
            c.append(&mut a, &row(t as f32, 3), &row(t as f32, 3))
                .unwrap();
        }
        c.append(&mut b, &row(9.0, 3), &row(9.0, 3)).unwrap();
        assert_eq!(a.block_count(), 2);
        assert_eq!(b.block_count(), 1);
        assert_eq!(c.free_blocks(), 1);
        // Releasing `a` then reallocating an identical sequence reuses
        // exactly the same blocks in the same order.
        let views_before: Vec<usize> =
            c.blocks(&a).iter().map(|v| v.start).collect();
        c.release(&mut a);
        assert_eq!(c.free_blocks(), 3);
        let mut a2 = SeqKv::new();
        for t in 0..3 {
            c.append(&mut a2, &row(t as f32, 3), &row(t as f32, 3))
                .unwrap();
        }
        let views_after: Vec<usize> =
            c.blocks(&a2).iter().map(|v| v.start).collect();
        assert_eq!(views_before, views_after);
        assert_eq!(c.free_blocks(), 1);
    }

    #[test]
    fn append_round_trips_rows_through_views() {
        let width = 4;
        let mut c = KvCache::new(3, 2, 2, 2);
        let mut s = SeqKv::new();
        for t in 0..5 {
            c.append(&mut s, &row(10.0 + t as f32, width),
                     &row(20.0 + t as f32, width)).unwrap();
        }
        assert_eq!(s.len(), 5);
        let views = c.blocks(&s);
        assert_eq!(views.len(), 3);
        assert_eq!(views[2].tokens, 1); // tail block partially filled
        let mut pos = 0usize;
        for v in &views {
            assert_eq!(v.start, pos);
            for t in 0..v.tokens {
                let k = &v.k[t * width..(t + 1) * width];
                let vv = &v.v[t * width..(t + 1) * width];
                assert_eq!(k, &row(10.0 + pos as f32, width)[..]);
                assert_eq!(vv, &row(20.0 + pos as f32, width)[..]);
                pos += 1;
            }
        }
        assert_eq!(pos, 5);
    }

    #[test]
    fn full_pool_errs_without_mutation() {
        let mut c = KvCache::new(1, 2, 1, 2);
        let mut a = SeqKv::new();
        c.append(&mut a, &[1.0, 2.0], &[3.0, 4.0]).unwrap();
        let mut b = SeqKv::new();
        assert_eq!(c.append(&mut b, &[9.0, 9.0], &[9.0, 9.0]),
                   Err(CacheFull));
        assert!(b.is_empty());
        assert_eq!(b.block_count(), 0);
        assert_eq!(c.free_blocks(), 0);
        // Second token of `a` fits in its existing block.
        c.append(&mut a, &[5.0, 6.0], &[7.0, 8.0]).unwrap();
        // Third needs a new block: full again, `a` untouched.
        assert_eq!(c.append(&mut a, &[0.0, 0.0], &[0.0, 0.0]),
                   Err(CacheFull));
        assert_eq!(a.len(), 2);
        // Releasing restores the free list exactly — no leaks.
        c.release(&mut a);
        assert_eq!(c.free_blocks(), c.capacity_blocks());
    }

    #[test]
    fn append_rows_is_all_or_nothing() {
        let width = 2;
        let mut c = KvCache::new(3, 2, 1, 2);
        let mut s = SeqKv::new();
        // 3 tokens in one chunk: spans two blocks, same layout as
        // three single appends.
        let chunk: Vec<f32> = (0..3 * width).map(|i| i as f32).collect();
        c.append_rows(&mut s, &chunk, &chunk).unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s.block_count(), 2);
        let mut c2 = KvCache::new(3, 2, 1, 2);
        let mut s2 = SeqKv::new();
        for t in 0..3 {
            c2.append(&mut s2, &chunk[t * width..(t + 1) * width],
                      &chunk[t * width..(t + 1) * width]).unwrap();
        }
        let a: Vec<Vec<f32>> =
            c.blocks(&s).iter().map(|v| v.k.to_vec()).collect();
        let b: Vec<Vec<f32>> =
            c2.blocks(&s2).iter().map(|v| v.k.to_vec()).collect();
        assert_eq!(a, b);
        // 4 more tokens need 2 fresh blocks but only 1 is left (s has
        // a 1-slot tail): CacheFull, and *nothing* moved — even though
        // 3 of the 4 tokens would have fit.
        let big: Vec<f32> = vec![9.0; 4 * width];
        assert_eq!(c.append_rows(&mut s, &big, &big), Err(CacheFull));
        assert_eq!(s.len(), 3);
        assert_eq!(s.block_count(), 2);
        assert_eq!(c.free_blocks(), 1);
        // A chunk that does fit (1 tail slot + 1 fresh block) lands.
        let ok: Vec<f32> = vec![7.0; 3 * width];
        c.append_rows(&mut s, &ok, &ok).unwrap();
        assert_eq!(s.len(), 6);
        assert_eq!(c.free_blocks(), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_release_panics() {
        let mut c = KvCache::new(2, 1, 1, 1);
        let mut s = SeqKv::new();
        c.append(&mut s, &[1.0], &[2.0]).unwrap();
        let stale = s.clone();
        c.release(&mut s);
        let mut stale = stale;
        c.release(&mut stale);
    }
}
