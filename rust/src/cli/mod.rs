//! Command-line argument parsing (no `clap` in the offline registry).
//!
//! Conventions: `spark <command> [--flag value] [--switch]`.  Flags are
//! declared up front so `--help` is generated and unknown flags are hard
//! errors — silent typo-eating in a benchmark harness corrupts results.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Declared flag (with `--help` metadata).
#[derive(Debug, Clone)]
pub struct FlagSpec {
    /// Flag name without the leading `--`.
    pub name: &'static str,
    /// One-line description shown by `--help`.
    pub help: &'static str,
    /// true = boolean switch; false = takes a value.
    pub is_switch: bool,
    /// Default value substituted when the flag is absent.
    pub default: Option<&'static str>,
}

/// Parsed invocation: flag values + positional arguments.
#[derive(Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    /// Non-flag arguments, in order of appearance.
    pub positional: Vec<String>,
}

impl Parsed {
    /// Value of flag `name` (its default if declared, else None).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    /// Value of flag `name` parsed as an integer (loud parse error).
    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        self.get(name).map(|v| v.parse::<usize>().map_err(
            |_| anyhow!("--{name} expects an integer, got {v:?}"))).transpose()
    }

    /// Value of flag `name` parsed as a number (loud parse error).
    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        self.get(name).map(|v| v.parse::<f64>().map_err(
            |_| anyhow!("--{name} expects a number, got {v:?}"))).transpose()
    }

    /// Whether boolean switch `name` was passed.
    pub fn switch(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }
}

/// A command parser: declared flags + positional arity.
#[derive(Debug)]
pub struct Command {
    /// Subcommand word (`spark <name> …`).
    pub name: &'static str,
    /// One-line description shown in usage.
    pub about: &'static str,
    flags: Vec<FlagSpec>,
}

impl Command {
    /// New command with no declared flags.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, flags: Vec::new() }
    }

    /// Declare a value-taking flag.
    pub fn flag(mut self, name: &'static str, help: &'static str,
                default: Option<&'static str>) -> Self {
        self.flags.push(FlagSpec { name, help, is_switch: false, default });
        self
    }

    /// Declare a boolean switch.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, is_switch: true,
                                   default: None });
        self
    }

    /// Generated `--help` text (command, flags, defaults).
    pub fn usage(&self) -> String {
        let mut s = format!("spark {} — {}\n\nflags:\n", self.name, self.about);
        for f in &self.flags {
            let kind = if f.is_switch { "" } else { " <value>" };
            let dfl = f.default.map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{kind}\n      {}{dfl}\n",
                                f.name, f.help));
        }
        s
    }

    /// Parse `args` (excluding the command word itself).
    pub fn parse(&self, args: &[String]) -> Result<Parsed> {
        let mut out = Parsed::default();
        for f in &self.flags {
            if let Some(d) = f.default {
                out.values.insert(f.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(name) = a.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = self.flags.iter().find(|f| f.name == name)
                    .ok_or_else(|| anyhow!(
                        "unknown flag --{name} for `spark {}`\n\n{}",
                        self.name, self.usage()))?;
                if spec.is_switch {
                    if inline.is_some() {
                        bail!("--{name} is a switch, it takes no value");
                    }
                    out.switches.insert(name.to_string(), true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i).cloned().ok_or_else(|| anyhow!(
                                "--{name} expects a value"))?
                        }
                    };
                    out.values.insert(name.to_string(), v);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("bench-forward", "run the Fig 10 sweep")
            .flag("iters", "measured iterations", Some("3"))
            .flag("artifacts", "artifact directory", Some("artifacts"))
            .switch("json", "emit JSON rows")
    }

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = cmd().parse(&args(&[])).unwrap();
        assert_eq!(p.get("iters"), Some("3"));
        assert!(!p.switch("json"));
    }

    #[test]
    fn values_and_switches() {
        let p = cmd().parse(&args(&["--iters", "7", "--json"])).unwrap();
        assert_eq!(p.get_usize("iters").unwrap(), Some(7));
        assert!(p.switch("json"));
    }

    #[test]
    fn inline_equals_form() {
        let p = cmd().parse(&args(&["--iters=9"])).unwrap();
        assert_eq!(p.get("iters"), Some("9"));
    }

    #[test]
    fn positional_collected() {
        let p = cmd().parse(&args(&["foo", "--iters", "2", "bar"])).unwrap();
        assert_eq!(p.positional, vec!["foo", "bar"]);
    }

    #[test]
    fn unknown_flag_is_error() {
        let e = cmd().parse(&args(&["--wat"])).unwrap_err().to_string();
        assert!(e.contains("unknown flag --wat"));
        assert!(e.contains("flags:"), "error should embed usage");
    }

    #[test]
    fn missing_value_is_error() {
        assert!(cmd().parse(&args(&["--iters"])).is_err());
    }

    #[test]
    fn bad_int_is_error() {
        let p = cmd().parse(&args(&["--iters", "x"])).unwrap();
        assert!(p.get_usize("iters").is_err());
    }

    #[test]
    fn switch_with_value_is_error() {
        assert!(cmd().parse(&args(&["--json=yes"])).is_err());
    }

    #[test]
    fn help_flag_surfaces_usage() {
        let e = cmd().parse(&args(&["--help"])).unwrap_err().to_string();
        assert!(e.contains("bench-forward"));
        assert!(e.contains("--iters"));
    }
}
