//! Typed configuration + a TOML-subset parser (no `toml`/`serde` offline).
//!
//! Supports the subset our configs use: `[section]` headers, `key = value`
//! with string/int/float/bool values, `#` comments, and arrays of scalars.
//! Everything is validated into `TrainConfig` / `BenchConfig` with explicit
//! error messages; defaults mirror the paper's hyperparameters (§4.1).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::attention::MaskSpec;
use crate::exec::{BackendKind, ExecOptions, Precision};

/// A scalar-ish TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Toml {
    /// A double-quoted string.
    Str(String),
    /// A base-10 integer.
    Int(i64),
    /// A floating-point number.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A bracketed array of scalars.
    Arr(Vec<Toml>),
}

impl Toml {
    /// String payload, if this value is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Toml::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Integer payload, if this value is an integer.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Toml::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Numeric payload (floats and integers both qualify).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Toml::Float(f) => Some(*f),
            Toml::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Boolean payload, if this value is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Toml::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: section → key → value ("" = top-level section).
#[derive(Debug, Default, Clone)]
pub struct Document {
    sections: BTreeMap<String, BTreeMap<String, Toml>>,
}

impl Document {
    /// Parse TOML-subset text into sections (hard error with a line
    /// number on anything malformed).
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = Document::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').ok_or_else(|| anyhow!(
                    "line {}: unterminated section header {line:?}",
                    lineno + 1))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| anyhow!(
                "line {} ({}): expected `key = value`, got {line:?}",
                lineno + 1, section_label(&section)))?;
            let value = parse_value(value.trim()).with_context(|| format!(
                "line {} ({}): bad value for key `{}`", lineno + 1,
                section_label(&section), key.trim()))?;
            doc.sections.entry(section.clone()).or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(doc)
    }

    /// Read and parse a config file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref()).with_context(
            || format!("reading config {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Look up `key` in `section` ("" = top level).
    pub fn get(&self, section: &str, key: &str) -> Option<&Toml> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    /// Iterate the section names present in the document.
    pub fn sections(&self) -> impl Iterator<Item = &String> {
        self.sections.keys()
    }

    fn str_or(&self, sec: &str, key: &str, default: &str) -> Result<String> {
        match self.get(sec, key) {
            None => Ok(default.to_string()),
            Some(v) => v.as_str().map(String::from).ok_or_else(
                || anyhow!("[{sec}] {key} must be a string")),
        }
    }

    fn usize_or(&self, sec: &str, key: &str, default: usize) -> Result<usize> {
        match self.get(sec, key) {
            None => Ok(default),
            Some(v) => v.as_i64().filter(|&i| i >= 0).map(|i| i as usize)
                .ok_or_else(|| anyhow!("[{sec}] {key} must be a non-negative \
                                        integer")),
        }
    }

    fn f64_or(&self, sec: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(sec, key) {
            None => Ok(default),
            Some(v) => v.as_f64().ok_or_else(
                || anyhow!("[{sec}] {key} must be a number")),
        }
    }

    fn bool_or(&self, sec: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(sec, key) {
            None => Ok(default),
            Some(v) => v.as_bool().ok_or_else(
                || anyhow!("[{sec}] {key} must be a bool")),
        }
    }
}

/// Render a section name for diagnostics — the empty pre-header
/// section reads as "top level" rather than "[]".
fn section_label(section: &str) -> String {
    if section.is_empty() {
        "top level".to_string()
    } else {
        format!("in [{section}]")
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a string literal.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Toml> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string {s:?}"))?;
        return Ok(Toml::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Toml::Bool(true));
    }
    if s == "false" {
        return Ok(Toml::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array {s:?}"))?;
        let mut out = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                out.push(parse_value(part.trim())?);
            }
        }
        return Ok(Toml::Arr(out));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Toml::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Toml::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

// ---------------------------------------------------------------------------
// Typed configs
// ---------------------------------------------------------------------------

/// `[exec]` section → backend selection (shared by train and bench).
///
/// ```toml
/// [exec]
/// backend = "simd"      # or "scalar" | "blocked"
/// threads = 8           # 0 = auto-detect
/// precision = "mixed"   # or "f32"; "mixed" implies backend = "simd"
///                       # unless a different backend is set explicitly
///                       # (that combination is a hard error)
/// tuning_table = "bench-results/tuning.json"  # optional: a table
///                       # written by `spark tune`, installed
///                       # process-wide for the tunable backends
/// ```
///
/// A configured `tuning_table` must load (missing or malformed files
/// are hard errors — configs are explicit, unlike the lenient
/// `SPARK_EXEC_TUNING_TABLE` bench environment hook).
pub fn exec_from_doc(doc: &Document) -> Result<ExecOptions> {
    let d = ExecOptions::default();
    let backend_explicit = exec_backend_explicit(doc);
    let kind = match doc.get("exec", "backend") {
        None => d.kind,
        Some(v) => BackendKind::parse(v.as_str().ok_or_else(
            || anyhow!("[exec] backend must be a string"))?)?,
    };
    let threads = doc.usize_or("exec", "threads", d.threads)?;
    let mut opts = ExecOptions { kind, threads, precision: d.precision };
    if let Some(v) = doc.get("exec", "precision") {
        // same "mixed implies simd" rule as the CLI / bench env
        opts = opts.with_precision(
            Precision::parse(v.as_str().ok_or_else(
                || anyhow!("[exec] precision must be a string"))?)?,
            backend_explicit);
    }
    opts.validate()?;
    if let Some(v) = doc.get("exec", "tuning_table") {
        let path = v.as_str().ok_or_else(
            || anyhow!("[exec] tuning_table must be a string"))?;
        crate::exec::tune::install_from_path(path)
            .context("[exec] tuning_table")?;
    }
    Ok(opts)
}

/// Whether a document explicitly chooses an exec backend — the one
/// derivation of the fact that gates the "mixed implies simd" rule and
/// CLI override behaviour (`spark train` consults it for flag merging).
pub fn exec_backend_explicit(doc: &Document) -> bool {
    doc.get("exec", "backend").is_some()
}

/// `[attention]` section → structured mask + streaming block shape.
///
/// ```toml
/// [attention]
/// mask = "window"      # dense | causal | window | window:W |
///                      # block:B[:DENSITY_PCT[:SEED]]
/// window = 256         # width for a bare mask = "window"
/// block_q = 64         # streaming q-tile rows (must be ≥ 1)
/// block_k = 64         # streaming k-tile rows (must be ≥ 1)
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnConfig {
    /// Structured mask specification (see [`MaskSpec`]).
    pub mask: MaskSpec,
    /// Streaming q-tile rows.
    pub block_q: usize,
    /// Streaming k-tile rows.
    pub block_k: usize,
}

impl Default for AttnConfig {
    fn default() -> Self {
        AttnConfig { mask: MaskSpec::Dense, block_q: 64, block_k: 64 }
    }
}

/// Parse the `[attention]` section (defaults fill absent keys).
/// Zero streaming blocks and a zero window width are rejected here
/// with section/key-named errors — the streaming entry points treat a
/// zero block as a misconfiguration, never a request to clamp.
pub fn attn_from_doc(doc: &Document) -> Result<AttnConfig> {
    let d = AttnConfig::default();
    let window = match doc.get("attention", "window") {
        None => None,
        Some(v) => Some(
            v.as_i64().filter(|&i| i >= 1).map(|i| i as usize).ok_or_else(
                || anyhow!("[attention] window must be an integer ≥ 1 \
                            (width 0 would mask every key)"))?),
    };
    let mask = match doc.get("attention", "mask") {
        None => d.mask,
        Some(v) => {
            let text = v.as_str().ok_or_else(
                || anyhow!("[attention] mask must be a string"))?;
            MaskSpec::parse(text, window)
                .map_err(|e| anyhow!("[attention] mask: {e}"))?
        }
    };
    let block_q = doc.usize_or("attention", "block_q", d.block_q)?;
    let block_k = doc.usize_or("attention", "block_k", d.block_k)?;
    for (key, val) in [("block_q", block_q), ("block_k", block_k)] {
        if val == 0 {
            bail!("[attention] {key} must be ≥ 1 (a zero streaming \
                   block is rejected, not clamped up to the smallest \
                   tile)");
        }
    }
    Ok(AttnConfig { mask, block_q, block_k })
}

/// Training-run configuration (`spark train --config …`).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Directory holding the AOT artifact set (`manifest.json`).
    pub artifact_dir: String,
    /// Number of optimizer steps to run.
    pub steps: usize,
    /// Run seed (corpus synthesis + parameter init).
    pub seed: u64,
    /// Steps between progress log lines.
    pub log_every: usize,
    /// Steps between checkpoints (0 = checkpointing disabled).
    pub checkpoint_every: usize,
    /// Directory checkpoints are written into.
    pub checkpoint_dir: String,
    /// zipf exponent of the synthetic corpus token distribution.
    pub corpus_zipf: f64,
    /// Size of the synthetic corpus in tokens.
    pub corpus_tokens: usize,
    /// Optional path for the metrics JSON dump.
    pub metrics_out: Option<String>,
    /// Host execution backend (`[exec]` section).
    pub exec: ExecOptions,
    /// Attention mask + streaming blocks (`[attention]` section).
    pub attn: AttnConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifact_dir: "artifacts".into(),
            steps: 200,
            seed: 42,
            log_every: 10,
            checkpoint_every: 0, // disabled
            checkpoint_dir: "checkpoints".into(),
            corpus_zipf: 1.1,
            corpus_tokens: 1 << 20,
            metrics_out: None,
            exec: ExecOptions::default(),
            attn: AttnConfig::default(),
        }
    }
}

impl TrainConfig {
    /// Typed view of a parsed document (defaults fill absent keys).
    pub fn from_doc(doc: &Document) -> Result<Self> {
        let d = TrainConfig::default();
        let cfg = TrainConfig {
            artifact_dir: doc.str_or("train", "artifact_dir",
                                     &d.artifact_dir)?,
            steps: doc.usize_or("train", "steps", d.steps)?,
            seed: doc.usize_or("train", "seed", d.seed as usize)? as u64,
            log_every: doc.usize_or("train", "log_every", d.log_every)?,
            checkpoint_every: doc.usize_or("train", "checkpoint_every",
                                           d.checkpoint_every)?,
            checkpoint_dir: doc.str_or("train", "checkpoint_dir",
                                       &d.checkpoint_dir)?,
            corpus_zipf: doc.f64_or("corpus", "zipf", d.corpus_zipf)?,
            corpus_tokens: doc.usize_or("corpus", "tokens",
                                        d.corpus_tokens)?,
            metrics_out: doc.get("train", "metrics_out")
                .and_then(Toml::as_str).map(String::from),
            exec: exec_from_doc(doc)?,
            attn: attn_from_doc(doc)?,
        };
        if cfg.steps == 0 {
            bail!("[train] steps must be > 0");
        }
        Ok(cfg)
    }

    /// Load and validate a config file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Self::from_doc(&Document::load(path)?)
    }
}

/// Benchmark-harness configuration (shared by `spark bench-*`).
#[derive(Debug, Clone, PartialEq)]
pub struct BenchConfig {
    /// Directory holding the AOT artifact set.
    pub artifact_dir: String,
    /// Unrecorded warmup iterations per configuration.
    pub warmup_iters: usize,
    /// Recorded iterations per configuration (min 1).
    pub iters: usize,
    /// Host memory budget for admitting artifact executions (bytes).
    pub mem_budget: usize,
    /// Emit machine-readable JSON rows alongside the table.
    pub json: bool,
    /// Optional path the JSON report is written to.
    pub out_path: Option<String>,
    /// Host execution backend (`[exec]` section).
    pub exec: ExecOptions,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            artifact_dir: "artifacts".into(),
            warmup_iters: 1,
            iters: 3,
            mem_budget: 8 << 30,
            json: false,
            out_path: None,
            exec: ExecOptions::default(),
        }
    }
}

impl BenchConfig {
    /// Typed view of a parsed document (defaults fill absent keys).
    pub fn from_doc(doc: &Document) -> Result<Self> {
        let d = BenchConfig::default();
        Ok(BenchConfig {
            artifact_dir: doc.str_or("bench", "artifact_dir",
                                     &d.artifact_dir)?,
            warmup_iters: doc.usize_or("bench", "warmup_iters",
                                       d.warmup_iters)?,
            iters: doc.usize_or("bench", "iters", d.iters)?.max(1),
            mem_budget: doc.usize_or("bench", "mem_budget_gb", 8)? << 30,
            json: doc.bool_or("bench", "json", d.json)?,
            out_path: doc.get("bench", "out_path")
                .and_then(Toml::as_str).map(String::from),
            exec: exec_from_doc(doc)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# training run
[train]
steps = 300
seed = 7
artifact_dir = "artifacts"   # inline comment
metrics_out = "metrics.json"

[corpus]
zipf = 1.3
tokens = 65536

[bench]
iters = 5
json = true
mem_budget_gb = 4

[exec]
backend = "blocked"
threads = 4
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = Document::parse(SAMPLE).unwrap();
        assert_eq!(doc.get("train", "steps"), Some(&Toml::Int(300)));
        assert_eq!(doc.get("corpus", "zipf"), Some(&Toml::Float(1.3)));
        assert_eq!(doc.get("bench", "json"), Some(&Toml::Bool(true)));
        assert_eq!(doc.get("train", "artifact_dir"),
                   Some(&Toml::Str("artifacts".into())));
    }

    #[test]
    fn typed_train_config() {
        let cfg = TrainConfig::from_doc(&Document::parse(SAMPLE).unwrap())
            .unwrap();
        assert_eq!(cfg.steps, 300);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.corpus_zipf, 1.3);
        assert_eq!(cfg.corpus_tokens, 65536);
        assert_eq!(cfg.metrics_out.as_deref(), Some("metrics.json"));
        // defaults fill the gaps
        assert_eq!(cfg.checkpoint_every, 0);
    }

    #[test]
    fn typed_bench_config() {
        let cfg = BenchConfig::from_doc(&Document::parse(SAMPLE).unwrap())
            .unwrap();
        assert_eq!(cfg.iters, 5);
        assert!(cfg.json);
        assert_eq!(cfg.mem_budget, 4 << 30);
        assert_eq!(cfg.exec, ExecOptions::blocked(4));
    }

    #[test]
    fn exec_section_parses_and_validates() {
        let cfg = TrainConfig::from_doc(&Document::parse(SAMPLE).unwrap())
            .unwrap();
        assert_eq!(cfg.exec.kind, BackendKind::Blocked);
        assert_eq!(cfg.exec.threads, 4);
        assert_eq!(cfg.exec.precision, Precision::F32);
        let scalar = Document::parse("[exec]\nbackend = \"scalar\"")
            .unwrap();
        assert_eq!(exec_from_doc(&scalar).unwrap().kind,
                   BackendKind::Scalar);
        // defaults: blocked + auto threads
        assert_eq!(exec_from_doc(&Document::parse("").unwrap()).unwrap(),
                   ExecOptions::default());
        // unknown backend is a hard error
        let bad = Document::parse("[exec]\nbackend = \"gpu\"").unwrap();
        assert!(exec_from_doc(&bad).is_err());
        let bad = Document::parse("[exec]\nbackend = 3").unwrap();
        assert!(exec_from_doc(&bad).is_err());
    }

    #[test]
    fn exec_precision_parses_and_validates() {
        let doc = Document::parse(
            "[exec]\nbackend = \"simd\"\nprecision = \"mixed\"\n\
             threads = 2").unwrap();
        assert_eq!(exec_from_doc(&doc).unwrap(),
                   ExecOptions::simd(2, Precision::Mixed));
        let doc = Document::parse("[exec]\nbackend = \"simd\"").unwrap();
        assert_eq!(exec_from_doc(&doc).unwrap().precision, Precision::F32);
        // mixed without an explicit backend implies simd (CLI parity)
        let doc = Document::parse("[exec]\nprecision = \"mixed\"").unwrap();
        assert_eq!(exec_from_doc(&doc).unwrap().kind, BackendKind::Simd);
        // mixed against an explicitly chosen non-simd backend is a
        // hard error, never a silent override
        let bad = Document::parse(
            "[exec]\nbackend = \"blocked\"\nprecision = \"mixed\"")
            .unwrap();
        assert!(exec_from_doc(&bad).is_err());
        // unknown precision is a hard error
        let bad = Document::parse(
            "[exec]\nbackend = \"simd\"\nprecision = \"fp64\"").unwrap();
        assert!(exec_from_doc(&bad).is_err());
        let bad = Document::parse(
            "[exec]\nbackend = \"simd\"\nprecision = 16").unwrap();
        assert!(exec_from_doc(&bad).is_err());
    }

    #[test]
    fn exec_tuning_table_loads_and_validates() {
        let _guard = crate::exec::tune::test_lock();
        crate::exec::tune::uninstall();
        // non-string value is a type error
        let bad = Document::parse("[exec]\ntuning_table = 3").unwrap();
        assert!(exec_from_doc(&bad).is_err());
        // missing file is a hard error (configs are explicit)
        let bad = Document::parse(
            "[exec]\ntuning_table = \"/nonexistent/tuning.json\"")
            .unwrap();
        assert!(exec_from_doc(&bad).is_err());
        // a real table installs process-wide
        let path = std::env::temp_dir().join(format!(
            "spark_config_tune_{}.json", std::process::id()));
        std::fs::write(&path,
            r#"{"version": 1, "entries": [{"m": 8, "k": 4, "n": 8,
                "precision": "f32", "mc": 4, "kc": 2}]}"#).unwrap();
        let doc = Document::parse(&format!(
            "[exec]\ntuning_table = \"{}\"", path.display())).unwrap();
        exec_from_doc(&doc).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(crate::exec::tune::installed().unwrap().len(), 1);
        crate::exec::tune::uninstall();
    }

    #[test]
    fn attention_section_parses() {
        let doc = Document::parse(
            "[attention]\nmask = \"window\"\nwindow = 256\n\
             block_q = 32\nblock_k = 128").unwrap();
        let cfg = attn_from_doc(&doc).unwrap();
        assert_eq!(cfg.mask, MaskSpec::SlidingWindow { w: 256 });
        assert_eq!((cfg.block_q, cfg.block_k), (32, 128));
        let doc = Document::parse(
            "[attention]\nmask = \"block:64:40:9\"").unwrap();
        assert_eq!(attn_from_doc(&doc).unwrap().mask,
                   MaskSpec::BlockSparse { block: 64, density_pct: 40,
                                           seed: 9 });
        // absent section → dense defaults
        let cfg = attn_from_doc(&Document::parse("").unwrap()).unwrap();
        assert_eq!(cfg, AttnConfig::default());
    }

    #[test]
    fn attention_errors_name_section_and_key() {
        // zero streaming blocks are rejected, never clamped
        for key in ["block_q", "block_k"] {
            let doc = Document::parse(&format!("[attention]\n{key} = 0"))
                .unwrap();
            let err = attn_from_doc(&doc).unwrap_err().to_string();
            assert!(err.contains("[attention]"), "{err}");
            assert!(err.contains(key), "{err}");
            assert!(err.contains("not clamped"), "{err}");
        }
        // a zero window width masks every key — rejected at parse
        let doc = Document::parse("[attention]\nmask = \"window\"\n\
                                   window = 0").unwrap();
        let err = attn_from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("[attention]") && err.contains("window"),
                "{err}");
        // bare "window" without a width names its remedies
        let doc = Document::parse("[attention]\nmask = \"window\"")
            .unwrap();
        let err = attn_from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("[attention]") && err.contains("window:W"),
                "{err}");
        // unknown mask grammar
        let doc = Document::parse("[attention]\nmask = \"diag\"").unwrap();
        assert!(attn_from_doc(&doc).is_err());
        // malformed value still names line/section/key (PR-7 style)
        let err = Document::parse("[attention]\nmask = @?!\n")
            .unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("[attention]"), "{err}");
        assert!(err.contains("`mask`"), "{err}");
    }

    #[test]
    fn defaults_from_empty_doc() {
        let cfg = TrainConfig::from_doc(&Document::parse("").unwrap())
            .unwrap();
        assert_eq!(cfg, TrainConfig::default());
    }

    #[test]
    fn arrays_parse() {
        let doc = Document::parse("xs = [1, 2, 3]\nys = []").unwrap();
        assert_eq!(doc.get("", "xs"),
                   Some(&Toml::Arr(vec![Toml::Int(1), Toml::Int(2),
                                        Toml::Int(3)])));
        assert_eq!(doc.get("", "ys"), Some(&Toml::Arr(vec![])));
    }

    #[test]
    fn comments_respect_strings() {
        let doc = Document::parse("s = \"a # b\"  # real comment").unwrap();
        assert_eq!(doc.get("", "s"), Some(&Toml::Str("a # b".into())));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Document::parse("[unterminated").is_err());
        assert!(Document::parse("novalue").is_err());
        assert!(Document::parse("x = @?!").is_err());
        assert!(TrainConfig::from_doc(
            &Document::parse("[train]\nsteps = 0").unwrap()).is_err());
    }

    #[test]
    fn type_errors_are_loud() {
        let doc = Document::parse("[train]\nsteps = \"many\"").unwrap();
        let err = TrainConfig::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("steps"), "error should name the key: {err}");
    }

    #[test]
    fn parse_errors_name_line_section_and_key() {
        let err = Document::parse("[bench]\niters = @?!\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 2"), "line number missing: {err}");
        assert!(err.contains("[bench]"), "section missing: {err}");
        assert!(err.contains("`iters`"), "key missing: {err}");

        let err = Document::parse("stray\n").unwrap_err().to_string();
        assert!(err.contains("top level"),
                "pre-header errors should say top level: {err}");

        let err = Document::parse("[train]\nnot_an_assignment\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("[train]"), "section missing: {err}");
        assert!(err.contains("key = value"), "hint missing: {err}");
    }
}
