//! Streaming backward — the paper's §3.3 recomputation dataflow on the
//! host, as an algorithm witness.
//!
//! Mirrors the two Pallas backward kernels exactly:
//!
//! * `dq` accumulation: for each Q tile, sweep K/V tiles, recompute
//!   `P = exp(S − LSE)`, fold `dS·K` into a local accumulator (the Pallas
//!   `dq_acc` scratch; on Volta this is the HBM-atomics path).
//! * `dk/dv` accumulation: for each K tile, sweep Q tiles (the grid
//!   transpose), fold `P_dropᵀ·dO` and `dSᵀ·Q` locally (the per-thread-
//!   block accumulation of Figure 9).
//!
//! Both grids are embarrassingly parallel over their outer tiles, so the
//! whole backward is submitted to the `exec::Backend` pool as one task
//! set: every `(bh, q-tile)` dq task and every `(bh, k-tile)` dk/dv task
//! owns a disjoint output slice.  Accumulation order inside a tile is
//! fixed by the block sizes alone, keeping results bitwise-deterministic
//! across thread counts.
//!
//! Like the streaming forward, both grids are *skip-aware* under a
//! structured [`super::Mask`]: inner sweeps skip score tiles outside
//! the mask ([`super::Mask::tile_live`]), and an outer tile whose
//! entire sweep is dead (a q-tile with no live k-tile, or a k-tile no
//! live q-tile attends to) is never packed into a pool task — its
//! gradient slice keeps the pre-initialised zeros, which is exact: a
//! fully-masked row/column receives no gradient.  Task builders
//! declare only the live write-sets for the debug-build race detector.
//!
//! Property tests pin this block-streamed backward against the monolithic
//! oracle for arbitrary tilings — independent evidence that the
//! recomputation algebra (Equation 4 + dPsum) is tiling-invariant, which
//! is the correctness core of the paper's backward design.

use super::{mha_forward, AttnParams, Grads};
use crate::exec::{self, Backend, Precision, Task};
use crate::tensor::{bf16, Tensor};

/// Block-streamed backward with forward recomputation from (Q, K, LSE).
///
/// `lse` must be the forward's log-sum-exp (e.g. from `mha_forward`);
/// fully-masked rows carry the `-inf` sentinel there and contribute
/// exactly zero gradient.  Under a mixed-precision backend, Q/K/V/dO
/// are quantized to bf16 once at entry and the recomputed P and dS
/// tiles are quantized before their GEMM-operand roles (P → dV fold,
/// dS → dQ/dK folds); the Δ statistics and every gradient accumulator
/// stay f32.  `block_q`/`block_k` must be ≥ 1 (0 is rejected, not
/// clamped); values larger than `n` are clamped down to `n`.
pub fn mha_backward_streaming(q: &Tensor, k: &Tensor, v: &Tensor,
                              dout: &Tensor, lse: &Tensor, p: &AttnParams,
                              block_q: usize, block_k: usize,
                              be: &dyn Backend) -> Grads {
    assert!(block_q >= 1 && block_k >= 1,
            "streaming blocks must be ≥ 1 (got block_q={block_q}, \
             block_k={block_k}); a zero block is a misconfiguration, \
             not a request for the smallest tile");
    let mixed = be.precision() == Precision::Mixed;
    let qx;
    let kx;
    let vx;
    let dx;
    let (q, k, v, dout) = if mixed {
        qx = q.clone().quantize_bf16();
        kx = k.clone().quantize_bf16();
        vx = v.clone().quantize_bf16();
        dx = dout.clone().quantize_bf16();
        (&qx, &kx, &vx, &dx)
    } else {
        (q, k, v, dout)
    };
    let (bh, n, d) = match *q.shape() {
        [a, b, c] => (a, b, c),
        ref s => panic!("q must be rank-3, got {s:?}"),
    };
    p.mask.check_n(n);
    let bq = block_q.min(n).max(1);
    let bk = block_k.min(n).max(1);
    assert!(n % bq == 0 && n % bk == 0,
            "n={n} must be divisible by blocks ({bq},{bk})");
    let (qd, kd, vd, dod, ld) =
        (q.data(), k.data(), v.data(), dout.data(), lse.data());

    // Δ = rowsum(dO ∘ O): the dPsum preprocess (recompute O row-block-wise
    // via the forward formula so no O tensor needs to be passed in).
    let o = recompute_output(q, k, v, lse, p, be);
    let od = o.data();
    let mut delta = vec![0.0f32; bh * n];
    for (i, dl) in delta.iter_mut().enumerate() {
        let (orow, drow) = (&od[i * d..(i + 1) * d],
                            &dod[i * d..(i + 1) * d]);
        *dl = orow.iter().zip(drow).map(|(a, b)| a * b).sum();
    }
    let delta = delta; // freeze for shared capture

    let mut dq = vec![0.0f32; bh * n * d];
    let mut dk = vec![0.0f32; bh * n * d];
    let mut dv = vec![0.0f32; bh * n * d];
    {
        let dl = &delta[..];
        let mut dq_rest: &mut [f32] = &mut dq;
        let mut dk_rest: &mut [f32] = &mut dk;
        let mut dv_rest: &mut [f32] = &mut dv;
        let mut tasks: Vec<Task<'_>> = Vec::new();

        // Kernel 1 — dq: grid over Q tiles, inner sweep over K tiles.
        // A q-tile with no live k-tile is never packed (zero gradient).
        for b in 0..bh {
            for iq in (0..n).step_by(bq) {
                let dq_tile = exec::carve(&mut dq_rest, bq * d);
                if !(0..n).step_by(bk)
                    .any(|ik| p.mask.tile_live(iq, bq, ik, bk))
                {
                    continue;
                }
                exec::pool::declare_task_writes(&[
                    exec::pool::span(&*dq_tile),
                ]);
                tasks.push(Box::new(move || {
                    dq_tile_task(qd, kd, vd, dod, ld, dl, dq_tile, p,
                                 b, iq, bq, bk, n, d, mixed);
                }));
            }
        }

        // Kernel 2 — dk/dv: grid over K tiles, inner sweep over Q tiles.
        // A k-tile no live q-tile attends to is never packed.
        for b in 0..bh {
            for ik in (0..n).step_by(bk) {
                let dk_tile = exec::carve(&mut dk_rest, bk * d);
                let dv_tile = exec::carve(&mut dv_rest, bk * d);
                if !(0..n).step_by(bq)
                    .any(|iq| p.mask.tile_live(iq, bq, ik, bk))
                {
                    continue;
                }
                exec::pool::declare_task_writes(&[
                    exec::pool::span(&*dk_tile),
                    exec::pool::span(&*dv_tile),
                ]);
                tasks.push(Box::new(move || {
                    dkv_tile_task(qd, kd, vd, dod, ld, dl, dk_tile,
                                  dv_tile, p, b, ik, bq, bk, n, d, mixed);
                }));
            }
        }

        be.run_tasks(tasks);
    }

    Grads {
        dq: Tensor::new(vec![bh, n, d], dq),
        dk: Tensor::new(vec![bh, n, d], dk),
        dv: Tensor::new(vec![bh, n, d], dv),
    }
}

/// Tile-local recompute of one (r, c) score entry's P from (Q, K, LSE).
/// The mask check comes first: masked entries are exactly 0.0 and the
/// row's LSE — which is the `-inf` sentinel when the whole row is
/// masked — is never exponentiated for them (`exp(s − -inf)` would be
/// `+inf`).  `mixed` quantizes the result to bf16 — P's operand role
/// in the dV/dP GEMMs (the statistics in `ld` stay f32).
fn p_entry(qd: &[f32], kd: &[f32], ld: &[f32], p: &AttnParams, n: usize,
           d: usize, b: usize, r: usize, c: usize, mixed: bool) -> f32 {
    if !p.mask.live(r, c) {
        return 0.0;
    }
    let qrow = &qd[(b * n + r) * d..(b * n + r + 1) * d];
    let krow = &kd[(b * n + c) * d..(b * n + c + 1) * d];
    let mut s = 0.0;
    for (x, y) in qrow.iter().zip(krow) {
        s += x * y;
    }
    // (masked entries already returned 0.0 above)
    let pe = (s * p.scale - ld[b * n + r]).exp();
    if mixed { bf16::quantize(pe) } else { pe }
}

/// dq for one `(bh, q-tile)`: sweep the mask-live K tiles, fold `dS·K`
/// locally.  `mixed` quantizes the recomputed P and the dS value at
/// their GEMM-operand boundaries; the fold accumulator stays f32.
fn dq_tile_task(qd: &[f32], kd: &[f32], vd: &[f32], dod: &[f32],
                ld: &[f32], delta: &[f32], dq_tile: &mut [f32],
                p: &AttnParams, b: usize, iq: usize, bq: usize, bk: usize,
                n: usize, d: usize, mixed: bool) {
    for ik in (0..n).step_by(bk) {
        if !p.mask.tile_live(iq, bq, ik, bk) {
            continue;
        }
        for r in 0..bq {
            let gr = iq + r;
            let dorow = &dod[(b * n + gr) * d..(b * n + gr + 1) * d];
            for c in 0..bk {
                let gc = ik + c;
                let pe = p_entry(qd, kd, ld, p, n, d, b, gr, gc, mixed);
                if pe == 0.0 {
                    continue;
                }
                let vrow = &vd[(b * n + gc) * d..(b * n + gc + 1) * d];
                let mut dp = 0.0;
                for (x, y) in dorow.iter().zip(vrow) {
                    dp += x * y;
                }
                let ds = pe * (dp - delta[b * n + gr]) * p.scale;
                let ds = if mixed { bf16::quantize(ds) } else { ds };
                let krow = &kd[(b * n + gc) * d..(b * n + gc + 1) * d];
                let acc = &mut dq_tile[r * d..(r + 1) * d];
                for (a, &kv) in acc.iter_mut().zip(krow) {
                    *a += ds * kv;
                }
            }
        }
    }
}

/// dk/dv for one `(bh, k-tile)`: sweep the mask-live Q tiles (the grid
/// transpose), fold `Pᵀ·dO` and `dSᵀ·Q` locally.  `mixed` quantizes P
/// and dS at their GEMM-operand boundaries; both fold accumulators
/// stay f32.
fn dkv_tile_task(qd: &[f32], kd: &[f32], vd: &[f32], dod: &[f32],
                 ld: &[f32], delta: &[f32], dk_tile: &mut [f32],
                 dv_tile: &mut [f32], p: &AttnParams, b: usize, ik: usize,
                 bq: usize, bk: usize, n: usize, d: usize, mixed: bool) {
    for iq in (0..n).step_by(bq) {
        if !p.mask.tile_live(iq, bq, ik, bk) {
            continue;
        }
        for r in 0..bq {
            let gr = iq + r;
            let dorow = &dod[(b * n + gr) * d..(b * n + gr + 1) * d];
            let qrow = &qd[(b * n + gr) * d..(b * n + gr + 1) * d];
            for c in 0..bk {
                let gc = ik + c;
                let pe = p_entry(qd, kd, ld, p, n, d, b, gr, gc, mixed);
                if pe == 0.0 {
                    continue;
                }
                // dV += Pᵀ dO
                let dvrow = &mut dv_tile[c * d..(c + 1) * d];
                for (a, &x) in dvrow.iter_mut().zip(dorow) {
                    *a += pe * x;
                }
                let vrow = &vd[(b * n + gc) * d..(b * n + gc + 1) * d];
                let mut dp = 0.0;
                for (x, y) in dorow.iter().zip(vrow) {
                    dp += x * y;
                }
                let ds = pe * (dp - delta[b * n + gr]) * p.scale;
                let ds = if mixed { bf16::quantize(ds) } else { ds };
                // dK += dSᵀ Q
                let dkrow = &mut dk_tile[c * d..(c + 1) * d];
                for (a, &x) in dkrow.iter_mut().zip(qrow) {
                    *a += ds * x;
                }
            }
        }
    }
}

/// Recompute O from (Q, K, V, LSE).  The device backward reads the
/// saved O tensor for its dPsum preprocess; the host witness recomputes
/// it from the statistics instead, so the witness needs only (Q, K, V,
/// LSE) — demonstrating the stronger memory claim.
fn recompute_output(q: &Tensor, k: &Tensor, v: &Tensor, lse: &Tensor,
                    p: &AttnParams, be: &dyn Backend) -> Tensor {
    // numerically identical to the forward given the same lse (a
    // mixed-precision backend recomputes from quantized operands, so
    // its statistics may sit a bf16-sized step away from an f32 lse);
    // fully-masked rows carry the -inf sentinel on both sides, which
    // counts as equal (their difference is NaN, not a deviation)
    let f = mha_forward(q, k, v, p, be);
    let tol = if be.precision() == Precision::Mixed { 0.5 } else { 1e-3 };
    debug_assert!(
        f.lse.data().iter().zip(lse.data()).all(|(&a, &b)| {
            (a == f32::NEG_INFINITY && b == f32::NEG_INFINITY)
                || (a - b).abs() < tol
        }),
        "provided LSE does not match this (q,k) pair"
    );
    f.output
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{mha_backward, BlockLayout, Mask};
    use crate::exec::{Blocked, Scalar};
    use crate::tensor::Rng;

    fn case(bh: usize, n: usize, d: usize, seed: u64)
            -> (Tensor, Tensor, Tensor, Tensor) {
        let mut r = Rng::new(seed);
        (Tensor::randn(vec![bh, n, d], &mut r),
         Tensor::randn(vec![bh, n, d], &mut r),
         Tensor::randn(vec![bh, n, d], &mut r),
         Tensor::randn(vec![bh, n, d], &mut r))
    }

    #[test]
    fn matches_oracle_full() {
        let (q, k, v, dout) = case(2, 32, 8, 1);
        let p = AttnParams::new(8, false).unwrap();
        let lse = mha_forward(&q, &k, &v, &p, &Scalar).lse;
        let want = mha_backward(&q, &k, &v, &dout, &p, &Scalar);
        for (bq, bk) in [(32, 32), (8, 8), (16, 4)] {
            let got = mha_backward_streaming(&q, &k, &v, &dout, &lse, &p,
                                             bq, bk, &Scalar);
            assert!(got.dq.max_abs_diff(&want.dq) < 1e-3, "dq ({bq},{bk})");
            assert!(got.dk.max_abs_diff(&want.dk) < 1e-3, "dk ({bq},{bk})");
            assert!(got.dv.max_abs_diff(&want.dv) < 1e-3, "dv ({bq},{bk})");
        }
    }

    #[test]
    fn matches_oracle_causal() {
        let (q, k, v, dout) = case(1, 32, 8, 2);
        let p = AttnParams::new(8, true).unwrap();
        let lse = mha_forward(&q, &k, &v, &p, &Scalar).lse;
        let want = mha_backward(&q, &k, &v, &dout, &p, &Scalar);
        for (bq, bk) in [(8, 8), (16, 8), (8, 16)] {
            let got = mha_backward_streaming(&q, &k, &v, &dout, &lse, &p,
                                             bq, bk, &Scalar);
            assert!(got.dq.max_abs_diff(&want.dq) < 1e-3, "dq ({bq},{bk})");
            assert!(got.dk.max_abs_diff(&want.dk) < 1e-3, "dk ({bq},{bk})");
            assert!(got.dv.max_abs_diff(&want.dv) < 1e-3, "dv ({bq},{bk})");
        }
    }

    #[test]
    fn matches_oracle_sliding_window_and_block_sparse() {
        let (q, k, v, dout) = case(1, 32, 8, 4);
        let mut live = vec![true; 16];
        for bj in 0..4 {
            live[2 * 4 + bj] = false; // query block-row 2 fully masked
        }
        let masks = [
            Mask::SlidingWindow { w: 1 },
            Mask::SlidingWindow { w: 6 },
            Mask::BlockSparse {
                layout: BlockLayout::new(8, 4, live).unwrap(),
            },
        ];
        for mask in masks {
            let p = AttnParams::with_mask(8, mask).unwrap();
            let lse = mha_forward(&q, &k, &v, &p, &Scalar).lse;
            let want = mha_backward(&q, &k, &v, &dout, &p, &Scalar);
            for (bq, bk) in [(8, 8), (16, 8), (8, 16)] {
                let got = mha_backward_streaming(&q, &k, &v, &dout, &lse,
                                                 &p, bq, bk, &Scalar);
                for (name, g, w) in [("dq", &got.dq, &want.dq),
                                     ("dk", &got.dk, &want.dk),
                                     ("dv", &got.dv, &want.dv)] {
                    assert!(g.max_abs_diff(w) < 1e-3,
                            "{name} ({bq},{bk}) mask {:?}", p.mask);
                }
            }
        }
    }

    /// The recomputation path must survive fully-masked rows: the LSE
    /// carries -inf sentinels and the gradients are exactly zero for
    /// those rows (no NaN anywhere).
    #[test]
    fn fully_masked_rows_give_zero_grads() {
        let (q, k, v, dout) = case(1, 16, 4, 5);
        let p = AttnParams::with_mask(4, Mask::SlidingWindow { w: 0 })
            .unwrap();
        let lse = mha_forward(&q, &k, &v, &p, &Scalar).lse;
        let got = mha_backward_streaming(&q, &k, &v, &dout, &lse, &p,
                                         4, 4, &Scalar);
        for (name, g) in [("dq", &got.dq), ("dk", &got.dk),
                          ("dv", &got.dv)] {
            for &x in g.data() {
                assert_eq!(x, 0.0, "{name} must be exactly zero");
            }
        }
    }

    #[test]
    #[should_panic(expected = "streaming blocks must be ≥ 1")]
    fn zero_blocks_are_rejected() {
        let (q, k, v, dout) = case(1, 8, 4, 6);
        let p = AttnParams::new(4, false).unwrap();
        let lse = mha_forward(&q, &k, &v, &p, &Scalar).lse;
        mha_backward_streaming(&q, &k, &v, &dout, &lse, &p, 0, 0, &Scalar);
    }

    #[test]
    fn thread_count_invariant() {
        let (q, k, v, dout) = case(2, 32, 8, 3);
        let p = AttnParams::new(8, true).unwrap();
        let lse = mha_forward(&q, &k, &v, &p, &Scalar).lse;
        let base = mha_backward_streaming(&q, &k, &v, &dout, &lse, &p, 8, 8,
                                          &Blocked::new(1));
        for threads in [2usize, 8] {
            let got = mha_backward_streaming(&q, &k, &v, &dout, &lse, &p,
                                             8, 8, &Blocked::new(threads));
            assert_eq!(base.dq.data(), got.dq.data(), "threads={threads}");
            assert_eq!(base.dk.data(), got.dk.data());
            assert_eq!(base.dv.data(), got.dv.data());
        }
    }
}
