//! Pure-Rust multi-head attention: oracle + streaming (online-softmax)
//! implementation.
//!
//! Two roles:
//!
//! 1. **Oracle** — `mha_forward` / `mha_backward` materialise the full N×N
//!    score matrix in f32 (Equation 1 / Equation 4 of the paper) and are the
//!    ground truth the device artifacts are verified against in the
//!    integration tests (`rust/tests/`).
//! 2. **Algorithm witness** — `mha_forward_streaming` re-implements the
//!    fused kernel's *dataflow* (block-streamed K/V, running (m, l)
//!    statistics, accumulator rescaling — Equation 3) on the host.  The
//!    property tests in `rust/tests/proptest_attention.rs` check it against
//!    the oracle over randomized shapes/blocks, which pins down the online
//!    softmax algebra independently of JAX.
//!
//! Dropout is intentionally absent here: masks are derived from the device
//! RNG (`python/compile/kernels/rng.py`), so cross-checking dropout paths
//! happens in the Python test suite where both sides share the RNG.

pub mod streaming_bwd;

pub use streaming_bwd::mha_backward_streaming;

use crate::tensor::{batch_matmul, batch_matmul_nt, batch_matmul_tn,
                    softmax_lastdim, Tensor};

/// Value used for masked-out logits (matches the kernels' `NEG_INF`).
pub const NEG_INF: f32 = -1e30;

/// Static attention parameters.
#[derive(Debug, Clone, Copy)]
pub struct AttnParams {
    pub causal: bool,
    /// Softmax temperature; the standard choice is `1/sqrt(d)`.
    pub scale: f32,
}

impl AttnParams {
    pub fn new(d: usize, causal: bool) -> Self {
        AttnParams { causal, scale: 1.0 / (d as f32).sqrt() }
    }
}

/// Forward outputs: attention output + log-sum-exp statistics.
#[derive(Debug, Clone)]
pub struct ForwardResult {
    pub output: Tensor,
    /// (bh, n) row-wise log-sum-exp — the paper's "LES" record.
    pub lse: Tensor,
}

/// Backward outputs (Equation 4).
#[derive(Debug, Clone)]
pub struct Grads {
    pub dq: Tensor,
    pub dk: Tensor,
    pub dv: Tensor,
}

fn dims(q: &Tensor, k: &Tensor, v: &Tensor) -> (usize, usize, usize) {
    let (bh, n, d) = match *q.shape() {
        [a, b, c] => (a, b, c),
        ref s => panic!("q must be rank-3 (bh, n, d), got {s:?}"),
    };
    assert_eq!(k.shape(), &[bh, n, d], "k shape mismatch");
    assert_eq!(v.shape(), &[bh, n, d], "v shape mismatch");
    (bh, n, d)
}

fn apply_causal_mask(s: &mut Tensor) {
    let (bh, n, m) = match *s.shape() {
        [a, b, c] => (a, b, c),
        _ => unreachable!(),
    };
    let data = s.data_mut();
    for bi in 0..bh {
        for i in 0..n {
            let row = &mut data[(bi * n + i) * m..(bi * n + i + 1) * m];
            for (j, x) in row.iter_mut().enumerate() {
                if j > i {
                    *x = NEG_INF;
                }
            }
        }
    }
}

/// Oracle forward: materialises S and P (the unfused dataflow), f32 math.
pub fn mha_forward(q: &Tensor, k: &Tensor, v: &Tensor,
                   p: AttnParams) -> ForwardResult {
    let (bh, n, _d) = dims(q, k, v);
    let mut s = batch_matmul_nt(q, k).scale(p.scale);
    if p.causal {
        apply_causal_mask(&mut s);
    }
    // lse before normalisation (for parity with the fused kernel output)
    let mut lse = Tensor::zeros(vec![bh, n]);
    {
        let sd = s.data();
        let ld = lse.data_mut();
        for (ri, row) in sd.chunks_exact(n).enumerate() {
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let sum: f32 = row.iter().map(|x| (x - m).exp()).sum();
            ld[ri] = m + sum.ln();
        }
    }
    softmax_lastdim(&mut s);
    ForwardResult { output: batch_matmul(&s, v), lse }
}

/// Streaming forward: the fused kernel's block dataflow on the host.
///
/// Iterates K/V in `block_k` tiles per `block_q` row tile, carrying
/// (m, l, acc) and rescaling by `exp(m_prev − m_cur)` — Equation 3.
pub fn mha_forward_streaming(q: &Tensor, k: &Tensor, v: &Tensor,
                             p: AttnParams, block_q: usize,
                             block_k: usize) -> ForwardResult {
    let (bh, n, d) = dims(q, k, v);
    let bq = block_q.min(n).max(1);
    let bk = block_k.min(n).max(1);
    assert!(n % bq == 0 && n % bk == 0,
            "n={n} must be divisible by blocks ({bq},{bk})");
    let qd = q.data();
    let kd = k.data();
    let vd = v.data();
    let mut out = vec![0.0f32; bh * n * d];
    let mut lse = vec![0.0f32; bh * n];

    for b in 0..bh {
        for iq in (0..n).step_by(bq) {
            // per-row running statistics + accumulator for this Q tile
            let mut m = vec![f32::NEG_INFINITY; bq];
            let mut l = vec![0.0f32; bq];
            let mut acc = vec![0.0f32; bq * d];
            for ik in (0..n).step_by(bk) {
                if p.causal && ik > iq + bq - 1 {
                    continue; // fully-masked tile: skipped, like the kernel
                }
                // s_tile = Q_tile · K_tileᵀ · scale  (+ causal mask)
                for r in 0..bq {
                    let qrow = &qd[(b * n + iq + r) * d
                                   ..(b * n + iq + r + 1) * d];
                    let mut srow = vec![0.0f32; bk];
                    for (c, sv) in srow.iter_mut().enumerate() {
                        let krow = &kd[(b * n + ik + c) * d
                                       ..(b * n + ik + c + 1) * d];
                        let mut dot = 0.0;
                        for (x, y) in qrow.iter().zip(krow) {
                            dot += x * y;
                        }
                        *sv = if p.causal && ik + c > iq + r {
                            NEG_INF
                        } else {
                            dot * p.scale
                        };
                    }
                    // online softmax update for row r
                    let m_cur = srow.iter().cloned().fold(m[r], f32::max);
                    let alpha = if m[r] == f32::NEG_INFINITY {
                        0.0
                    } else {
                        (m[r] - m_cur).exp()
                    };
                    let mut psum = 0.0;
                    let arow = &mut acc[r * d..(r + 1) * d];
                    for x in arow.iter_mut() {
                        *x *= alpha;
                    }
                    for (c, &sv) in srow.iter().enumerate() {
                        let pv = (sv - m_cur).exp();
                        psum += pv;
                        if pv != 0.0 {
                            let vrow = &vd[(b * n + ik + c) * d
                                           ..(b * n + ik + c + 1) * d];
                            for (a, &vv) in arow.iter_mut().zip(vrow) {
                                *a += pv * vv;
                            }
                        }
                    }
                    l[r] = l[r] * alpha + psum;
                    m[r] = m_cur;
                }
            }
            for r in 0..bq {
                let arow = &acc[r * d..(r + 1) * d];
                let orow = &mut out[(b * n + iq + r) * d
                                    ..(b * n + iq + r + 1) * d];
                for (o, &a) in orow.iter_mut().zip(arow) {
                    *o = a / l[r];
                }
                lse[b * n + iq + r] = m[r] + l[r].ln();
            }
        }
    }
    ForwardResult {
        output: Tensor::new(vec![bh, n, d], out),
        lse: Tensor::new(vec![bh, n], lse),
    }
}

/// Oracle backward (Equation 4), recomputing the forward internally.
pub fn mha_backward(q: &Tensor, k: &Tensor, v: &Tensor, dout: &Tensor,
                    p: AttnParams) -> Grads {
    let (_bh, _n, _d) = dims(q, k, v);
    let mut s = batch_matmul_nt(q, k).scale(p.scale);
    if p.causal {
        apply_causal_mask(&mut s);
    }
    softmax_lastdim(&mut s);
    let pm = s; // P

    // dV = Pᵀ · dO
    let dv = batch_matmul_tn(&pm, dout);
    // dP = dO · Vᵀ
    let dp = batch_matmul_nt(dout, v);
    // dS = P ∘ (dP − rowsum(P ∘ dP))
    let n = pm.shape()[1];
    let mut ds = pm.clone();
    {
        let pd = pm.data();
        let dpd = dp.data();
        let dsd = ds.data_mut();
        for ri in 0..pd.len() / n {
            let prow = &pd[ri * n..(ri + 1) * n];
            let dprow = &dpd[ri * n..(ri + 1) * n];
            let dsum: f32 = prow.iter().zip(dprow).map(|(a, b)| a * b).sum();
            let dsrow = &mut dsd[ri * n..(ri + 1) * n];
            for ((dsv, &pv), &dpv) in dsrow.iter_mut().zip(prow).zip(dprow) {
                *dsv = pv * (dpv - dsum);
            }
        }
    }
    // dQ = dS · K · scale;  dK = dSᵀ · Q · scale
    let dq = batch_matmul(&ds, k).scale(p.scale);
    let dk = batch_matmul_tn(&ds, q).scale(p.scale);
    Grads { dq, dk, dv }
}

/// Matmul FLOPs of one MHA (Fig 10/11 TFLOPs denominator; mirrors
/// `python/compile/kernels/ref.py::attention_flops`).
pub fn attention_flops(bh: usize, n: usize, d: usize, causal: bool,
                       backward: bool) -> u64 {
    let matmuls: u64 = if backward { 5 } else { 2 };
    let flops = matmuls * 2 * (n as u64) * (n as u64) * (d as u64)
        * (bh as u64);
    if causal { flops / 2 } else { flops }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn rand_qkv(bh: usize, n: usize, d: usize, seed: u64)
                -> (Tensor, Tensor, Tensor) {
        let mut r = Rng::new(seed);
        (Tensor::randn(vec![bh, n, d], &mut r),
         Tensor::randn(vec![bh, n, d], &mut r),
         Tensor::randn(vec![bh, n, d], &mut r))
    }

    #[test]
    fn forward_uniform_attention_averages_v() {
        // q = 0 → uniform softmax → output = column mean of V
        let (_, k, v) = rand_qkv(1, 8, 4, 1);
        let q = Tensor::zeros(vec![1, 8, 4]);
        let r = mha_forward(&q, &k, &v, AttnParams::new(4, false));
        let vd = v.data();
        for c in 0..4 {
            let mean: f32 = (0..8).map(|i| vd[i * 4 + c]).sum::<f32>() / 8.0;
            for i in 0..8 {
                assert!((r.output.at(&[0, i, c]) - mean).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn causal_first_row_copies_v0() {
        let (q, k, v) = rand_qkv(2, 16, 8, 2);
        let r = mha_forward(&q, &k, &v, AttnParams::new(8, true));
        for b in 0..2 {
            for c in 0..8 {
                assert!((r.output.at(&[b, 0, c]) - v.at(&[b, 0, c])).abs()
                        < 1e-5, "row 0 must attend only to position 0");
            }
        }
    }

    #[test]
    fn streaming_matches_oracle_full() {
        let (q, k, v) = rand_qkv(2, 32, 8, 3);
        let p = AttnParams::new(8, false);
        let a = mha_forward(&q, &k, &v, p);
        for (bq, bk) in [(32, 32), (8, 8), (16, 4), (4, 16), (1, 1)] {
            let b = mha_forward_streaming(&q, &k, &v, p, bq, bk);
            assert!(a.output.max_abs_diff(&b.output) < 1e-4,
                    "blocks ({bq},{bk})");
            assert!(a.lse.max_abs_diff(&b.lse) < 1e-4);
        }
    }

    #[test]
    fn streaming_matches_oracle_causal() {
        let (q, k, v) = rand_qkv(2, 32, 8, 4);
        let p = AttnParams::new(8, true);
        let a = mha_forward(&q, &k, &v, p);
        for (bq, bk) in [(8, 8), (16, 8), (8, 16)] {
            let b = mha_forward_streaming(&q, &k, &v, p, bq, bk);
            assert!(a.output.max_abs_diff(&b.output) < 1e-4,
                    "blocks ({bq},{bk})");
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        let (q, k, v) = rand_qkv(1, 6, 4, 5);
        let p = AttnParams::new(4, false);
        let dout = Tensor::full(vec![1, 6, 4], 1.0);
        let g = mha_backward(&q, &k, &v, &dout, p);
        let eps = 1e-3f32;
        let f = |q: &Tensor, k: &Tensor, v: &Tensor| -> f32 {
            mha_forward(q, k, v, p).output.data().iter().sum()
        };
        // spot-check several coordinates of dq, dk, dv
        for (which, grad) in [("q", &g.dq), ("k", &g.dk), ("v", &g.dv)] {
            for idx in [0usize, 7, 13, 23] {
                let (mut qp, mut kp, mut vp) =
                    (q.clone(), k.clone(), v.clone());
                let bump = |qp: &mut Tensor, kp: &mut Tensor,
                            vp: &mut Tensor, delta: f32| {
                    let t = match which {
                        "q" => qp,
                        "k" => kp,
                        _ => vp,
                    };
                    t.data_mut()[idx] += delta;
                };
                bump(&mut qp, &mut kp, &mut vp, eps);
                let up = f(&qp, &kp, &vp);
                bump(&mut qp, &mut kp, &mut vp, -2.0 * eps);
                let dn = f(&qp, &kp, &vp);
                let fd = (up - dn) / (2.0 * eps);
                let an = grad.data()[idx];
                assert!((fd - an).abs() < 2e-2,
                        "d{which}[{idx}]: fd={fd} analytic={an}");
            }
        }
    }

    #[test]
    fn lse_is_finite() {
        let (q, k, v) = rand_qkv(1, 16, 8, 6);
        let r = mha_forward(&q, &k, &v, AttnParams::new(8, false));
        for &x in r.lse.data() {
            assert!(x.is_finite());
        }
    }

    #[test]
    fn flops_halve_under_causal() {
        assert_eq!(attention_flops(4, 256, 64, true, false) * 2,
                   attention_flops(4, 256, 64, false, false));
        // backward = 5 matmuls vs forward 2
        assert_eq!(attention_flops(1, 128, 64, false, true) * 2,
                   attention_flops(1, 128, 64, false, false) * 5);
    }
}
