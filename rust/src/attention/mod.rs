//! Pure-Rust multi-head attention: oracle + streaming (online-softmax)
//! implementation, executed through an `exec::Backend`.
//!
//! Two roles:
//!
//! 1. **Oracle** — `mha_forward` / `mha_backward` materialise the full N×N
//!    score matrix in f32 (Equation 1 / Equation 4 of the paper) and are the
//!    ground truth the device artifacts are verified against in the
//!    integration tests (`rust/tests/`).  Run them on `exec::Scalar` when
//!    they serve as ground truth.
//! 2. **Algorithm witness** — `mha_forward_streaming` re-implements the
//!    fused kernel's *dataflow* (block-streamed K/V, running (m, l)
//!    statistics, accumulator rescaling — Equation 3) on the host.  The
//!    property tests in `rust/tests/proptest_attention.rs` check it against
//!    the oracle over randomized shapes/blocks, which pins down the online
//!    softmax algebra independently of JAX.
//!
//! Every entry point takes a `&dyn exec::Backend`.  The matmuls route
//! through the backend, and the streaming paths fan their `(bh, q-block)`
//! tiles out over the backend's worker pool with per-tile (m, l)
//! statistics — so for a fixed block size the result is bitwise-identical
//! for any thread count (each tile's accumulation order never changes).
//!
//! **Masks.**  [`AttnParams`] carries a structured [`Mask`] (dense,
//! causal, sliding-window, block-sparse — see [`mask`]).  Masked logits
//! become `-inf` before the softmax, and a query row with *no* live key
//! is defined to produce an exactly-zero output row with an LSE of
//! `-inf` (the sentinel) — never NaN, never uniform weights — in the
//! fused oracle, the streaming forward, and the streaming backward's
//! recomputation, bitwise across backends and thread counts.  The
//! streaming tilings are *skip-aware*: score tiles provably outside the
//! mask ([`Mask::tile_live`]) are never packed or scheduled on the
//! pool (a query tile with no live key tile doesn't even become a
//! task), and the same enumeration drives the `iomodel` masked traffic
//! accounting.
//!
//! **Precision.**  The streaming paths also honour the backend's
//! [`exec::Precision`]: under a mixed-precision backend the leaf
//! operands (Q, K, V, dO) are quantized to bf16 once at entry — the
//! host analogue of packing fp16 fragments — and the recomputed P / dS
//! tiles are quantized before they feed the second GEMM of each pass,
//! exactly where a Volta kernel converts registers for the next `mma`.
//! Softmax statistics (m, l, LSE, Δ) and every accumulator stay f32,
//! the paper's FP32-accumulate contract.  Under an f32 backend nothing
//! is quantized and the bitwise determinism contract above holds
//! unchanged.
//!
//! Dropout is intentionally absent here: masks are derived from the device
//! RNG (`python/compile/kernels/rng.py`), so cross-checking dropout paths
//! happens in the Python test suite where both sides share the RNG.

pub mod decode;
pub mod mask;
pub mod prefill;
pub mod streaming_bwd;

pub use decode::decode_step;
pub use mask::{BlockLayout, Mask, MaskSpec, TileCounts};
pub use prefill::{prefill_chunk, PrefillState};
pub use streaming_bwd::mha_backward_streaming;

use crate::exec::{self, Backend, ExecOptions, Precision, Task};
use crate::tensor::{bf16, Tensor};
use anyhow::{bail, Result};

/// Finite stand-in for `-inf` used by the *device* kernels for masked
/// logits (matches `python/compile/kernels`' `NEG_INF`).  The host
/// paths now use true `f32::NEG_INFINITY` internally, which is
/// bitwise-equivalent for every partially-masked row (`exp` underflows
/// to exactly 0.0 either way) but — unlike a finite sentinel — makes a
/// fully-masked row detectable as `row max == -inf` instead of
/// silently softmaxing into uniform weights over forbidden keys.
pub const NEG_INF: f32 = -1e30;

/// Rows of the score matrix handled per worker task in the fused
/// scale/mask/softmax/LSE pass.  Fixed (not thread-derived) so the work
/// partition is reproducible in traces regardless of `exec.threads`.
const SOFTMAX_ROWS_PER_TASK: usize = 16;

/// Static attention parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct AttnParams {
    /// Which (query, key) scores are live (see [`mask`]).
    pub mask: Mask,
    /// Softmax temperature; the standard choice is `1/sqrt(d)`.
    pub scale: f32,
}

impl AttnParams {
    /// Parameters for head dimension `d` with the standard `1/sqrt(d)`
    /// temperature and a dense or causal mask.  `d = 0` is rejected
    /// (the scale would be `inf` and every output NaN).
    pub fn new(d: usize, causal: bool) -> Result<Self> {
        Self::with_mask(d, if causal { Mask::Causal } else { Mask::Dense })
    }

    /// Parameters for head dimension `d` with an explicit [`Mask`].
    pub fn with_mask(d: usize, mask: Mask) -> Result<Self> {
        if d == 0 {
            bail!("attention head dimension d must be ≥ 1: d = 0 gives \
                   scale = 1/sqrt(0) = inf and NaN outputs");
        }
        Ok(AttnParams { mask, scale: 1.0 / (d as f32).sqrt() })
    }
}

/// Forward outputs: attention output + log-sum-exp statistics.
#[derive(Debug, Clone)]
pub struct ForwardResult {
    /// (bh, n, d) attention output.
    pub output: Tensor,
    /// (bh, n) row-wise log-sum-exp — the paper's "LES" record.  A
    /// fully-masked query row carries the `-inf` sentinel.
    pub lse: Tensor,
}

/// Backward outputs (Equation 4).
#[derive(Debug, Clone)]
pub struct Grads {
    /// Gradient w.r.t. the queries, (bh, n, d).
    pub dq: Tensor,
    /// Gradient w.r.t. the keys, (bh, n, d).
    pub dk: Tensor,
    /// Gradient w.r.t. the values, (bh, n, d).
    pub dv: Tensor,
}

fn dims(q: &Tensor, k: &Tensor, v: &Tensor) -> (usize, usize, usize) {
    let (bh, n, d) = match *q.shape() {
        [a, b, c] => (a, b, c),
        ref s => panic!("q must be rank-3 (bh, n, d), got {s:?}"),
    };
    assert_eq!(k.shape(), &[bh, n, d], "k shape mismatch");
    assert_eq!(v.shape(), &[bh, n, d], "v shape mismatch");
    (bh, n, d)
}

/// Fused scale → mask → softmax pass over raw scores, row-parallel on
/// the backend pool.  Writes the row-wise log-sum-exp into `lse` (pass
/// a scratch slice if the caller doesn't need it).  Masked logits
/// become `-inf`; a row whose max is still `-inf` after masking has no
/// live key and is written as exact zeros with the `-inf` LSE sentinel
/// (softmaxing such a row would divide uniform `exp(0)` weights over
/// forbidden keys).  Element-for-element this performs the same
/// operations in the same order as the unfused `scale` + mask +
/// `softmax_lastdim` sequence, so it is bitwise-stable across backends
/// and thread counts.
fn finish_scores(s: &mut Tensor, lse: &mut [f32], p: &AttnParams,
                 be: &dyn Backend) {
    let (bh, nq, nk) = match *s.shape() {
        [a, b, c] => (a, b, c),
        ref sh => panic!("scores must be rank-3, got {sh:?}"),
    };
    let total_rows = bh * nq;
    assert_eq!(lse.len(), total_rows);
    let mut srest: &mut [f32] = s.data_mut();
    let mut lrest: &mut [f32] = lse;
    let mut tasks: Vec<Task<'_>> = Vec::new();
    let mut r0 = 0;
    while r0 < total_rows {
        let rows = SOFTMAX_ROWS_PER_TASK.min(total_rows - r0);
        let schunk = exec::carve(&mut srest, rows * nk);
        let lchunk = exec::carve(&mut lrest, rows);
        exec::pool::declare_task_writes(&[
            exec::pool::span(&*schunk),
            exec::pool::span(&*lchunk),
        ]);
        tasks.push(Box::new(move || {
            for (ri, (row, lse1)) in schunk.chunks_exact_mut(nk)
                .zip(lchunk.iter_mut()).enumerate()
            {
                let i = (r0 + ri) % nq; // query position within the batch
                for (j, x) in row.iter_mut().enumerate() {
                    *x = if p.mask.live(i, j) {
                        *x * p.scale
                    } else {
                        f32::NEG_INFINITY
                    };
                }
                let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                if m == f32::NEG_INFINITY {
                    // fully-masked row: zero weights + LSE sentinel
                    for x in row.iter_mut() {
                        *x = 0.0;
                    }
                    *lse1 = f32::NEG_INFINITY;
                    continue;
                }
                let mut sum = 0.0;
                for x in row.iter_mut() {
                    *x = (*x - m).exp();
                    sum += *x;
                }
                for x in row.iter_mut() {
                    *x /= sum;
                }
                *lse1 = m + sum.ln();
            }
        }));
        r0 += rows;
    }
    be.run_tasks(tasks);
}

/// The mask roster `witness_self_check` sweeps: dense, causal, a
/// sliding window, and a block-sparse layout whose query block-row 2
/// is fully dead — so the fully-masked-row sentinel path is exercised
/// through every backend on every startup check.
fn witness_masks(n: usize) -> Result<Vec<Mask>> {
    let nb = 4;
    let block = n / nb;
    let mut live = vec![false; nb * nb];
    for bj in 0..nb {
        live[bj] = bj == 0; //            row 0: first block only
        live[nb + bj] = bj < 2; //        row 1: first two blocks
        live[3 * nb + bj] = true; //      row 3: fully live
    } //                                  row 2: fully masked
    Ok(vec![
        Mask::Dense,
        Mask::Causal,
        Mask::SlidingWindow { w: 5 },
        Mask::BlockSparse { layout: BlockLayout::new(block, nb, live)? },
    ])
}

/// Run the full algorithm witness through **every** available backend
/// (the `exec::roster` of `opts`, not just the configured one) and
/// cross-check the results pairwise, so a failure names the diverging
/// pair.  Each backend's streaming forward/backward is additionally
/// anchored against the monolithic Scalar oracle.  The sweep covers
/// every [`Mask`] variant, including a block-sparse layout with a
/// fully-masked query block-row (the zero-output/`-inf`-LSE sentinel
/// contract).  Pure-f32 backends must agree with each other to ~1 ulp
/// (the determinism contract); pairs involving the mixed-precision
/// backend get a loose bf16-derived bound — the point there is
/// catching a broken kernel, not re-proving the quantization error
/// analysis (which lives in `rust/tests/exec_backend.rs`).  `spark
/// train` runs this at startup so a miscompiled or misconfigured
/// backend aborts before any long run (the witness is what grounds
/// trust in the fused artifacts' dataflow).
pub fn witness_self_check(opts: ExecOptions) -> Result<()> {
    let backends = exec::roster(opts);
    let (bh, n, d) = (2usize, 32usize, 8usize);
    let mut rng = crate::tensor::Rng::new(0xBEAC);
    let q = Tensor::randn(vec![bh, n, d], &mut rng);
    let k = Tensor::randn(vec![bh, n, d], &mut rng);
    let v = Tensor::randn(vec![bh, n, d], &mut rng);
    let dout = Tensor::randn(vec![bh, n, d], &mut rng);
    // loose sanity bounds for anything involving the mixed backend
    let (mixed_ftol, mixed_btol) = (0.5f32, 1.0f32);
    for mask in witness_masks(n)? {
        let label = mask.label();
        let p = AttnParams::with_mask(d, mask)?;
        let oracle = mha_forward(&q, &k, &v, &p, &exec::Scalar);
        let oracle_bwd = mha_backward(&q, &k, &v, &dout, &p, &exec::Scalar);
        let mut results: Vec<(String, Precision, ForwardResult, Grads)> =
            Vec::new();
        for be in &backends {
            let fwd = mha_forward_streaming(&q, &k, &v, &p, 8, 16,
                                            be.as_ref());
            let bwd = mha_backward_streaming(&q, &k, &v, &dout,
                                             &oracle.lse, &p, 8, 16,
                                             be.as_ref());
            results.push((be.name(), be.precision(), fwd, bwd));
        }
        // anchor: every backend against the monolithic Scalar oracle
        for (name, prec, fwd, bwd) in &results {
            let (ftol, btol) = if *prec == Precision::Mixed {
                (mixed_ftol, mixed_btol)
            } else {
                (1e-4, 1e-3)
            };
            let err = fwd.output.max_abs_diff(&oracle.output);
            if err > ftol {
                bail!("backend {name}: streaming forward deviates from \
                       the oracle (mask={label}, max err {err}, \
                       tol {ftol})");
            }
            for (gname, g, w) in [("dq", &bwd.dq, &oracle_bwd.dq),
                                  ("dk", &bwd.dk, &oracle_bwd.dk),
                                  ("dv", &bwd.dv, &oracle_bwd.dv)] {
                let err = g.max_abs_diff(w);
                if err > btol {
                    bail!("backend {name}: streaming backward {gname} \
                           deviates (mask={label}, max err {err}, \
                           tol {btol})");
                }
            }
        }
        // pairwise: which pair diverged?
        for i in 0..results.len() {
            for j in i + 1..results.len() {
                let same_mode = results[i].1 == results[j].1;
                let (ftol, btol) = if same_mode {
                    (1e-6, 1e-6)
                } else {
                    (mixed_ftol, mixed_btol)
                };
                let err = results[i].2.output
                    .max_abs_diff(&results[j].2.output);
                if err > ftol {
                    bail!("witness self-check: backends {} and {} \
                           diverge on the streaming forward \
                           (mask={label}, max err {err})",
                          results[i].0, results[j].0);
                }
                for (gname, gi, gj) in
                    [("dq", &results[i].3.dq, &results[j].3.dq),
                     ("dk", &results[i].3.dk, &results[j].3.dk),
                     ("dv", &results[i].3.dv, &results[j].3.dv)]
                {
                    let err = gi.max_abs_diff(gj);
                    if err > btol {
                        bail!("witness self-check: backends {} and {} \
                               diverge on streaming {gname} \
                               (mask={label}, max err {err})",
                              results[i].0, results[j].0);
                    }
                }
            }
        }
    }
    Ok(())
}

/// Validate and exercise the *configured* mask (from `[attention]` or
/// `--mask`/`--window`) before a long run.  Builds the spec at a small
/// witness length compatible with it (block-sparse needs
/// `block | n`), then checks streaming-vs-oracle forward parity under
/// the configured backend at the configured streaming block shape
/// (clamped to divisors of the witness length).  `spark train` calls
/// this at startup so a typo'd mask or an impossible block shape
/// aborts before step 0, with the mask named in the error.  Very large
/// block-sparse blocks (witness length > 4096) get construction
/// validation only — the quadratic oracle would cost more than it
/// assures.
pub fn configured_mask_self_check(spec: MaskSpec, block_q: usize,
                                  block_k: usize, opts: ExecOptions)
                                  -> Result<()> {
    if block_q == 0 || block_k == 0 {
        bail!("streaming blocks must be ≥ 1 (got block_q={block_q}, \
               block_k={block_k}); a zero block is rejected, not \
               clamped");
    }
    let n = match spec {
        MaskSpec::BlockSparse { block, .. } => block * 4,
        _ => 32,
    };
    let mask = spec.build(n)?;
    let (bh, d) = (2usize, 8usize);
    let p = AttnParams::with_mask(d, mask)?;
    if n > 4096 {
        return Ok(());
    }
    let mut rng = crate::tensor::Rng::new(0xC0F1);
    let q = Tensor::randn(vec![bh, n, d], &mut rng);
    let k = Tensor::randn(vec![bh, n, d], &mut rng);
    let v = Tensor::randn(vec![bh, n, d], &mut rng);
    // streaming requires dividing blocks: clamp each to the largest
    // divisor of the witness length that does not exceed it
    let clamp = |b: usize| (1..=b.min(n)).rev().find(|x| n % x == 0)
        .unwrap_or(1);
    let (bq, bk) = (clamp(block_q), clamp(block_k));
    let be = opts.build();
    let oracle = mha_forward(&q, &k, &v, &p, &exec::Scalar);
    let got = mha_forward_streaming(&q, &k, &v, &p, bq, bk, be.as_ref());
    let tol = if be.precision() == Precision::Mixed { 0.5 } else { 1e-4 };
    let err = got.output.max_abs_diff(&oracle.output);
    if err > tol {
        bail!("configured mask {}: streaming forward deviates from the \
               oracle under backend {} (blocks {bq}×{bk}, max err {err}, \
               tol {tol})", spec.label(), be.name());
    }
    Ok(())
}

/// Oracle forward: materialises S and P (the unfused dataflow), f32 math.
pub fn mha_forward(q: &Tensor, k: &Tensor, v: &Tensor, p: &AttnParams,
                   be: &dyn Backend) -> ForwardResult {
    let (bh, n, _d) = dims(q, k, v);
    p.mask.check_n(n);
    let mut s = be.batch_matmul_nt(q, k);
    let mut lse = vec![0.0f32; bh * n];
    finish_scores(&mut s, &mut lse, p, be);
    ForwardResult {
        output: be.batch_matmul(&s, v),
        lse: Tensor::new(vec![bh, n], lse),
    }
}

/// Streaming forward: the fused kernel's block dataflow on the host.
///
/// Iterates K/V in `block_k` tiles per `block_q` row tile, carrying
/// (m, l, acc) and rescaling by `exp(m_prev − m_cur)` — Equation 3.
/// Tiles are independent `(bh, q-block)` units fanned out over the
/// backend's pool.  The enumeration is skip-aware: key tiles outside
/// the mask ([`Mask::tile_live`]) are never streamed, and a query tile
/// with no live key tile is never packed into a task at all — its
/// rows keep the pre-initialised zero output and `-inf` LSE sentinel.
/// Task builders declare only the live write-sets, so the debug-build
/// race detector covers exactly the scheduled work.  Under a
/// mixed-precision backend, Q/K/V are quantized to bf16 once here and
/// the P tiles are quantized before the P·V accumulation (see the
/// module docs); statistics and accumulators stay f32.
///
/// `block_q`/`block_k` must be ≥ 1 (0 is rejected, not clamped);
/// values larger than `n` are clamped down to `n`.
pub fn mha_forward_streaming(q: &Tensor, k: &Tensor, v: &Tensor,
                             p: &AttnParams, block_q: usize,
                             block_k: usize, be: &dyn Backend)
                             -> ForwardResult {
    assert!(block_q >= 1 && block_k >= 1,
            "streaming blocks must be ≥ 1 (got block_q={block_q}, \
             block_k={block_k}); a zero block is a misconfiguration, \
             not a request for the smallest tile");
    let mixed = be.precision() == Precision::Mixed;
    let qx;
    let kx;
    let vx;
    let (q, k, v) = if mixed {
        qx = q.clone().quantize_bf16();
        kx = k.clone().quantize_bf16();
        vx = v.clone().quantize_bf16();
        (&qx, &kx, &vx)
    } else {
        (q, k, v)
    };
    let (bh, n, d) = dims(q, k, v);
    p.mask.check_n(n);
    let bq = block_q.min(n).max(1);
    let bk = block_k.min(n).max(1);
    assert!(n % bq == 0 && n % bk == 0,
            "n={n} must be divisible by blocks ({bq},{bk})");
    let qd = q.data();
    let kd = k.data();
    let vd = v.data();
    let mut out = vec![0.0f32; bh * n * d];
    // pre-seeded with the fully-masked sentinel: rows of query tiles
    // that are never scheduled keep -inf here and 0.0 in `out`
    let mut lse = vec![f32::NEG_INFINITY; bh * n];
    {
        let mut orest: &mut [f32] = &mut out;
        let mut lrest: &mut [f32] = &mut lse;
        let mut tasks: Vec<Task<'_>> = Vec::new();
        for b in 0..bh {
            for iq in (0..n).step_by(bq) {
                let otile = exec::carve(&mut orest, bq * d);
                let ltile = exec::carve(&mut lrest, bq);
                if !(0..n).step_by(bk)
                    .any(|ik| p.mask.tile_live(iq, bq, ik, bk))
                {
                    continue; // no live key tile: never becomes a task
                }
                exec::pool::declare_task_writes(&[
                    exec::pool::span(&*otile),
                    exec::pool::span(&*ltile),
                ]);
                tasks.push(Box::new(move || {
                    streaming_fwd_tile(qd, kd, vd, otile, ltile, p,
                                       b, iq, bq, bk, n, d, mixed);
                }));
            }
        }
        be.run_tasks(tasks);
    }
    ForwardResult {
        output: Tensor::new(vec![bh, n, d], out),
        lse: Tensor::new(vec![bh, n], lse),
    }
}

/// One `(bh, q-block)` tile of the streaming forward: sweeps the
/// mask-live K/V blocks carrying per-row (m, l) statistics and a
/// rescaled accumulator.  Tiles with no live element are skipped
/// before any packing (same predicate as the builder's task
/// enumeration).  A row that never sees a live key keeps `l = 0` and
/// is finished as exact zeros + `-inf` LSE instead of dividing into
/// NaN.  `mixed` quantizes each P value to bf16 before it enters the
/// P·V accumulation (its operand role in the second GEMM); the (m, l)
/// statistics and the accumulator itself stay f32.
fn streaming_fwd_tile(qd: &[f32], kd: &[f32], vd: &[f32], otile: &mut [f32],
                      ltile: &mut [f32], p: &AttnParams, b: usize,
                      iq: usize, bq: usize, bk: usize, n: usize, d: usize,
                      mixed: bool) {
    let mut m = vec![f32::NEG_INFINITY; bq];
    let mut l = vec![0.0f32; bq];
    let mut acc = vec![0.0f32; bq * d];
    for ik in (0..n).step_by(bk) {
        if !p.mask.tile_live(iq, bq, ik, bk) {
            continue; // provably outside the mask: never packed
        }
        // s_tile = Q_tile · K_tileᵀ · scale  (masked → -inf)
        for r in 0..bq {
            let qrow = &qd[(b * n + iq + r) * d..(b * n + iq + r + 1) * d];
            let mut srow = vec![0.0f32; bk];
            for (c, sv) in srow.iter_mut().enumerate() {
                let krow = &kd[(b * n + ik + c) * d
                               ..(b * n + ik + c + 1) * d];
                let mut dot = 0.0;
                for (x, y) in qrow.iter().zip(krow) {
                    dot += x * y;
                }
                *sv = if p.mask.live(iq + r, ik + c) {
                    dot * p.scale
                } else {
                    f32::NEG_INFINITY
                };
            }
            // online softmax update for row r
            let m_cur = srow.iter().cloned().fold(m[r], f32::max);
            if m_cur == f32::NEG_INFINITY {
                continue; // row fully masked so far: exp(-inf − -inf)
                          // is NaN, so skip the update entirely
            }
            let alpha = if m[r] == f32::NEG_INFINITY {
                0.0
            } else {
                (m[r] - m_cur).exp()
            };
            let mut psum = 0.0;
            let arow = &mut acc[r * d..(r + 1) * d];
            for x in arow.iter_mut() {
                *x *= alpha;
            }
            for (c, &sv) in srow.iter().enumerate() {
                let pv = (sv - m_cur).exp();
                let pv = if mixed { bf16::quantize(pv) } else { pv };
                psum += pv;
                if pv != 0.0 {
                    let vrow = &vd[(b * n + ik + c) * d
                                   ..(b * n + ik + c + 1) * d];
                    for (a, &vv) in arow.iter_mut().zip(vrow) {
                        *a += pv * vv;
                    }
                }
            }
            l[r] = l[r] * alpha + psum;
            m[r] = m_cur;
        }
    }
    for r in 0..bq {
        let arow = &acc[r * d..(r + 1) * d];
        let orow = &mut otile[r * d..(r + 1) * d];
        if l[r] == 0.0 {
            // no live key anywhere in this row (l ≥ 1 otherwise: the
            // max element contributes exp(0) = 1): sentinel contract
            for o in orow.iter_mut() {
                *o = 0.0;
            }
            ltile[r] = f32::NEG_INFINITY;
        } else {
            for (o, &a) in orow.iter_mut().zip(arow) {
                *o = a / l[r];
            }
            ltile[r] = m[r] + l[r].ln();
        }
    }
}

/// Oracle backward (Equation 4), recomputing the forward internally.
pub fn mha_backward(q: &Tensor, k: &Tensor, v: &Tensor, dout: &Tensor,
                    p: &AttnParams, be: &dyn Backend) -> Grads {
    let (bh, n, _d) = dims(q, k, v);
    p.mask.check_n(n);
    let mut s = be.batch_matmul_nt(q, k);
    let mut lse_scratch = vec![0.0f32; bh * n];
    finish_scores(&mut s, &mut lse_scratch, p, be);
    let pm = s; // P (fully-masked rows are exact zeros → zero grads)

    // dV = Pᵀ · dO
    let dv = be.batch_matmul_tn(&pm, dout);
    // dP = dO · Vᵀ
    let dp = be.batch_matmul_nt(dout, v);
    // dS = P ∘ (dP − rowsum(P ∘ dP)), row-parallel
    let mut ds = pm.clone();
    {
        let pd = pm.data();
        let dpd = dp.data();
        exec::par_row_chunks(be, ds.data_mut(), n, SOFTMAX_ROWS_PER_TASK,
                             |ci, chunk| {
            let base = ci * SOFTMAX_ROWS_PER_TASK;
            for (ri, dsrow) in chunk.chunks_exact_mut(n).enumerate() {
                let r = base + ri;
                let prow = &pd[r * n..(r + 1) * n];
                let dprow = &dpd[r * n..(r + 1) * n];
                let dsum: f32 =
                    prow.iter().zip(dprow).map(|(a, b)| a * b).sum();
                for ((dsv, &pv), &dpv) in
                    dsrow.iter_mut().zip(prow).zip(dprow)
                {
                    *dsv = pv * (dpv - dsum);
                }
            }
        });
    }
    // dQ = dS · K · scale;  dK = dSᵀ · Q · scale
    let dq = be.batch_matmul(&ds, k).scale(p.scale);
    let dk = be.batch_matmul_tn(&ds, q).scale(p.scale);
    Grads { dq, dk, dv }
}

/// Matmul FLOPs of one MHA (Fig 10/11 TFLOPs denominator; mirrors
/// `python/compile/kernels/ref.py::attention_flops`).  Coarse paper
/// accounting: dense `n²` with a flat ÷2 for causal.  For exact
/// per-mask counts use [`attention_flops_masked`].
pub fn attention_flops(bh: usize, n: usize, d: usize, causal: bool,
                       backward: bool) -> u64 {
    let matmuls: u64 = if backward { 5 } else { 2 };
    let flops = matmuls * 2 * (n as u64) * (n as u64) * (d as u64)
        * (bh as u64);
    if causal { flops / 2 } else { flops }
}

/// Exact matmul FLOPs of one masked MHA: every GEMM touches only the
/// mask's live score elements ([`Mask::live_elements`]), so dense
/// reproduces [`attention_flops`] and a sliding window scales as
/// `n·w` instead of `n²` — the per-mask TFLOPs denominator for the
/// bench rows.
pub fn attention_flops_masked(bh: usize, n: usize, d: usize, mask: &Mask,
                              backward: bool) -> u64 {
    let matmuls: u64 = if backward { 5 } else { 2 };
    matmuls * 2 * (mask.live_elements(n) as u64) * (d as u64) * (bh as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{Blocked, Scalar};
    use crate::tensor::Rng;

    fn rand_qkv(bh: usize, n: usize, d: usize, seed: u64)
                -> (Tensor, Tensor, Tensor) {
        let mut r = Rng::new(seed);
        (Tensor::randn(vec![bh, n, d], &mut r),
         Tensor::randn(vec![bh, n, d], &mut r),
         Tensor::randn(vec![bh, n, d], &mut r))
    }

    #[test]
    fn forward_uniform_attention_averages_v() {
        // q = 0 → uniform softmax → output = column mean of V
        let (_, k, v) = rand_qkv(1, 8, 4, 1);
        let q = Tensor::zeros(vec![1, 8, 4]);
        let p = AttnParams::new(4, false).unwrap();
        let r = mha_forward(&q, &k, &v, &p, &Scalar);
        let vd = v.data();
        for c in 0..4 {
            let mean: f32 = (0..8).map(|i| vd[i * 4 + c]).sum::<f32>() / 8.0;
            for i in 0..8 {
                assert!((r.output.at(&[0, i, c]) - mean).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn causal_first_row_copies_v0() {
        let (q, k, v) = rand_qkv(2, 16, 8, 2);
        let p = AttnParams::new(8, true).unwrap();
        let r = mha_forward(&q, &k, &v, &p, &Scalar);
        for b in 0..2 {
            for c in 0..8 {
                assert!((r.output.at(&[b, 0, c]) - v.at(&[b, 0, c])).abs()
                        < 1e-5, "row 0 must attend only to position 0");
            }
        }
    }

    #[test]
    fn d_zero_is_rejected_at_construction() {
        let err = AttnParams::new(0, false).unwrap_err().to_string();
        assert!(err.contains("d = 0"), "{err}");
        assert!(AttnParams::with_mask(0, Mask::Causal).is_err());
    }

    #[test]
    #[should_panic(expected = "streaming blocks must be ≥ 1")]
    fn zero_block_q_is_rejected_not_clamped() {
        let (q, k, v) = rand_qkv(1, 8, 4, 1);
        let p = AttnParams::new(4, false).unwrap();
        mha_forward_streaming(&q, &k, &v, &p, 0, 8, &Scalar);
    }

    #[test]
    #[should_panic(expected = "streaming blocks must be ≥ 1")]
    fn zero_block_k_is_rejected_not_clamped() {
        let (q, k, v) = rand_qkv(1, 8, 4, 1);
        let p = AttnParams::new(4, false).unwrap();
        mha_forward_streaming(&q, &k, &v, &p, 8, 0, &Scalar);
    }

    #[test]
    fn streaming_matches_oracle_full() {
        let (q, k, v) = rand_qkv(2, 32, 8, 3);
        let p = AttnParams::new(8, false).unwrap();
        let a = mha_forward(&q, &k, &v, &p, &Scalar);
        for (bq, bk) in [(32, 32), (8, 8), (16, 4), (4, 16), (1, 1)] {
            let b = mha_forward_streaming(&q, &k, &v, &p, bq, bk, &Scalar);
            assert!(a.output.max_abs_diff(&b.output) < 1e-4,
                    "blocks ({bq},{bk})");
            assert!(a.lse.max_abs_diff(&b.lse) < 1e-4);
        }
    }

    #[test]
    fn streaming_matches_oracle_causal() {
        let (q, k, v) = rand_qkv(2, 32, 8, 4);
        let p = AttnParams::new(8, true).unwrap();
        let a = mha_forward(&q, &k, &v, &p, &Scalar);
        for (bq, bk) in [(8, 8), (16, 8), (8, 16)] {
            let b = mha_forward_streaming(&q, &k, &v, &p, bq, bk, &Scalar);
            assert!(a.output.max_abs_diff(&b.output) < 1e-4,
                    "blocks ({bq},{bk})");
        }
    }

    #[test]
    fn streaming_matches_oracle_sliding_window() {
        let (q, k, v) = rand_qkv(2, 32, 8, 14);
        for w in [1usize, 3, 8, 40] {
            let p = AttnParams::with_mask(8, Mask::SlidingWindow { w })
                .unwrap();
            let a = mha_forward(&q, &k, &v, &p, &Scalar);
            for (bq, bk) in [(8, 8), (16, 4), (4, 16), (32, 32)] {
                let b =
                    mha_forward_streaming(&q, &k, &v, &p, bq, bk, &Scalar);
                assert!(a.output.max_abs_diff(&b.output) < 1e-4,
                        "w={w} blocks ({bq},{bk})");
                assert!(a.lse.max_abs_diff(&b.lse) < 1e-4, "w={w}");
            }
        }
    }

    #[test]
    fn streaming_matches_oracle_block_sparse() {
        let (q, k, v) = rand_qkv(2, 32, 8, 15);
        let layout = BlockLayout::random(8, 4, 40, 3).unwrap();
        let p = AttnParams::with_mask(8, Mask::BlockSparse { layout })
            .unwrap();
        let a = mha_forward(&q, &k, &v, &p, &Scalar);
        for (bq, bk) in [(8, 8), (16, 8), (4, 4), (32, 16)] {
            let b = mha_forward_streaming(&q, &k, &v, &p, bq, bk, &Scalar);
            assert!(a.output.max_abs_diff(&b.output) < 1e-4,
                    "blocks ({bq},{bk})");
            assert!(a.lse.max_abs_diff(&b.lse) < 1e-4);
        }
    }

    /// The headline bugfix regression: a fully-masked row must be
    /// exact zeros with an LSE of -inf — not uniform attention over
    /// forbidden keys (fused path) and not NaN from l = 0 (streaming
    /// path) — bitwise-identically across backends and thread counts.
    #[test]
    fn fully_masked_rows_are_zeros_with_lse_sentinel() {
        let (q, k, v) = rand_qkv(2, 16, 8, 7);
        // window of width 0 masks every (i, j): every row is the edge
        let p = AttnParams::with_mask(8, Mask::SlidingWindow { w: 0 })
            .unwrap();
        let fused = mha_forward(&q, &k, &v, &p, &Scalar);
        let stream = mha_forward_streaming(&q, &k, &v, &p, 4, 8, &Scalar);
        for r in [&fused, &stream] {
            for &x in r.output.data() {
                assert_eq!(x.to_bits(), 0.0f32.to_bits(),
                           "output must be exact zeros, got {x}");
            }
            for &x in r.lse.data() {
                assert_eq!(x, f32::NEG_INFINITY, "LSE sentinel");
            }
        }
        // bitwise across backends and thread counts
        for threads in [1usize, 2, 8] {
            for be in [&Blocked::new(threads) as &dyn Backend,
                       &exec::Simd::new(threads, Precision::F32)] {
                let f = mha_forward(&q, &k, &v, &p, be);
                let s = mha_forward_streaming(&q, &k, &v, &p, 4, 8, be);
                assert_eq!(fused.output.data(), f.output.data());
                assert_eq!(fused.lse.data(), f.lse.data());
                assert_eq!(stream.output.data(), s.output.data());
                assert_eq!(stream.lse.data(), s.lse.data());
            }
        }
        // the oracle backward of an all-masked pattern is zero grads
        let dout = Tensor::full(vec![2, 16, 8], 1.0);
        let g = mha_backward(&q, &k, &v, &dout, &p, &Scalar);
        for t in [&g.dq, &g.dk, &g.dv] {
            for &x in t.data() {
                assert_eq!(x, 0.0, "masked rows must carry zero grads");
            }
        }
    }

    /// Same contract reached through a `BlockSparse` row with no live
    /// blocks, with the other rows still live (mixed live/dead rows in
    /// one launch).
    #[test]
    fn block_sparse_empty_row_is_zero_others_match_oracle() {
        let (q, k, v) = rand_qkv(1, 16, 4, 8);
        // 4×4 grid of 4-wide blocks; query block-row 1 fully dead
        let mut live = vec![true; 16];
        for bj in 0..4 {
            live[4 + bj] = false;
        }
        let layout = BlockLayout::new(4, 4, live).unwrap();
        let p = AttnParams::with_mask(4, Mask::BlockSparse { layout })
            .unwrap();
        let fused = mha_forward(&q, &k, &v, &p, &Scalar);
        let stream = mha_forward_streaming(&q, &k, &v, &p, 4, 4, &Scalar);
        for r in [&fused, &stream] {
            for i in 4..8 {
                for c in 0..4 {
                    assert_eq!(r.output.at(&[0, i, c]), 0.0,
                               "dead row {i} must be zero");
                }
                assert_eq!(r.lse.at(&[0, i]), f32::NEG_INFINITY);
            }
            for i in (0..4).chain(8..16) {
                assert!(r.lse.at(&[0, i]).is_finite(),
                        "live row {i} must have finite LSE");
            }
        }
        assert!(fused.output.max_abs_diff(&stream.output) < 1e-4);
    }

    #[test]
    fn backends_agree_bitwise_on_forward() {
        let (q, k, v) = rand_qkv(3, 32, 16, 9);
        for causal in [false, true] {
            let p = AttnParams::new(16, causal).unwrap();
            let a = mha_forward(&q, &k, &v, &p, &Scalar);
            for threads in [1usize, 2, 8] {
                let b = mha_forward(&q, &k, &v, &p, &Blocked::new(threads));
                assert_eq!(a.output.data(), b.output.data(),
                           "causal={causal} threads={threads}");
                assert_eq!(a.lse.data(), b.lse.data());
            }
        }
    }

    #[test]
    fn streaming_thread_count_invariant() {
        let (q, k, v) = rand_qkv(2, 64, 8, 10);
        let p = AttnParams::new(8, true).unwrap();
        let base = mha_forward_streaming(&q, &k, &v, &p, 16, 16,
                                         &Blocked::new(1));
        for threads in [2usize, 8] {
            let got = mha_forward_streaming(&q, &k, &v, &p, 16, 16,
                                            &Blocked::new(threads));
            assert_eq!(base.output.data(), got.output.data(),
                       "threads={threads}");
            assert_eq!(base.lse.data(), got.lse.data());
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        let (q, k, v) = rand_qkv(1, 6, 4, 5);
        let p = AttnParams::new(4, false).unwrap();
        let dout = Tensor::full(vec![1, 6, 4], 1.0);
        let g = mha_backward(&q, &k, &v, &dout, &p, &Scalar);
        let eps = 1e-3f32;
        let f = |q: &Tensor, k: &Tensor, v: &Tensor| -> f32 {
            mha_forward(q, k, v, &p, &Scalar).output.data().iter().sum()
        };
        // spot-check several coordinates of dq, dk, dv
        for (which, grad) in [("q", &g.dq), ("k", &g.dk), ("v", &g.dv)] {
            for idx in [0usize, 7, 13, 23] {
                let (mut qp, mut kp, mut vp) =
                    (q.clone(), k.clone(), v.clone());
                let bump = |qp: &mut Tensor, kp: &mut Tensor,
                            vp: &mut Tensor, delta: f32| {
                    let t = match which {
                        "q" => qp,
                        "k" => kp,
                        _ => vp,
                    };
                    t.data_mut()[idx] += delta;
                };
                bump(&mut qp, &mut kp, &mut vp, eps);
                let up = f(&qp, &kp, &vp);
                bump(&mut qp, &mut kp, &mut vp, -2.0 * eps);
                let dn = f(&qp, &kp, &vp);
                let fd = (up - dn) / (2.0 * eps);
                let an = grad.data()[idx];
                assert!((fd - an).abs() < 2e-2,
                        "d{which}[{idx}]: fd={fd} analytic={an}");
            }
        }
    }

    #[test]
    fn witness_self_check_passes_pairwise() {
        witness_self_check(ExecOptions::scalar()).unwrap();
        witness_self_check(ExecOptions::default()).unwrap();
        witness_self_check(
            ExecOptions::simd(3, exec::Precision::Mixed)).unwrap();
    }

    #[test]
    fn simd_f32_forward_is_bitwise_scalar() {
        let (q, k, v) = rand_qkv(2, 32, 8, 11);
        for causal in [false, true] {
            let p = AttnParams::new(8, causal).unwrap();
            let want = mha_forward(&q, &k, &v, &p, &Scalar);
            for threads in [1usize, 2, 8] {
                let be = exec::Simd::new(threads, exec::Precision::F32);
                let got = mha_forward(&q, &k, &v, &p, &be);
                assert_eq!(want.output.data(), got.output.data(),
                           "causal={causal} threads={threads}");
                assert_eq!(want.lse.data(), got.lse.data());
                let stream = mha_forward_streaming(&q, &k, &v, &p, 8, 8,
                                                   &be);
                let stream_s = mha_forward_streaming(&q, &k, &v, &p, 8, 8,
                                                     &Scalar);
                assert_eq!(stream_s.output.data(), stream.output.data());
            }
        }
    }

    #[test]
    fn mixed_streaming_matches_quantized_scalar_reference() {
        // Under the mixed backend the streaming forward must equal the
        // f32 streaming forward of bf16-quantized inputs, up to the
        // P-tile quantization: |Δout| ≤ ~3·ε_bf16·max|v| per element.
        let (q, k, v) = rand_qkv(2, 32, 8, 12);
        let qq = q.clone().quantize_bf16();
        let kq = k.clone().quantize_bf16();
        let vq = v.clone().quantize_bf16();
        let vmax = v.data().iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let tol = 16.0 * crate::tensor::bf16::EPSILON * (1.0 + vmax);
        for causal in [false, true] {
            let p = AttnParams::new(8, causal).unwrap();
            let want = mha_forward_streaming(&qq, &kq, &vq, &p, 8, 8,
                                             &Scalar);
            let be = exec::Simd::new(2, exec::Precision::Mixed);
            let got = mha_forward_streaming(&q, &k, &v, &p, 8, 8, &be);
            let err = got.output.max_abs_diff(&want.output);
            assert!(err < tol, "causal={causal}: err {err} ≥ tol {tol}");
        }
    }

    #[test]
    fn lse_is_finite() {
        let (q, k, v) = rand_qkv(1, 16, 8, 6);
        let p = AttnParams::new(8, false).unwrap();
        let r = mha_forward(&q, &k, &v, &p, &Scalar);
        for &x in r.lse.data() {
            assert!(x.is_finite());
        }
    }

    #[test]
    fn flops_halve_under_causal() {
        assert_eq!(attention_flops(4, 256, 64, true, false) * 2,
                   attention_flops(4, 256, 64, false, false));
        // backward = 5 matmuls vs forward 2
        assert_eq!(attention_flops(1, 128, 64, false, true) * 2,
                   attention_flops(1, 128, 64, false, false) * 5);
    }

    #[test]
    fn masked_flops_are_exact() {
        // dense reproduces the coarse accounting exactly
        assert_eq!(attention_flops_masked(4, 256, 64, &Mask::Dense, false),
                   attention_flops(4, 256, 64, false, false));
        // causal is n(n+1)/2 live elements — exact, not the flat ÷2
        assert_eq!(attention_flops_masked(1, 4, 2, &Mask::Causal, false),
                   2 * 2 * 10 * 2);
        // a window of width w ≪ n is linear in n
        let w = Mask::SlidingWindow { w: 4 };
        let f1 = attention_flops_masked(1, 256, 8, &w, false);
        let f2 = attention_flops_masked(1, 512, 8, &w, false);
        assert!(f2 < 2 * f1 + 8 * 4 * 4 * 2 * 2,
                "window flops must scale ~linearly: {f1} → {f2}");
    }
}
