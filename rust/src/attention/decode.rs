//! Decode-step attention: one query token against a paged K/V cache.
//!
//! Serving appends a single token per sequence per scheduler step, so
//! the forward pass degenerates to one query row per head attending to
//! the cached history — the `bq = 1` corner of the streaming tiling.
//! [`decode_step`] replays `streaming_fwd_tile`'s per-row online
//! softmax *exactly*: the same score computation (mul-then-add dot in
//! key order, masked logits to `-inf`), the same `(m, l, acc)` update
//! with `alpha = exp(m_prev − m_cur)` rescaling, the same
//! fully-masked-row contract (exact zeros + `-inf` LSE), and the same
//! `pv != 0` accumulation skip.  Each cache block plays the role of
//! one `block_k` key tile, so when the cache's `block_tokens` divides
//! the prefix length the result is **bitwise identical** to row
//! `pos` of [`super::mha_forward_streaming`] with `block_k =
//! block_tokens` over the same prefix — the property the serve tests
//! pin.  (Processing order over key blocks is the only degree of
//! freedom, and a key block that is dead for this row is a bitwise
//! no-op either way: with `m = -inf` the update is skipped outright,
//! and with `m > -inf` it multiplies the accumulator by
//! `exp(0) = 1.0` exactly and adds `exp(-inf) = 0.0` to `l`.)
//!
//! **Masks.**  `i` is the query's absolute position `pos`, `j` a
//! cached key's absolute position — so `Mask::Causal` is always live
//! (the cache only holds the past), `SlidingWindow` drops keys older
//! than `w`, and `BlockSparse` must cover `pos` (its layout `n` bounds
//! the sequence, checked here like `check_n` does for the full paths).
//!
//! **Precision.**  `mixed` quantizes the query row once and each
//! cached K/V element at its operand boundary — bf16 quantization is
//! idempotent, so this is bitwise-equivalent to the streaming path's
//! quantize-whole-tensors-at-entry under the same inputs.

use crate::tensor::bf16;
use crate::tensor::paged::KvBlockView;

use super::AttnParams;

/// Fold one cached K/V block into a single query row's online-softmax
/// state `(m, l, acc)` — the per-(row, key-tile) update of
/// `streaming_fwd_tile`, verbatim.  `qrow` is the head's `d`-length
/// query slice, already bf16-quantized when `mixed`; `pos` is the
/// row's absolute sequence position.  Shared by [`decode_step`]
/// (`bq = 1`, one block per step) and
/// [`super::prefill::prefill_chunk`] (many rows × many blocks), so
/// the two entry points cannot drift apart bitwise.
pub(crate) fn fold_kv_block(qrow: &[f32], blk: &KvBlockView<'_>,
                            h: usize, d: usize, width: usize,
                            pos: usize, p: &AttnParams, mixed: bool,
                            m: &mut f32, l: &mut f32, acc: &mut [f32]) {
    debug_assert!(blk.tokens >= 1);
    if !p.mask.tile_live(pos, 1, blk.start, blk.tokens) {
        return; // provably outside the mask, like streaming
    }
    // srow = q · K_blockᵀ · scale  (masked → -inf), key order
    let mut srow = vec![0.0f32; blk.tokens];
    for (c, sv) in srow.iter_mut().enumerate() {
        let krow = &blk.k[c * width + h * d..c * width + (h + 1) * d];
        let mut dot = 0.0;
        for (x, &y) in qrow.iter().zip(krow) {
            let y = if mixed { bf16::quantize(y) } else { y };
            dot += x * y;
        }
        *sv = if p.mask.live(pos, blk.start + c) {
            dot * p.scale
        } else {
            f32::NEG_INFINITY
        };
    }
    // online softmax update — streaming_fwd_tile verbatim
    let m_cur = srow.iter().cloned().fold(*m, f32::max);
    if m_cur == f32::NEG_INFINITY {
        return; // row fully masked so far
    }
    let alpha = if *m == f32::NEG_INFINITY {
        0.0
    } else {
        (*m - m_cur).exp()
    };
    let mut psum = 0.0;
    for x in acc.iter_mut() {
        *x *= alpha;
    }
    for (c, &sv) in srow.iter().enumerate() {
        let pv = (sv - m_cur).exp();
        let pv = if mixed { bf16::quantize(pv) } else { pv };
        psum += pv;
        if pv != 0.0 {
            let vrow =
                &blk.v[c * width + h * d..c * width + (h + 1) * d];
            for (a, &vv) in acc.iter_mut().zip(vrow) {
                let vv = if mixed { bf16::quantize(vv) } else { vv };
                *a += pv * vv;
            }
        }
    }
    *l = *l * alpha + psum;
    *m = m_cur;
}

/// Turn a finished `(m, l, acc)` row state into output + LSE, with the
/// fully-masked contract (`l == 0` ⟹ exact zeros, `-inf` sentinel).
pub(crate) fn finalize_row(m: f32, l: f32, acc: &[f32],
                           orow: &mut [f32], lse: &mut f32) {
    if l == 0.0 {
        for o in orow.iter_mut() {
            *o = 0.0;
        }
        *lse = f32::NEG_INFINITY;
    } else {
        for (o, &a) in orow.iter_mut().zip(acc) {
            *o = a / l;
        }
        *lse = m + l.ln();
    }
}

/// One decode step for one sequence: the query row `q` (`heads · d`
/// f32s, the token at absolute position `pos`) attends to the cached
/// history in `blocks` (which must cover exactly positions
/// `0..=pos`).  Writes the attention output into `out` (`heads · d`)
/// and the per-head log-sum-exp into `lse` (`heads`); a head whose
/// row is fully masked gets exact zeros and the `-inf` sentinel,
/// matching the streaming contract.
pub fn decode_step(q: &[f32], blocks: &[KvBlockView<'_>], heads: usize,
                   d: usize, pos: usize, p: &AttnParams, mixed: bool,
                   out: &mut [f32], lse: &mut [f32]) {
    let width = heads * d;
    assert!(heads > 0 && d > 0, "decode needs heads ≥ 1 and d ≥ 1");
    assert_eq!(q.len(), width, "query row must be heads·d");
    assert_eq!(out.len(), width, "output row must be heads·d");
    assert_eq!(lse.len(), heads, "lse must have one slot per head");
    let cached: usize = blocks.iter().map(|b| b.tokens).sum();
    assert_eq!(cached, pos + 1,
               "cache holds {cached} tokens but the query sits at \
                position {pos}: append the query's own K/V first");
    if let super::Mask::BlockSparse { layout } = &p.mask {
        assert!(pos < layout.n(),
                "block-sparse layout covers n={} but decode position \
                 is {pos}", layout.n());
    }

    for h in 0..heads {
        let mut m = f32::NEG_INFINITY;
        let mut l = 0.0f32;
        let mut acc = vec![0.0f32; d];
        let qrow: Vec<f32> = q[h * d..(h + 1) * d].iter()
            .map(|&x| if mixed { bf16::quantize(x) } else { x })
            .collect();
        for blk in blocks {
            fold_kv_block(&qrow, blk, h, d, width, pos, p, mixed,
                          &mut m, &mut l, &mut acc);
        }
        finalize_row(m, l, &acc, &mut out[h * d..(h + 1) * d],
                     &mut lse[h]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{mha_forward, mha_forward_streaming,
                           BlockLayout, Mask};
    use crate::exec::{ExecOptions, Scalar};
    use crate::tensor::paged::{KvCache, SeqKv};
    use crate::tensor::{Rng, Tensor};

    /// Masks exercised by every equivalence test; `n` is the full
    /// sequence length the cache grows to.
    fn mask_roster(n: usize) -> Vec<Mask> {
        vec![
            Mask::Dense,
            Mask::Causal,
            Mask::SlidingWindow { w: 1 },
            Mask::SlidingWindow { w: 3 },
            Mask::SlidingWindow { w: n },
            Mask::BlockSparse {
                layout: BlockLayout::random(n / 4, 4, 30, 7).unwrap(),
            },
        ]
    }

    /// Fills a cache with the rows of (heads, n, d) K/V tensors and
    /// returns the per-token flattened (heads·d) query rows.
    fn fill_cache(c: &mut KvCache, s: &mut SeqKv, k: &Tensor, v: &Tensor,
                  upto: usize, heads: usize, d: usize, n: usize) {
        let width = heads * d;
        for t in 0..upto {
            let mut krow = vec![0.0f32; width];
            let mut vrow = vec![0.0f32; width];
            for h in 0..heads {
                let base = (h * n + t) * d;
                krow[h * d..(h + 1) * d]
                    .copy_from_slice(&k.data()[base..base + d]);
                vrow[h * d..(h + 1) * d]
                    .copy_from_slice(&v.data()[base..base + d]);
            }
            c.append(s, &krow, &vrow).unwrap();
        }
    }

    fn qrow_flat(q: &Tensor, t: usize, heads: usize, d: usize, n: usize)
                 -> Vec<f32> {
        let mut row = vec![0.0f32; heads * d];
        for h in 0..heads {
            let base = (h * n + t) * d;
            row[h * d..(h + 1) * d]
                .copy_from_slice(&q.data()[base..base + d]);
        }
        row
    }

    // Bitwise: when block_tokens divides the prefix length, every
    // decode step equals the matching row of the streaming forward
    // with block_k = block_tokens, for every mask variant.
    #[test]
    fn decode_is_bitwise_streaming_row() {
        let (heads, d, n, bt) = (2usize, 4usize, 8usize, 4usize);
        let mut rng = Rng::new(0xDEC0DE);
        let q = Tensor::randn(vec![heads, n, d], &mut rng);
        let k = Tensor::randn(vec![heads, n, d], &mut rng);
        let v = Tensor::randn(vec![heads, n, d], &mut rng);
        for mask in mask_roster(n) {
            let p = AttnParams::with_mask(d, mask).unwrap();
            let mut cache = KvCache::new(n / bt + 1, bt, heads, d);
            let mut seq = SeqKv::new();
            for pos in 0..n {
                // append exactly token `pos`'s K/V, then decode it
                let width = heads * d;
                let mut krow = vec![0.0f32; width];
                let mut vrow = vec![0.0f32; width];
                for h in 0..heads {
                    let base = (h * n + pos) * d;
                    krow[h * d..(h + 1) * d]
                        .copy_from_slice(&k.data()[base..base + d]);
                    vrow[h * d..(h + 1) * d]
                        .copy_from_slice(&v.data()[base..base + d]);
                }
                cache.append(&mut seq, &krow, &vrow).unwrap();
                // only compare at prefixes the streaming path can tile
                let t = pos + 1;
                if t % bt != 0 {
                    continue;
                }
                if let Mask::BlockSparse { layout } = &p.mask {
                    if layout.n() != t {
                        continue; // layout pinned to one n
                    }
                }
                let qt = Tensor::new(vec![heads, t, d],
                    (0..heads).flat_map(|h| {
                        q.data()[h * n * d..(h * n + t) * d].to_vec()
                    }).collect());
                let kt = Tensor::new(vec![heads, t, d],
                    (0..heads).flat_map(|h| {
                        k.data()[h * n * d..(h * n + t) * d].to_vec()
                    }).collect());
                let vt = Tensor::new(vec![heads, t, d],
                    (0..heads).flat_map(|h| {
                        v.data()[h * n * d..(h * n + t) * d].to_vec()
                    }).collect());
                let want = mha_forward_streaming(&qt, &kt, &vt, &p, bt,
                                                 bt, &Scalar);
                let mut out = vec![0.0f32; heads * d];
                let mut lse = vec![0.0f32; heads];
                decode_step(&qrow_flat(&q, pos, heads, d, n),
                            &cache.blocks(&seq), heads, d, pos, &p,
                            false, &mut out, &mut lse);
                for h in 0..heads {
                    let wrow = &want.output.data()
                        [(h * t + pos) * d..(h * t + pos + 1) * d];
                    let grow = &out[h * d..(h + 1) * d];
                    for (a, b) in grow.iter().zip(wrow) {
                        assert_eq!(a.to_bits(), b.to_bits(),
                                   "mask {} pos {pos} head {h}",
                                   p.mask.label());
                    }
                    let wl = want.lse.data()[h * t + pos];
                    assert_eq!(lse[h].to_bits(), wl.to_bits(),
                               "lse mask {} pos {pos} head {h}",
                               p.mask.label());
                }
            }
        }
    }

    // Tolerance: at prefixes the streaming tiling cannot represent
    // (partial tail block), decode still matches the fused oracle.
    #[test]
    fn decode_matches_oracle_at_ragged_prefixes() {
        let (heads, d, n, bt) = (2usize, 4usize, 8usize, 4usize);
        let mut rng = Rng::new(0xFACADE);
        let q = Tensor::randn(vec![heads, n, d], &mut rng);
        let k = Tensor::randn(vec![heads, n, d], &mut rng);
        let v = Tensor::randn(vec![heads, n, d], &mut rng);
        for mask in [Mask::Dense, Mask::Causal,
                     Mask::SlidingWindow { w: 3 }] {
            let p = AttnParams::with_mask(d, mask).unwrap();
            for pos in [2usize, 5, 6] {
                // a cache truncated to pos+1 tokens: rebuild
                let mut c2 = KvCache::new(n / bt + 1, bt, heads, d);
                let mut s2 = SeqKv::new();
                fill_cache(&mut c2, &mut s2, &k, &v, pos + 1, heads, d,
                           n);
                let t = pos + 1;
                let qt = Tensor::new(vec![heads, t, d],
                    (0..heads).flat_map(|h| {
                        q.data()[h * n * d..(h * n + t) * d].to_vec()
                    }).collect());
                let kt = Tensor::new(vec![heads, t, d],
                    (0..heads).flat_map(|h| {
                        k.data()[h * n * d..(h * n + t) * d].to_vec()
                    }).collect());
                let vt = Tensor::new(vec![heads, t, d],
                    (0..heads).flat_map(|h| {
                        v.data()[h * n * d..(h * n + t) * d].to_vec()
                    }).collect());
                let want = mha_forward(&qt, &kt, &vt, &p, &Scalar);
                let mut out = vec![0.0f32; heads * d];
                let mut lse = vec![0.0f32; heads];
                decode_step(&qrow_flat(&q, pos, heads, d, n),
                            &c2.blocks(&s2), heads, d, pos, &p, false,
                            &mut out, &mut lse);
                for h in 0..heads {
                    let wrow = &want.output.data()
                        [(h * t + pos) * d..(h * t + pos + 1) * d];
                    for (a, b) in out[h * d..(h + 1) * d].iter()
                        .zip(wrow)
                    {
                        assert!((a - b).abs() < 1e-5,
                                "mask {} pos {pos} head {h}: {a} vs {b}",
                                p.mask.label());
                    }
                }
            }
        }
    }

    // A fully-masked decode row (window 0 analogue can't come from the
    // spec surface, but the core Mask can express it) produces exact
    // zeros and the -inf sentinel.
    #[test]
    fn fully_masked_decode_row_is_zero_with_sentinel() {
        let (heads, d, bt) = (2usize, 3usize, 2usize);
        let p = AttnParams::with_mask(
            d, Mask::SlidingWindow { w: 0 }).unwrap();
        let mut cache = KvCache::new(4, bt, heads, d);
        let mut seq = SeqKv::new();
        let width = heads * d;
        for t in 0..3 {
            let row: Vec<f32> =
                (0..width).map(|i| (t * width + i) as f32).collect();
            cache.append(&mut seq, &row, &row).unwrap();
        }
        let qv = vec![1.0f32; width];
        let mut out = vec![9.0f32; width];
        let mut lse = vec![9.0f32; heads];
        decode_step(&qv, &cache.blocks(&seq), heads, d, 2, &p, false,
                    &mut out, &mut lse);
        assert!(out.iter().all(|x| x.to_bits() == 0));
        assert!(lse.iter().all(|x| *x == f32::NEG_INFINITY));
    }

    // Mixed precision: decode's quantize-at-read equals streaming's
    // quantize-at-entry bitwise.
    #[test]
    fn mixed_decode_is_bitwise_mixed_streaming_row() {
        let (heads, d, n, bt) = (2usize, 4usize, 8usize, 4usize);
        let mut rng = Rng::new(0xB16B00);
        let q = Tensor::randn(vec![heads, n, d], &mut rng);
        let k = Tensor::randn(vec![heads, n, d], &mut rng);
        let v = Tensor::randn(vec![heads, n, d], &mut rng);
        let p = AttnParams::new(d, true).unwrap();
        let be =
            ExecOptions::simd(2, crate::exec::Precision::Mixed).build();
        let want = mha_forward_streaming(&q, &k, &v, &p, bt, bt,
                                         be.as_ref());
        let mut cache = KvCache::new(n / bt, bt, heads, d);
        let mut seq = SeqKv::new();
        fill_cache(&mut cache, &mut seq, &k, &v, n, heads, d, n);
        let pos = n - 1;
        let mut out = vec![0.0f32; heads * d];
        let mut lse = vec![0.0f32; heads];
        decode_step(&qrow_flat(&q, pos, heads, d, n),
                    &cache.blocks(&seq), heads, d, pos, &p, true,
                    &mut out, &mut lse);
        for h in 0..heads {
            let wrow = &want.output.data()
                [(h * n + pos) * d..(h * n + pos + 1) * d];
            for (a, b) in out[h * d..(h + 1) * d].iter().zip(wrow) {
                assert_eq!(a.to_bits(), b.to_bits(), "head {h}");
            }
            assert_eq!(lse[h].to_bits(),
                       want.lse.data()[h * n + pos].to_bits());
        }
    }
}
