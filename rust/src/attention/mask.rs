//! Structured attention masks and skip-aware tile enumeration.
//!
//! A [`Mask`] describes which (query, key) score entries are *live*.
//! The attention paths consult it at three granularities:
//!
//! * **element** — [`Mask::live`] decides whether a single logit is
//!   kept (scaled) or replaced by `-inf` before the softmax;
//! * **tile** — [`Mask::tile_live`] decides whether a `(block_q,
//!   block_k)` score tile can contain *any* live element.  The
//!   streaming fwd/bwd tilings never pack, schedule, or stream a tile
//!   for which this returns `false`, and the `iomodel` traffic
//!   accounting drops the same tiles (see
//!   [`crate::iomodel::analytic_fused_fwd_masked`]);
//! * **row** — a query row with no live element at all is defined to
//!   produce an exactly-zero output row with an LSE of `-inf` (the
//!   sentinel), identically in the fused oracle and both streaming
//!   paths, bitwise across every backend and thread count.
//!
//! `tile_live` is **exact**: it returns `true` iff at least one
//! element in the tile is live (property-tested against a brute-force
//! element scan), so a skipped tile is provably outside the mask and
//! the live/skipped counts from [`Mask::tile_counts`] are the ground
//! truth the traffic model and the pool's task set must both match.
//!
//! [`MaskSpec`] is the sequence-length-independent description used by
//! config (`[attention] mask`), the CLI (`--mask`/`--window`), and the
//! bench harness; [`MaskSpec::build`] instantiates it for a concrete
//! `n`.

use anyhow::{bail, Result};

/// Block-granular sparsity layout for [`Mask::BlockSparse`]: an
/// `nblocks × nblocks` boolean grid over square `block × block` score
/// tiles, row-major (`live[bi * nblocks + bj]` is the block covering
/// queries `bi*block..` and keys `bj*block..`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockLayout {
    block: usize,
    nblocks: usize,
    live: Vec<bool>,
}

/// SplitMix64 finalizer: the deterministic, allocation-free hash used
/// to draw pseudo-random block layouts (no `HashMap`, no wall clock —
/// the analyzer's determinism rules apply to this module).
fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl BlockLayout {
    /// Builds a layout from an explicit row-major liveness grid.
    /// `live.len()` must equal `nblocks * nblocks`; `block` and
    /// `nblocks` must be non-zero.
    pub fn new(block: usize, nblocks: usize, live: Vec<bool>) -> Result<Self> {
        if block == 0 || nblocks == 0 {
            bail!("block-sparse layout needs block ≥ 1 and nblocks ≥ 1 \
                   (got block={block}, nblocks={nblocks})");
        }
        if live.len() != nblocks * nblocks {
            bail!("block-sparse layout grid has {} entries, expected \
                   nblocks² = {}",
                  live.len(), nblocks * nblocks);
        }
        Ok(Self { block, nblocks, live })
    }

    /// Deterministic pseudo-random layout: the diagonal is always live
    /// (so no query row is fully masked by accident in benches), and
    /// each off-diagonal block is live with probability
    /// `density_pct / 100`, drawn from a splitmix hash of
    /// `(bi, bj, seed)` — same layout for the same arguments on every
    /// platform and run.
    pub fn random(block: usize, nblocks: usize, density_pct: usize,
                  seed: u64) -> Result<Self> {
        if density_pct > 100 {
            bail!("block-sparse density must be 0..=100 percent \
                   (got {density_pct})");
        }
        let live = (0..nblocks * nblocks)
            .map(|idx| {
                let (bi, bj) = (idx / nblocks, idx % nblocks);
                let h = splitmix(seed
                                     ^ ((bi as u64) << 32)
                                     ^ bj as u64);
                bi == bj || (h % 100) < density_pct as u64
            })
            .collect();
        Self::new(block, nblocks, live)
    }

    /// Side length of one square block.
    pub fn block(&self) -> usize {
        self.block
    }

    /// Number of blocks along each axis.
    pub fn nblocks(&self) -> usize {
        self.nblocks
    }

    /// Sequence length this layout covers (`block * nblocks`).
    pub fn n(&self) -> usize {
        self.block * self.nblocks
    }

    /// Whether block `(bi, bj)` is live.
    pub fn is_live(&self, bi: usize, bj: usize) -> bool {
        self.live[bi * self.nblocks + bj]
    }

    /// Number of live blocks in the grid.
    pub fn live_blocks(&self) -> usize {
        self.live.iter().filter(|&&b| b).count()
    }
}

/// Which (query `i`, key `j`) attention scores are live.
///
/// Masked-row contract: if row `i` has no live `j` at all, attention
/// output row `i` is exactly zero and its log-sum-exp is
/// `f32::NEG_INFINITY` — never NaN, never uniform weights.
#[derive(Debug, Clone, PartialEq)]
pub enum Mask {
    /// Every score is live (full dense attention).
    Dense,
    /// Lower-triangular: key `j` is live for query `i` iff `j <= i`.
    Causal,
    /// Causal window of width `w`: live iff `j <= i && i - j < w`
    /// (each query sees itself and the `w - 1` previous keys).
    /// `w = 0` masks everything — the canonical fully-masked-row
    /// regression input.
    SlidingWindow {
        /// Window width in keys, including the query position itself.
        w: usize,
    },
    /// Block-granular sparsity over a [`BlockLayout`] grid; the layout
    /// side `layout.n()` must equal the sequence length.
    BlockSparse {
        /// The block liveness grid.
        layout: BlockLayout,
    },
}

/// Live/skipped tile totals from [`Mask::tile_counts`]: the enumerator
/// ground truth that both the pool's task set and the `iomodel`
/// traffic counts are asserted against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileCounts {
    /// Tiles with at least one live element (packed, scheduled,
    /// streamed, counted).
    pub live: usize,
    /// Tiles provably outside the mask (never packed, never
    /// scheduled, absent from traffic counts).
    pub skipped: usize,
    /// Query tiles with at least one live key tile (a query tile with
    /// none is not even scheduled as a pool task — its output rows are
    /// the pre-initialised zeros + `-inf` LSE sentinel).
    pub live_q_tiles: usize,
}

impl Mask {
    /// Whether score `(i, j)` (query `i` attends key `j`) is live.
    pub fn live(&self, i: usize, j: usize) -> bool {
        match self {
            Mask::Dense => true,
            Mask::Causal => j <= i,
            Mask::SlidingWindow { w } => j <= i && i - j < *w,
            Mask::BlockSparse { layout } => {
                layout.is_live(i / layout.block, j / layout.block)
            }
        }
    }

    /// Whether the tile of queries `iq..iq+bq` × keys `ik..ik+bk` can
    /// contain a live element.  Exact (true ⇔ ∃ live element), so
    /// `!tile_live` tiles are provably skippable.
    pub fn tile_live(&self, iq: usize, bq: usize, ik: usize, bk: usize)
                     -> bool {
        debug_assert!(bq >= 1 && bk >= 1);
        match self {
            Mask::Dense => true,
            // feasibility of j <= i over the rectangle: min j vs max i
            Mask::Causal => ik <= iq + bq - 1,
            // the band 0 <= i-j <= w-1 meets the rectangle iff both
            // one-sided diagonal bounds are achievable (the i-j range
            // over a rectangle is a contiguous interval)
            Mask::SlidingWindow { w } => {
                *w > 0 && ik <= iq + bq - 1 && iq <= ik + bk + *w - 2
            }
            Mask::BlockSparse { layout } => {
                let b = layout.block;
                let (b0, b1) = (iq / b, (iq + bq - 1) / b);
                let (c0, c1) = (ik / b, (ik + bk - 1) / b);
                (b0..=b1.min(layout.nblocks - 1)).any(|bi| {
                    (c0..=c1.min(layout.nblocks - 1))
                        .any(|bj| layout.is_live(bi, bj))
                })
            }
        }
    }

    /// Enumerates the `(block_q, block_k)` tile grid over an `n × n`
    /// score matrix (trailing partial tiles included) and counts live
    /// vs skipped tiles — the single source of truth the streaming
    /// task builders and the `iomodel` masked traffic model both
    /// follow.
    pub fn tile_counts(&self, n: usize, block_q: usize, block_k: usize)
                       -> TileCounts {
        assert!(block_q >= 1 && block_k >= 1,
                "tile_counts needs block_q/block_k ≥ 1");
        let mut c = TileCounts { live: 0, skipped: 0, live_q_tiles: 0 };
        for iq in (0..n).step_by(block_q) {
            let bq = block_q.min(n - iq);
            let mut row_live = 0usize;
            for ik in (0..n).step_by(block_k) {
                let bk = block_k.min(n - ik);
                if self.tile_live(iq, bq, ik, bk) {
                    row_live += 1;
                } else {
                    c.skipped += 1;
                }
            }
            c.live += row_live;
            if row_live > 0 {
                c.live_q_tiles += 1;
            }
        }
        c
    }

    /// Number of live score elements in an `n × n` attention matrix —
    /// the basis for mask-aware FLOP accounting (dense `n²`, causal
    /// `n(n+1)/2`, window ≈ `n·w`, block-sparse
    /// `live_blocks · block²`).
    pub fn live_elements(&self, n: usize) -> usize {
        match self {
            Mask::Dense => n * n,
            Mask::Causal => n * (n + 1) / 2,
            Mask::SlidingWindow { w } => {
                let w = *w;
                if w >= n {
                    n * (n + 1) / 2
                } else {
                    // rows 0..w ramp up (i+1 live keys), the rest see
                    // exactly w
                    w * (w + 1) / 2 + (n - w) * w
                }
            }
            Mask::BlockSparse { layout } => {
                debug_assert_eq!(layout.n(), n);
                layout.live_blocks() * layout.block * layout.block
            }
        }
    }

    /// Panics unless the mask is consistent with sequence length `n`
    /// (only [`Mask::BlockSparse`] constrains it).
    pub fn check_n(&self, n: usize) {
        if let Mask::BlockSparse { layout } = self {
            assert_eq!(layout.n(), n,
                       "block-sparse layout covers n={} but attention \
                        inputs have n={}",
                       layout.n(), n);
        }
    }

    /// Short stable label for bench rows and logs (`dense`, `causal`,
    /// `win{w}`, `bs{block}x{nblocks}`).
    pub fn label(&self) -> String {
        match self {
            Mask::Dense => "dense".into(),
            Mask::Causal => "causal".into(),
            Mask::SlidingWindow { w } => format!("win{w}"),
            Mask::BlockSparse { layout } => {
                format!("bs{}x{}", layout.block, layout.nblocks)
            }
        }
    }
}

/// Sequence-length-independent mask description: what config
/// (`[attention] mask`), the CLI (`--mask`/`--window`), and the bench
/// env (`SPARK_HOST_MASKS`) parse, instantiated per shape via
/// [`MaskSpec::build`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskSpec {
    /// Full dense attention.
    Dense,
    /// Lower-triangular causal attention.
    Causal,
    /// Causal sliding window of width `w ≥ 1`.
    SlidingWindow {
        /// Window width in keys, including the query itself.
        w: usize,
    },
    /// Deterministic pseudo-random block-sparse pattern (diagonal
    /// always live); `block` must divide the sequence length at
    /// [`MaskSpec::build`] time.
    BlockSparse {
        /// Square block side length.
        block: usize,
        /// Off-diagonal live probability, percent (0..=100).
        density_pct: usize,
        /// Layout seed (same seed ⇒ same layout everywhere).
        seed: u64,
    },
}

impl MaskSpec {
    /// Parses one spec.  Grammar: `dense` | `causal` | `window:W` |
    /// `block:B[:DENSITY_PCT[:SEED]]`.  A bare `window` takes its
    /// width from `window` (the `--window` flag / `[attention] window`
    /// key) and is an error when none was given.  Widths and blocks of
    /// 0 are rejected here, at the configuration surface — the core
    /// [`Mask`] still represents `SlidingWindow { w: 0 }` for the
    /// fully-masked regression tests.
    pub fn parse(text: &str, window: Option<usize>) -> Result<Self> {
        let parts: Vec<&str> = text.trim().split(':').collect();
        let uint = |s: &str, what: &str| -> Result<usize> {
            s.parse::<usize>().map_err(|_| {
                anyhow::anyhow!("mask `{text}`: {what} `{s}` is not an \
                                 unsigned integer")
            })
        };
        match parts.as_slice() {
            ["dense"] => Ok(MaskSpec::Dense),
            ["causal"] => Ok(MaskSpec::Causal),
            ["window"] => match window {
                Some(w) if w >= 1 => Ok(MaskSpec::SlidingWindow { w }),
                Some(_) => bail!("sliding-window width must be ≥ 1 \
                                  (window = 0 masks every key; got 0)"),
                None => bail!("mask `window` needs a width: pass \
                               `window:W`, or set `--window` / \
                               `[attention] window`"),
            },
            ["window", w] => {
                let w = uint(w, "width")?;
                if w == 0 {
                    bail!("sliding-window width must be ≥ 1 (window = 0 \
                           masks every key; got 0)");
                }
                Ok(MaskSpec::SlidingWindow { w })
            }
            ["block", rest @ ..] if rest.len() <= 3 && !rest.is_empty() => {
                let block = uint(rest[0], "block size")?;
                if block == 0 {
                    bail!("block-sparse block size must be ≥ 1 (got 0)");
                }
                let density_pct = match rest.get(1) {
                    Some(s) => uint(s, "density")?,
                    None => 25,
                };
                if density_pct > 100 {
                    bail!("block-sparse density must be 0..=100 percent \
                           (got {density_pct})");
                }
                let seed = match rest.get(2) {
                    Some(s) => s.parse::<u64>().map_err(|_| {
                        anyhow::anyhow!("mask `{text}`: seed `{s}` is not \
                                         an unsigned integer")
                    })?,
                    None => 0,
                };
                Ok(MaskSpec::BlockSparse { block, density_pct, seed })
            }
            _ => bail!("unknown mask `{text}`: expected dense | causal | \
                        window:W | block:B[:DENSITY_PCT[:SEED]]"),
        }
    }

    /// Parses a comma-separated list of specs (bench env / `--mask`
    /// accepts e.g. `dense,causal,window:256`).
    pub fn parse_list(text: &str, window: Option<usize>)
                      -> Result<Vec<Self>> {
        text.split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| Self::parse(s, window))
            .collect()
    }

    /// Short stable label (`dense`, `causal`, `win{w}`,
    /// `bs{block}d{density}`) used to name bench groups.
    pub fn label(&self) -> String {
        match self {
            MaskSpec::Dense => "dense".into(),
            MaskSpec::Causal => "causal".into(),
            MaskSpec::SlidingWindow { w } => format!("win{w}"),
            MaskSpec::BlockSparse { block, density_pct, .. } => {
                format!("bs{block}d{density_pct}")
            }
        }
    }

    /// Instantiates the spec for sequence length `n` (block-sparse
    /// blocks must divide `n`).
    pub fn build(&self, n: usize) -> Result<Mask> {
        match *self {
            MaskSpec::Dense => Ok(Mask::Dense),
            MaskSpec::Causal => Ok(Mask::Causal),
            MaskSpec::SlidingWindow { w } => Ok(Mask::SlidingWindow { w }),
            MaskSpec::BlockSparse { block, density_pct, seed } => {
                if n % block != 0 {
                    bail!("block-sparse block {block} must divide the \
                           sequence length (n = {n})");
                }
                let layout =
                    BlockLayout::random(block, n / block, density_pct,
                                        seed)?;
                Ok(Mask::BlockSparse { layout })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Brute-force reference for `tile_live`: scan every element.
    fn tile_live_ref(m: &Mask, iq: usize, bq: usize, ik: usize, bk: usize)
                     -> bool {
        (iq..iq + bq).any(|i| (ik..ik + bk).any(|j| m.live(i, j)))
    }

    fn roster(n: usize) -> Vec<Mask> {
        let nb = 4;
        let block = n / nb;
        let mut masks = vec![
            Mask::Dense,
            Mask::Causal,
            Mask::SlidingWindow { w: 0 },
            Mask::SlidingWindow { w: 1 },
            Mask::SlidingWindow { w: 3 },
            Mask::SlidingWindow { w: n },
            Mask::SlidingWindow { w: 2 * n },
        ];
        if block >= 1 {
            masks.push(Mask::BlockSparse {
                layout: BlockLayout::random(block, nb, 30, 7).unwrap(),
            });
            // one fully-dead query block-row (row 2), one fully-live
            let mut live = vec![false; nb * nb];
            for bj in 0..nb {
                live[bj] = bj == 0;
                live[nb + bj] = bj % 2 == 0;
                live[3 * nb + bj] = true;
            }
            masks.push(Mask::BlockSparse {
                layout: BlockLayout::new(block, nb, live).unwrap(),
            });
        }
        masks
    }

    #[test]
    fn tile_live_is_exact() {
        for n in [8usize, 12, 16] {
            for m in roster(n) {
                for bq in [1usize, 2, 3, 4, 8] {
                    for bk in [1usize, 2, 3, 4, 8] {
                        for iq in (0..n).step_by(bq) {
                            let tq = bq.min(n - iq);
                            for ik in (0..n).step_by(bk) {
                                let tk = bk.min(n - ik);
                                assert_eq!(
                                    m.tile_live(iq, tq, ik, tk),
                                    tile_live_ref(&m, iq, tq, ik, tk),
                                    "mask {m:?} tile ({iq},{tq})×\
                                     ({ik},{tk})"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn tile_counts_partition_the_grid() {
        let n = 16;
        for m in roster(n) {
            for (bq, bk) in [(4usize, 4usize), (8, 4), (4, 8), (3, 5)] {
                let c = m.tile_counts(n, bq, bk);
                let grid = n.div_ceil(bq) * n.div_ceil(bk);
                assert_eq!(c.live + c.skipped, grid, "mask {m:?}");
                assert!(c.live_q_tiles <= n.div_ceil(bq));
            }
        }
    }

    #[test]
    fn live_elements_matches_element_scan() {
        for n in [8usize, 12, 16] {
            for m in roster(n) {
                let scan: usize = (0..n)
                    .map(|i| (0..n).filter(|&j| m.live(i, j)).count())
                    .sum();
                assert_eq!(m.live_elements(n), scan, "mask {m:?} n={n}");
            }
        }
    }

    #[test]
    fn window_zero_masks_everything() {
        let m = Mask::SlidingWindow { w: 0 };
        assert_eq!(m.live_elements(8), 0);
        assert_eq!(m.tile_counts(8, 4, 4).live, 0);
        assert_eq!(m.tile_counts(8, 4, 4).live_q_tiles, 0);
    }

    #[test]
    fn random_layout_is_deterministic_with_live_diagonal() {
        let a = BlockLayout::random(8, 6, 40, 123).unwrap();
        let b = BlockLayout::random(8, 6, 40, 123).unwrap();
        assert_eq!(a, b);
        for bi in 0..6 {
            assert!(a.is_live(bi, bi), "diagonal block {bi} must be live");
        }
        let c = BlockLayout::random(8, 6, 40, 124).unwrap();
        assert_ne!(a, c, "different seeds should differ (6×6 @ 40%)");
    }

    #[test]
    fn spec_parse_grammar_and_errors() {
        assert_eq!(MaskSpec::parse("dense", None).unwrap(), MaskSpec::Dense);
        assert_eq!(MaskSpec::parse("causal", None).unwrap(),
                   MaskSpec::Causal);
        assert_eq!(MaskSpec::parse("window:7", None).unwrap(),
                   MaskSpec::SlidingWindow { w: 7 });
        assert_eq!(MaskSpec::parse("window", Some(9)).unwrap(),
                   MaskSpec::SlidingWindow { w: 9 });
        assert_eq!(MaskSpec::parse("block:16", None).unwrap(),
                   MaskSpec::BlockSparse { block: 16, density_pct: 25,
                                           seed: 0 });
        assert_eq!(MaskSpec::parse("block:16:50:3", None).unwrap(),
                   MaskSpec::BlockSparse { block: 16, density_pct: 50,
                                           seed: 3 });
        for bad in ["window", "window:0", "block:0", "block:8:200",
                    "diag", "window:x"] {
            assert!(MaskSpec::parse(bad, None).is_err(), "{bad}");
        }
        let list = MaskSpec::parse_list("dense, causal,window:4", None)
            .unwrap();
        assert_eq!(list.len(), 3);
        assert_eq!(list[2], MaskSpec::SlidingWindow { w: 4 });
    }

    #[test]
    fn spec_build_checks_divisibility() {
        let spec = MaskSpec::BlockSparse { block: 6, density_pct: 25,
                                           seed: 0 };
        assert!(spec.build(16).is_err());
        assert!(spec.build(12).is_ok());
    }
}
