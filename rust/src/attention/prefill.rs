//! Chunked prefill: prompt ingestion through the streaming tiling.
//!
//! Serving a prompt means computing attention for *many* query rows
//! whose keys arrive incrementally — the prompt is appended to the
//! paged cache one block-sized chunk per scheduler step so long
//! prompts cannot starve running decodes.  [`prefill_chunk`] is the
//! kernel for one such step: it folds the chunk's freshly cached keys
//! into every prompt row ingested so far, and catches the chunk's own
//! rows up on the whole cached history, all through the exact
//! per-(row, key-tile) update `streaming_fwd_tile` and
//! [`super::decode_step`] share ([`super::decode::fold_kv_block`]).
//!
//! **The state machine.**  A [`PrefillState`] carries the per-row
//! online-softmax statistics `(m, l, acc)` — *not* finished outputs —
//! across chunks, exactly the FlashAttention-style accumulation the
//! paper's kernel fusion builds on.  Per chunk:
//!
//! 1. every previously ingested row folds the chunk's new key blocks
//!    (its next key tiles, in ascending order), and
//! 2. every new row initialises `(m = -inf, l = 0, acc = 0)` and folds
//!    *all* cached blocks from position 0.
//!
//! Each row therefore visits the ascending sequence of `block_tokens`-
//! aligned key tiles over the full prompt — the same tile walk
//! `mha_forward_streaming` performs with `block_k = block_tokens` —
//! regardless of how the prompt was chunked.  [`PrefillState::finalize`]
//! turns the states into outputs once the last chunk lands.  Two
//! consequences, pinned by `rust/tests/prefill.rs`:
//!
//! * **Bitwise identity with streaming, every mask.**  When
//!   `block_tokens` divides the prompt length, `finalize` equals
//!   `mha_forward_streaming` over the whole prompt bitwise — for
//!   *every* `Mask` variant (a `Dense` row attends to keys cached
//!   *after* its own chunk: deferring finalisation is what makes that
//!   possible), in f32 and simd-mixed.  At non-aligned lengths the
//!   streaming path cannot tile the prompt at all; prefill still
//!   matches the fused oracle to tolerance, and for causal-type masks
//!   (`Causal`, `SlidingWindow`) stays bitwise-identical to streaming
//!   over any block-aligned *continuation* — a partial tail tile is a
//!   full tile whose extra keys are masked, which the online update
//!   treats as an exact no-op (see [`super::decode_step`]'s module
//!   docs).
//! * **Chunk-schedule invariance.**  The finalized outputs are
//!   bitwise-independent of the chunk partition (any multiples of
//!   `block_tokens`, plus the tail), because the partition only moves
//!   *when* a row starts its walk, never the walk itself.
//!
//! **Precision.**  `mixed` quantizes each query row once at ingest and
//! each cached K/V element at its read — bf16 quantization is
//! idempotent, so this matches the streaming path's
//! quantize-at-entry bitwise.

use crate::tensor::bf16;
use crate::tensor::paged::KvBlockView;

use super::decode::{finalize_row, fold_kv_block};
use super::AttnParams;

/// Per-row online-softmax statistics for a prompt mid-ingestion.
///
/// Owns, per ingested row and head, the running maximum `m`, the
/// normaliser `l`, the unnormalised accumulator `acc` (`d` values),
/// and the row's query (quantized at ingest under mixed precision) —
/// everything needed to keep folding key tiles as later chunks land.
/// Dropping the state mid-prompt (an eviction) loses nothing but
/// work: re-ingesting the same prompt rebuilds it bitwise.
#[derive(Debug, Default)]
pub struct PrefillState {
    heads: usize,
    d: usize,
    /// Prompt rows ingested so far == keys folded into each of them
    /// (every `prefill_chunk` call restores this invariant).
    rows: usize,
    /// Query rows, `rows · heads · d`, quantized under mixed.
    q: Vec<f32>,
    /// Running row maxima, `rows · heads`.
    m: Vec<f32>,
    /// Running normalisers, `rows · heads`.
    l: Vec<f32>,
    /// Unnormalised accumulators, `rows · heads · d`.
    acc: Vec<f32>,
}

impl PrefillState {
    /// Empty state for a prompt of `heads × d` rows.  `prompt_len` is
    /// a capacity hint: reserving up front keeps the vectors from
    /// reallocating while a prefill task runs on the exec pool.
    pub fn new(heads: usize, d: usize, prompt_len: usize) -> Self {
        assert!(heads > 0 && d > 0,
                "prefill needs heads ≥ 1 and d ≥ 1");
        let width = heads * d;
        PrefillState {
            heads,
            d,
            rows: 0,
            q: Vec::with_capacity(prompt_len * width),
            m: Vec::with_capacity(prompt_len * heads),
            l: Vec::with_capacity(prompt_len * heads),
            acc: Vec::with_capacity(prompt_len * width),
        }
    }

    /// Prompt rows ingested so far.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Byte spans a `prefill_chunk` task will write, for the exec
    /// pool's race detector: the state's whole backing vectors
    /// (capacity, not just the initialised prefix — the chunk appends
    /// into the reserved tail).
    pub fn write_spans(&self) -> Vec<(usize, usize)> {
        let cap = |p: *const f32, c: usize| {
            (p as usize, p as usize + c * std::mem::size_of::<f32>())
        };
        vec![
            cap(self.q.as_ptr(), self.q.capacity()),
            cap(self.m.as_ptr(), self.m.capacity()),
            cap(self.l.as_ptr(), self.l.capacity()),
            cap(self.acc.as_ptr(), self.acc.capacity()),
        ]
    }

    /// Emit the finalized attention rows: `out` is `rows · heads · d`
    /// (row-major: row, then head, then `d`), `lse` is `rows · heads`.
    /// A fully-masked row gets exact zeros and the `-inf` sentinel,
    /// matching the streaming contract.  Call once the whole prompt
    /// has been ingested (callable mid-prompt too — rows then reflect
    /// only the keys cached so far, which for causal-type masks is
    /// already their final value).
    pub fn finalize(&self, out: &mut [f32], lse: &mut [f32]) {
        let (heads, d) = (self.heads, self.d);
        let width = heads * d;
        assert_eq!(out.len(), self.rows * width,
                   "out must be rows · heads · d");
        assert_eq!(lse.len(), self.rows * heads,
                   "lse must be rows · heads");
        for r in 0..self.rows {
            for h in 0..heads {
                let s = r * heads + h;
                finalize_row(self.m[s], self.l[s],
                             &self.acc[s * d..(s + 1) * d],
                             &mut out[(r * heads + h) * d
                                      ..(r * heads + h + 1) * d],
                             &mut lse[s]);
            }
        }
    }
}

/// Ingest one prompt chunk: the chunk's K/V must already be appended
/// to the paged cache, so `blocks` covers positions
/// `0 .. st.rows() + chunk_len` where `chunk_len =
/// q_chunk.len() / (heads · d)` — the chunk's query rows at absolute
/// positions `st.rows() ..`.  Every chunk except a prompt's last must
/// end on a cache-block boundary (the scheduler chunks prompts in
/// `block_tokens`-sized pieces, so this holds by construction); a
/// chunk that would extend a partially filled block mid-prompt is a
/// caller bug and panics, because its rows' key-tile walk would no
/// longer match the streaming tiling.
pub fn prefill_chunk(st: &mut PrefillState, q_chunk: &[f32],
                     blocks: &[KvBlockView<'_>], p: &AttnParams,
                     mixed: bool) {
    let (heads, d) = (st.heads, st.d);
    let width = heads * d;
    assert!(width > 0, "prefill state must be built via new()");
    assert!(!q_chunk.is_empty() && q_chunk.len() % width == 0,
            "chunk must be a nonzero multiple of heads·d ({} given)",
            q_chunk.len());
    let chunk_len = q_chunk.len() / width;
    let cached: usize = blocks.iter().map(|b| b.tokens).sum();
    assert_eq!(cached, st.rows + chunk_len,
               "cache holds {cached} tokens but the state has {} rows \
                + {chunk_len} chunk rows: append the chunk's K/V first",
               st.rows);
    if let super::Mask::BlockSparse { layout } = &p.mask {
        assert!(cached <= layout.n(),
                "block-sparse layout covers n={} but the prompt \
                 reaches {cached}", layout.n());
    }
    for blk in blocks {
        assert!(blk.start >= st.rows
                    || blk.start + blk.tokens <= st.rows,
                "chunk boundary {} falls inside cache block \
                 [{}, {}): prior chunks must be multiples of \
                 block_tokens", st.rows, blk.start,
                blk.start + blk.tokens);
    }

    // Phase A: previously ingested rows fold the chunk's new key
    // tiles — the next steps of their ascending tile walk.
    let prev_rows = st.rows;
    for r in 0..prev_rows {
        for h in 0..heads {
            let s = r * heads + h;
            let qrow = &st.q[s * d..(s + 1) * d];
            let (mut m, mut l) = (st.m[s], st.l[s]);
            let acc = &mut st.acc[s * d..(s + 1) * d];
            for blk in blocks.iter().filter(|b| b.start >= prev_rows) {
                fold_kv_block(qrow, blk, h, d, width, r, p, mixed,
                              &mut m, &mut l, acc);
            }
            st.m[s] = m;
            st.l[s] = l;
        }
    }

    // Phase B: the chunk's own rows start their walk from tile 0 over
    // everything cached (their own chunk included — the mask decides
    // what is live; `Dense` rows keep folding in later chunks).
    for j in 0..chunk_len {
        let pos = prev_rows + j;
        for h in 0..heads {
            let qrow: Vec<f32> = q_chunk[(j * heads + h) * d
                                         ..(j * heads + h + 1) * d]
                .iter()
                .map(|&x| if mixed { bf16::quantize(x) } else { x })
                .collect();
            let mut m = f32::NEG_INFINITY;
            let mut l = 0.0f32;
            let mut acc = vec![0.0f32; d];
            for blk in blocks {
                fold_kv_block(&qrow, blk, h, d, width, pos, p, mixed,
                              &mut m, &mut l, &mut acc);
            }
            st.q.extend_from_slice(&qrow);
            st.m.push(m);
            st.l.push(l);
            st.acc.extend_from_slice(&acc);
        }
    }
    st.rows = cached;
}
