//! PJRT runtime: manifest-driven artifact registry, host⇄device value
//! conversion, and the execution engine.
//!
//! Layer boundary: everything above this module (coordinator, benches,
//! examples) speaks `HostValue` + artifact names; everything below is the
//! `xla` crate's PJRT C-API wrapper.  Python never appears at run time —
//! artifacts are HLO text produced once by `make artifacts`.

pub mod engine;
pub mod host;
pub mod manifest;

pub use engine::{Engine, EngineStats};
pub use host::HostValue;
pub use manifest::{ArtifactMeta, DType, Manifest, TensorSpec};
