//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.  `manifest.json` lists every HLO entry point with its
//! input/output tensor specs and static attributes (shapes, FLOPs, HBM
//! traffic model) — the runtime never guesses shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonio::{self, Value};

/// Element dtype of an artifact tensor (manifest string form).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// bfloat16 (the device interchange dtype).
    Bf16,
    /// 32-bit float.
    F32,
    /// 64-bit float.
    F64,
    /// 32-bit signed integer.
    S32,
    /// 32-bit unsigned integer.
    U32,
    /// Boolean predicate.
    Pred,
}

impl DType {
    /// Parse the manifest string form (`"bf16"`, `"f32"`, …).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "bf16" => DType::Bf16,
            "f32" => DType::F32,
            "f64" => DType::F64,
            "s32" => DType::S32,
            "u32" => DType::U32,
            "pred" => DType::Pred,
            other => bail!("unknown dtype {other:?} in manifest"),
        })
    }

    /// Manifest string form (inverse of [`DType::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            DType::Bf16 => "bf16",
            DType::F32 => "f32",
            DType::F64 => "f64",
            DType::S32 => "s32",
            DType::U32 => "u32",
            DType::Pred => "pred",
        }
    }

    /// Bytes per element.
    pub fn byte_size(self) -> usize {
        match self {
            DType::Bf16 => 2,
            DType::F32 | DType::S32 | DType::U32 => 4,
            DType::F64 => 8,
            DType::Pred => 1,
        }
    }
}

/// Shape + dtype of one artifact input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Parameter name in the artifact signature.
    pub name: String,
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Element dtype.
    pub dtype: DType,
}

impl TensorSpec {
    /// Total element count.
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    /// Total byte size (elements × dtype width).
    pub fn byte_size(&self) -> usize {
        self.element_count() * self.dtype.byte_size()
    }

    fn from_json(v: &Value) -> Result<Self> {
        let name = v.get("name").and_then(Value::as_str)
            .ok_or_else(|| anyhow!("tensor spec missing name"))?.to_string();
        let shape = v.get("shape").and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("tensor spec {name}: missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim in {name}")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(
            v.get("dtype").and_then(Value::as_str)
                .ok_or_else(|| anyhow!("tensor spec {name}: missing dtype"))?)?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// One AOT-compiled HLO entry point.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Unique artifact name (manifest key).
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Artifact family (`mha_fwd`, `mha_bwd`, `encoder_fwd`, …).
    pub kind: String,
    /// Input tensor specs, positional.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs, positional.
    pub outputs: Vec<TensorSpec>,
    /// Static attributes (shapes, FLOPs, traffic model) as JSON.
    pub attrs: Value,
}

impl ArtifactMeta {
    fn from_json(v: &Value) -> Result<Self> {
        let name = v.get("name").and_then(Value::as_str)
            .ok_or_else(|| anyhow!("artifact missing name"))?.to_string();
        let get_specs = |key: &str| -> Result<Vec<TensorSpec>> {
            v.get(key).and_then(Value::as_arr)
                .ok_or_else(|| anyhow!("artifact {name}: missing {key}"))?
                .iter().map(TensorSpec::from_json).collect()
        };
        Ok(ArtifactMeta {
            file: v.get("file").and_then(Value::as_str)
                .ok_or_else(|| anyhow!("artifact {name}: missing file"))?
                .to_string(),
            kind: v.get("kind").and_then(Value::as_str)
                .ok_or_else(|| anyhow!("artifact {name}: missing kind"))?
                .to_string(),
            inputs: get_specs("inputs")?,
            outputs: get_specs("outputs")?,
            attrs: v.get("attrs").cloned().unwrap_or(Value::Null),
            name,
        })
    }

    /// Integer attribute accessor (`n`, `d`, `bh`, `flops`, …).
    pub fn attr_i64(&self, key: &str) -> Option<i64> {
        self.attrs.get(key).and_then(Value::as_i64)
    }

    /// Float attribute accessor (`dropout`, `mxu_utilization`, …).
    pub fn attr_f64(&self, key: &str) -> Option<f64> {
        self.attrs.get(key).and_then(Value::as_f64)
    }

    /// Boolean attribute accessor (`causal`, …).
    pub fn attr_bool(&self, key: &str) -> Option<bool> {
        self.attrs.get(key).and_then(Value::as_bool)
    }

    /// String attribute accessor (`acc`, `impl`, …).
    pub fn attr_str(&self, key: &str) -> Option<&str> {
        self.attrs.get(key).and_then(Value::as_str)
    }

    /// Total bytes of all inputs + outputs (host-side working set).
    pub fn io_bytes(&self) -> usize {
        self.inputs.iter().map(TensorSpec::byte_size).sum::<usize>()
            + self.outputs.iter().map(TensorSpec::byte_size).sum::<usize>()
    }
}

/// The parsed manifest: artifact lookup by name, kind, and attribute query.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest (and HLO files) live in.
    pub dir: PathBuf,
    by_name: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!(
                "reading {} — run `make artifacts` first", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON text rooted at `dir`.
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let root = jsonio::parse(text).context("parsing manifest.json")?;
        let arts = root.get("artifacts").and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts array"))?;
        let mut by_name = BTreeMap::new();
        for a in arts {
            let meta = ArtifactMeta::from_json(a)?;
            if by_name.insert(meta.name.clone(), meta.clone()).is_some() {
                bail!("duplicate artifact name {}", meta.name);
            }
        }
        Ok(Manifest { dir, by_name })
    }

    /// Number of artifacts.
    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    /// Whether the manifest lists no artifacts.
    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    /// Artifact by name (loud error naming the manifest size).
    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.by_name.get(name).ok_or_else(|| anyhow!(
            "artifact {name:?} not in manifest ({} entries); \
             run `make artifacts`?", self.by_name.len()))
    }

    /// Iterate all artifacts in name order.
    pub fn iter(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.by_name.values()
    }

    /// All artifacts of one kind, manifest order.
    pub fn of_kind<'a>(&'a self, kind: &'a str)
                       -> impl Iterator<Item = &'a ArtifactMeta> + 'a {
        self.by_name.values().filter(move |a| a.kind == kind)
    }

    /// Path to an artifact's HLO text file.
    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "a1", "file": "a1.hlo.txt", "kind": "mha_fwd",
         "attrs": {"n": 256, "d": 64, "causal": true, "acc": "f32",
                   "flops": 134217728, "mxu_utilization": 0.5},
         "inputs": [{"name": "seed", "shape": [1], "dtype": "f32"},
                    {"name": "q", "shape": [4, 256, 64], "dtype": "bf16"}],
         "outputs": [{"name": "out0", "shape": [4, 256, 64], "dtype": "bf16"}]},
        {"name": "a2", "file": "a2.hlo.txt", "kind": "encoder_fwd",
         "attrs": {}, "inputs": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.len(), 2);
        let a = m.get("a1").unwrap();
        assert_eq!(a.kind, "mha_fwd");
        assert_eq!(a.inputs[1].shape, vec![4, 256, 64]);
        assert_eq!(a.inputs[1].dtype, DType::Bf16);
        assert_eq!(a.attr_i64("n"), Some(256));
        assert_eq!(a.attr_bool("causal"), Some(true));
        assert_eq!(a.attr_str("acc"), Some("f32"));
        assert!(a.attr_f64("mxu_utilization").unwrap() > 0.4);
        assert_eq!(a.attr_i64("missing"), None);
    }

    #[test]
    fn byte_sizes() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let a = m.get("a1").unwrap();
        assert_eq!(a.inputs[0].byte_size(), 4);
        assert_eq!(a.inputs[1].byte_size(), 4 * 256 * 64 * 2);
        assert_eq!(a.io_bytes(), 4 + 2 * 4 * 256 * 64 * 2);
    }

    #[test]
    fn kind_filter() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.of_kind("mha_fwd").count(), 1);
        assert_eq!(m.of_kind("nope").count(), 0);
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_duplicates() {
        let dup = SAMPLE.replace("\"a2\"", "\"a1\"");
        assert!(Manifest::parse(&dup, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("\"bf16\"", "\"q7\"");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }
}
