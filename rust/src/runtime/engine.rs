//! The PJRT execution engine: loads HLO-text artifacts, compiles them once,
//! and executes them from the request path.
//!
//! Mirrors `/opt/xla-example/load_hlo.rs`: HLO **text** → `HloModuleProto`
//! → `XlaComputation` → `PjRtClient::compile` → `execute`.  Compilation is
//! amortised behind a cache keyed by artifact name; the hot path is
//! literal-encode → execute → literal-decode.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable,
          XlaComputation};

use super::host::HostValue;
use super::manifest::{ArtifactMeta, Manifest};

/// Compile/execute statistics (observable via `spark inspect-artifacts`).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    /// Artifacts compiled (cache misses).
    pub compiles: u64,
    /// Total compile time, milliseconds.
    pub compile_ms: f64,
    /// Artifact executions.
    pub executions: u64,
    /// Total device execute time, milliseconds.
    pub execute_ms: f64,
    /// Host→device literal staging time, milliseconds.
    pub h2d_ms: f64,
    /// Device→host readback time, milliseconds.
    pub d2h_ms: f64,
}

/// Artifact registry + PJRT client.  Single-threaded by design (the PJRT
/// CPU client is driven from the coordinator's event loop; worker
/// parallelism lives inside XLA).
pub struct Engine {
    client: PjRtClient,
    manifest: Manifest,
    // BTreeMap, not a hash map: iteration order (and thus any future
    // warmup/eviction sweep) stays deterministic — the `det-hash`
    // rule in `spark check` holds crate-wide.
    cache: RefCell<BTreeMap<String, Rc<PjRtLoadedExecutable>>>,
    stats: RefCell<EngineStats>,
}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(BTreeMap::new()),
            stats: RefCell::new(EngineStats::default()),
        })
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Snapshot of the compile/execute counters.
    pub fn stats(&self) -> EngineStats {
        self.stats.borrow().clone()
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn load(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(exe));
        }
        let meta = self.manifest.get(name)?;
        let path = self.manifest.hlo_path(meta);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let exe = Rc::new(exe);
        {
            let mut st = self.stats.borrow_mut();
            st.compiles += 1;
            st.compile_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (amortise before the timed region).
    pub fn warmup<'a>(&self, names: impl IntoIterator<Item = &'a str>)
                      -> Result<()> {
        for n in names {
            self.load(n)?;
        }
        Ok(())
    }

    /// Execute an artifact with host values; returns decoded host outputs.
    ///
    /// Inputs are validated against the manifest specs (count, shape,
    /// dtype); outputs come back as f32/i32 host tensors.
    pub fn execute(&self, name: &str, inputs: &[HostValue])
                   -> Result<Vec<HostValue>> {
        let meta = self.manifest.get(name)?.clone();
        let exe = self.load(name)?;
        if inputs.len() != meta.inputs.len() {
            bail!("artifact {name}: expected {} inputs, got {}",
                  meta.inputs.len(), inputs.len());
        }
        let t0 = Instant::now();
        let literals = inputs.iter().zip(&meta.inputs)
            .map(|(hv, spec)| hv.to_literal(spec))
            .collect::<Result<Vec<Literal>>>()?;
        let h2d = t0.elapsed();

        let t1 = Instant::now();
        let result = exe.execute::<Literal>(&literals)
            .with_context(|| format!("executing artifact {name}"))?;
        let exec = t1.elapsed();

        let t2 = Instant::now();
        let out = self.decode_result(name, &meta, result)?;
        let d2h = t2.elapsed();

        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.h2d_ms += h2d.as_secs_f64() * 1e3;
        st.execute_ms += exec.as_secs_f64() * 1e3;
        st.d2h_ms += d2h.as_secs_f64() * 1e3;
        Ok(out)
    }

    /// Timed execute for benches: returns (outputs, pure-execute seconds).
    pub fn execute_timed(&self, name: &str, inputs: &[HostValue])
                         -> Result<(Vec<HostValue>, f64)> {
        let meta = self.manifest.get(name)?.clone();
        let exe = self.load(name)?;
        let literals = inputs.iter().zip(&meta.inputs)
            .map(|(hv, spec)| hv.to_literal(spec))
            .collect::<Result<Vec<Literal>>>()?;
        let t0 = Instant::now();
        let result = exe.execute::<Literal>(&literals)
            .with_context(|| format!("executing artifact {name}"))?;
        let secs = t0.elapsed().as_secs_f64();
        let out = self.decode_result(name, &meta, result)?;
        Ok((out, secs))
    }

    fn decode_result(&self, name: &str, meta: &ArtifactMeta,
                     result: Vec<Vec<xla::PjRtBuffer>>)
                     -> Result<Vec<HostValue>> {
        // aot.py lowers with return_tuple=True: one buffer, a tuple literal.
        let buf = result.first().and_then(|r| r.first())
            .with_context(|| format!("artifact {name} produced no output"))?;
        let lit = buf.to_literal_sync()?;
        let parts = lit.to_tuple()?;
        if parts.len() != meta.outputs.len() {
            bail!("artifact {name}: manifest promises {} outputs, tuple has {}",
                  meta.outputs.len(), parts.len());
        }
        parts.iter().map(HostValue::from_literal).collect()
    }
}
