//! Host-side values and their PJRT literal encoding.
//!
//! The coordinator works in f32/i32 on the host; artifacts consume bf16 /
//! f32 / s32 / u32 tensors.  `HostValue::to_literal` converts with explicit
//! round-to-nearest-even bf16 quantisation (`tensor::bf16`), and
//! `from_literal` upconverts device outputs back — so precision loss happens
//! in exactly one visible place.

use anyhow::{anyhow, bail, Result};
use xla::{ElementType, Literal, PrimitiveType};

use super::manifest::{DType, TensorSpec};
use crate::tensor::{bf16, Tensor};

/// A host tensor headed to, or coming from, a PJRT executable.
#[derive(Debug, Clone, PartialEq)]
pub enum HostValue {
    /// f32 payload (also the carrier for bf16 artifacts).
    F32 {
        /// Dimension sizes, outermost first.
        shape: Vec<usize>,
        /// Row-major elements.
        data: Vec<f32>,
    },
    /// i32 payload (token ids).
    I32 {
        /// Dimension sizes, outermost first.
        shape: Vec<usize>,
        /// Row-major elements.
        data: Vec<i32>,
    },
    /// u32 payload (RNG seeds/counters).
    U32 {
        /// Dimension sizes, outermost first.
        shape: Vec<usize>,
        /// Row-major elements.
        data: Vec<u32>,
    },
}

impl HostValue {
    /// Rank-1 single-element f32 value.
    pub fn scalar_f32(v: f32) -> Self {
        HostValue::F32 { shape: vec![1], data: vec![v] }
    }

    /// Rank-1 single-element u32 value.
    pub fn scalar_u32(v: u32) -> Self {
        HostValue::U32 { shape: vec![1], data: vec![v] }
    }

    /// Copy a host tensor into an f32 value.
    pub fn from_tensor(t: &Tensor) -> Self {
        HostValue::F32 { shape: t.shape().to_vec(), data: t.data().to_vec() }
    }

    /// Dimension sizes, outermost first.
    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32 { shape, .. }
            | HostValue::I32 { shape, .. }
            | HostValue::U32 { shape, .. } => shape,
        }
    }

    /// Total element count.
    pub fn element_count(&self) -> usize {
        self.shape().iter().product()
    }

    /// View as an f32 `Tensor` (errors on integer payloads).
    pub fn as_tensor(&self) -> Result<Tensor> {
        match self {
            HostValue::F32 { shape, data } => {
                Ok(Tensor::new(shape.clone(), data.clone()))
            }
            _ => bail!("expected float tensor, got {self:?}"),
        }
    }

    /// Borrow the f32 payload (errors on integer payloads).
    pub fn as_f32_slice(&self) -> Result<&[f32]> {
        match self {
            HostValue::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 payload"),
        }
    }

    /// Encode for a given artifact input spec (shape-checked).
    pub fn to_literal(&self, spec: &TensorSpec) -> Result<Literal> {
        if self.shape() != &spec.shape[..] {
            bail!("input {}: shape {:?} != spec {:?}",
                  spec.name, self.shape(), spec.shape);
        }
        match (self, spec.dtype) {
            (HostValue::F32 { shape, data }, DType::Bf16) => {
                let bytes = bf16::encode(data);
                Ok(Literal::create_from_shape_and_untyped_data(
                    ElementType::Bf16, shape, &bytes)?)
            }
            (HostValue::F32 { shape, data }, DType::F32) => {
                let bytes: Vec<u8> =
                    data.iter().flat_map(|x| x.to_le_bytes()).collect();
                Ok(Literal::create_from_shape_and_untyped_data(
                    ElementType::F32, shape, &bytes)?)
            }
            (HostValue::I32 { shape, data }, DType::S32) => {
                let bytes: Vec<u8> =
                    data.iter().flat_map(|x| x.to_le_bytes()).collect();
                Ok(Literal::create_from_shape_and_untyped_data(
                    ElementType::S32, shape, &bytes)?)
            }
            (HostValue::U32 { shape, data }, DType::U32) => {
                let bytes: Vec<u8> =
                    data.iter().flat_map(|x| x.to_le_bytes()).collect();
                Ok(Literal::create_from_shape_and_untyped_data(
                    ElementType::U32, shape, &bytes)?)
            }
            (hv, dt) => bail!(
                "input {}: no conversion from host {:?} to {}",
                spec.name, variant_name(hv), dt.name()),
        }
    }

    /// Decode a device literal (any supported dtype) into a host value.
    pub fn from_literal(lit: &Literal) -> Result<HostValue> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.primitive_type() {
            PrimitiveType::Bf16 | PrimitiveType::F16 => {
                let as_f32 = lit.convert(PrimitiveType::F32)?;
                Ok(HostValue::F32 { shape: dims, data: as_f32.to_vec::<f32>()? })
            }
            PrimitiveType::F32 => {
                Ok(HostValue::F32 { shape: dims, data: lit.to_vec::<f32>()? })
            }
            PrimitiveType::F64 => {
                let v = lit.to_vec::<f64>()?;
                Ok(HostValue::F32 {
                    shape: dims,
                    data: v.into_iter().map(|x| x as f32).collect(),
                })
            }
            PrimitiveType::S32 => {
                Ok(HostValue::I32 { shape: dims, data: lit.to_vec::<i32>()? })
            }
            PrimitiveType::U32 => {
                Ok(HostValue::U32 { shape: dims, data: lit.to_vec::<u32>()? })
            }
            other => Err(anyhow!("unsupported output primitive type {other:?}")),
        }
    }
}

fn variant_name(hv: &HostValue) -> &'static str {
    match hv {
        HostValue::F32 { .. } => "F32",
        HostValue::I32 { .. } => "I32",
        HostValue::U32 { .. } => "U32",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize], dtype: DType) -> TensorSpec {
        TensorSpec { name: name.into(), shape: shape.to_vec(), dtype }
    }

    #[test]
    fn f32_literal_roundtrip() {
        let hv = HostValue::F32 { shape: vec![2, 3],
                                  data: vec![1., 2., 3., 4., 5., 6.] };
        let lit = hv.to_literal(&spec("x", &[2, 3], DType::F32)).unwrap();
        let back = HostValue::from_literal(&lit).unwrap();
        assert_eq!(back, hv);
    }

    #[test]
    fn bf16_literal_quantizes() {
        let vals = vec![1.0f32, 1.0 + 2f32.powi(-10), -3.7];
        let hv = HostValue::F32 { shape: vec![3], data: vals.clone() };
        let lit = hv.to_literal(&spec("x", &[3], DType::Bf16)).unwrap();
        let back = HostValue::from_literal(&lit).unwrap();
        let got = back.as_f32_slice().unwrap();
        for (g, v) in got.iter().zip(&vals) {
            assert_eq!(*g, bf16::quantize(*v));
        }
    }

    #[test]
    fn i32_literal_roundtrip() {
        let hv = HostValue::I32 { shape: vec![4], data: vec![-1, 0, 7, 1 << 20] };
        let lit = hv.to_literal(&spec("t", &[4], DType::S32)).unwrap();
        assert_eq!(HostValue::from_literal(&lit).unwrap(), hv);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let hv = HostValue::F32 { shape: vec![2], data: vec![0.0; 2] };
        assert!(hv.to_literal(&spec("x", &[3], DType::F32)).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let hv = HostValue::I32 { shape: vec![1], data: vec![1] };
        assert!(hv.to_literal(&spec("x", &[1], DType::Bf16)).is_err());
    }
}
