//! Tiny env-filtered logger backing the `log` crate facade.
//!
//! `SPARK_LOG=debug spark train …` raises verbosity; default is `info`.
//! Messages go to stderr with a monotonic timestamp so bench output on
//! stdout stays machine-parseable.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

struct SparkLogger {
    start: Instant,
    level: Level,
}

impl log::Log for SparkLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        eprintln!("[{t:9.3}s {:5} {}] {}", record.level(),
                  record.target().split("::").last().unwrap_or(""),
                  record.args());
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<SparkLogger> = OnceLock::new();

/// Install the logger (idempotent).  Level from `SPARK_LOG` ∈
/// {error, warn, info, debug, trace}; default info.
pub fn init() {
    let level = match std::env::var("SPARK_LOG").ok().as_deref() {
        Some("error") => Level::Error,
        Some("warn") => Level::Warn,
        Some("debug") => Level::Debug,
        Some("trace") => Level::Trace,
        _ => Level::Info,
    };
    let logger = LOGGER.get_or_init(|| SparkLogger {
        start: Instant::now(),
        level,
    });
    // Ignore the error if a logger is already set (tests call init twice).
    let _ = log::set_logger(logger);
    log::set_max_level(LevelFilter::Trace.min(match level {
        Level::Error => LevelFilter::Error,
        Level::Warn => LevelFilter::Warn,
        Level::Info => LevelFilter::Info,
        Level::Debug => LevelFilter::Debug,
        Level::Trace => LevelFilter::Trace,
    }));
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke message");
    }
}
