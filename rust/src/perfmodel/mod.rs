//! V100 performance projection: roofline over the I/O model.
//!
//! Interpret-mode Pallas wallclock on a CPU is not a hardware proxy, so the
//! paper's absolute Fig 10/11 numbers are *projected*: we combine the FLOP
//! counts (Equation 1/4) with the HBM traffic from `iomodel` under a V100
//! roofline (112 TFLOP/s FP16 TCU, 28 TFLOP/s CUDA-core FP32, 900 GB/s
//! HBM2).  The projection answers the questions the paper's figures answer:
//! who wins, by what factor, and where the memory wall sits.
//!
//! Model: `t = max(t_compute, t_memory) + t_launch · kernels` per stage.
//! The unfused baseline additionally pays CUDA-core time for the softmax
//! (the paper's challenge #1: scalar work cannot run on the TCU).

use crate::iomodel::{self, MhaShape};

/// Hardware description for the roofline.
#[derive(Debug, Clone, Copy)]
pub struct Machine {
    /// Matrix-unit peak (FP16 Tensor Core on V100): FLOP/s.
    pub matrix_flops: f64,
    /// Scalar/vector peak (CUDA cores, FP32): FLOP/s.
    pub scalar_flops: f64,
    /// HBM bandwidth: bytes/s.
    pub hbm_bw: f64,
    /// Fixed cost per kernel launch: seconds.
    pub launch_overhead: f64,
    /// Device memory capacity: bytes (OOM threshold).
    pub hbm_capacity: usize,
    /// Achievable fraction of peak (empirical de-rating).
    pub efficiency: f64,
}

/// NVIDIA V100-SXM2-32GB (§4.1 of the paper).
pub const V100: Machine = Machine {
    matrix_flops: 112e12,
    scalar_flops: 28e12,
    hbm_bw: 900e9,
    launch_overhead: 5e-6,
    hbm_capacity: 32 * (1 << 30),
    efficiency: 0.55,
};

/// What a projected stage spent its time on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Bound {
    /// FLOP-limited: the roofline's compute ceiling binds.
    Compute,
    /// Bandwidth-limited: HBM traffic binds.
    Memory,
    /// Infeasible: working set exceeds device memory.
    Oom,
}

/// Projection result for one schedule.
#[derive(Debug, Clone, Copy)]
pub struct Projection {
    /// Projected wallclock (seconds).
    pub seconds: f64,
    /// Which roofline ceiling bound the stage.
    pub bound: Bound,
    /// Achieved TFLOP/s at the projected time.
    pub tflops: f64,
    /// Total HBM bytes moved.
    pub hbm_bytes: usize,
}

fn stage_time(m: &Machine, matrix_flops: f64, scalar_flops: f64,
              bytes: f64) -> (f64, Bound) {
    let t_c = matrix_flops / (m.matrix_flops * m.efficiency)
        + scalar_flops / (m.scalar_flops * m.efficiency);
    let t_m = bytes / (m.hbm_bw * m.efficiency);
    if t_c >= t_m {
        (t_c, Bound::Compute)
    } else {
        (t_m, Bound::Memory)
    }
}

/// Project the **fused** forward (one kernel, overlapped compute/traffic).
pub fn project_fused_fwd(m: &Machine, s: MhaShape, causal: bool,
                         block_q: usize) -> Projection {
    if iomodel::peak_resident_bytes(s, true) > m.hbm_capacity {
        return oom(s, true);
    }
    let flops = crate::attention::attention_flops(s.bh, s.n, s.d, causal,
                                                  false) as f64;
    // softmax exponentials ride on CUDA cores: ~5 scalar ops per score
    let scalar = 5.0 * (s.bh * s.n * s.n) as f64 * if causal { 0.5 } else { 1.0 };
    let traffic = iomodel::analytic_fused_fwd_streamed(s, block_q);
    let (t, bound) = stage_time(m, flops, scalar, traffic.total_bytes() as f64);
    let t = t + m.launch_overhead;
    Projection { seconds: t, bound, tflops: flops / t / 1e12,
                 hbm_bytes: traffic.total_bytes() }
}

/// Project the **unfused** forward: staged kernels (PyTorch eager), each
/// stage its own roofline, N×N round-trips between stages, softmax +
/// dropout masks on CUDA cores.  Stages cannot overlap with each other.
pub fn project_unfused_fwd(m: &Machine, s: MhaShape, causal: bool)
                           -> Projection {
    if iomodel::peak_resident_bytes(s, false) > m.hbm_capacity {
        return oom(s, false);
    }
    let flops = crate::attention::attention_flops(s.bh, s.n, s.d, causal,
                                                  false) as f64;
    let op = s.operand_bytes() as f64;
    let nn = s.score_bytes() as f64;
    let nn_scalar = (s.bh * s.n * s.n) as f64;
    // Stage 1: S = QKᵀ
    let (t1, b1) = stage_time(m, flops / 2.0, 0.0, 2.0 * op + nn);
    // Stage 2: softmax (pure scalar + full N×N round-trip)
    let (t2, b2) = stage_time(m, 0.0, 5.0 * nn_scalar, 2.0 * nn);
    // Stage 2b: dropout (mask generation + apply; another N×N round-trip —
    // the paper benches with dropout 0.1, which the fused kernel hides)
    let (t2b, _) = stage_time(m, 0.0, 3.0 * nn_scalar, 2.0 * nn);
    // Stage 3: O = PV
    let (t3, b3) = stage_time(m, flops / 2.0, 0.0, nn + 2.0 * op);
    let t = t1 + t2 + t2b + t3 + 4.0 * m.launch_overhead;
    let bound = if t2 + t2b > t1 + t3 { b2 } else if t1 > t3 { b1 } else { b3 };
    let traffic = iomodel::analytic_unfused_fwd(s);
    Projection { seconds: t, bound, tflops: flops / t / 1e12,
                 hbm_bytes: traffic.total_bytes() }
}

/// Project the fused backward (recompute adds ~1 extra matmul to the 5 of
/// Equation 4; all traffic stays operand-sized).
pub fn project_fused_bwd(m: &Machine, s: MhaShape, causal: bool)
                         -> Projection {
    if iomodel::peak_resident_bytes(s, true) > m.hbm_capacity {
        return oom(s, true);
    }
    let flops = crate::attention::attention_flops(s.bh, s.n, s.d, causal,
                                                  true) as f64 * 1.2;
    let scalar = 8.0 * (s.bh * s.n * s.n) as f64
        * if causal { 0.5 } else { 1.0 };
    let traffic = iomodel::analytic_fused_bwd(s);
    let (t, bound) = stage_time(m, flops, scalar,
                                traffic.total_bytes() as f64);
    let t = t + 2.0 * m.launch_overhead; // dq kernel + dkv kernel
    Projection { seconds: t, bound, tflops: flops / t / 1e12,
                 hbm_bytes: traffic.total_bytes() }
}

/// Project the unfused backward: PyTorch autograd replays Equation 4 as
/// five separate GEMM/elementwise kernels over the saved S/P (+ dropout
/// mask), each with its own N×N traffic, no cross-stage overlap.
pub fn project_unfused_bwd(m: &Machine, s: MhaShape, causal: bool)
                           -> Projection {
    if iomodel::peak_resident_bytes(s, false) > m.hbm_capacity {
        return oom(s, false);
    }
    let flops = crate::attention::attention_flops(s.bh, s.n, s.d, causal,
                                                  true) as f64;
    let gemm = flops / 5.0;
    let op = s.operand_bytes() as f64;
    let nn = s.score_bytes() as f64;
    let nn_scalar = (s.bh * s.n * s.n) as f64;
    let mut t = 0.0;
    let mut t_mem = 0.0;
    // dV = P_dropᵀ·dO — reads the saved P and the dropout mask
    let (t1, b) = stage_time(m, gemm, 0.0, nn + 2.0 * op);
    t += t1;
    t_mem += if b == Bound::Memory { t1 } else { 0.0 };
    // dP = dO·Vᵀ — writes N×N
    let (t2, b) = stage_time(m, gemm, 0.0, 2.0 * op + nn);
    t += t2;
    t_mem += if b == Bound::Memory { t2 } else { 0.0 };
    // dropout bwd + dsoftmax: read dP, P, mask; write dS (scalar-only)
    let (t3, _) = stage_time(m, 0.0, 8.0 * nn_scalar, 4.0 * nn);
    t += t3;
    t_mem += t3;
    // dQ = dS·K and dK = dSᵀ·Q — each re-reads the N×N dS
    for _ in 0..2 {
        let (ti, b) = stage_time(m, gemm, 0.0, nn + 2.0 * op);
        t += ti;
        t_mem += if b == Bound::Memory { ti } else { 0.0 };
    }
    let t = t + 6.0 * m.launch_overhead;
    let bound = if t_mem > t / 2.0 { Bound::Memory } else { Bound::Compute };
    let traffic = iomodel::analytic_unfused_bwd(s);
    Projection { seconds: t, bound, tflops: flops / t / 1e12,
                 hbm_bytes: traffic.total_bytes() }
}

fn oom(s: MhaShape, fused: bool) -> Projection {
    Projection {
        seconds: f64::INFINITY,
        bound: Bound::Oom,
        tflops: 0.0,
        hbm_bytes: iomodel::peak_resident_bytes(s, fused),
    }
}

/// The paper's hyperparameter grid (§4.1): heads = 2048/d, batch = 16384/n.
pub fn paper_shape(n: usize, d: usize) -> MhaShape {
    let heads = 2048 / d;
    let batch = (16384 / n).max(1);
    MhaShape::new(batch * heads, n, d)
}

// ---------------------------------------------------------------------------
// Fig 12: encoder-layer end-to-end projection
// ---------------------------------------------------------------------------

/// One encoder layer's non-attention work: QKV/O projections + FFN (GEMMs)
/// + layernorms/residuals (scalar + memory).
fn encoder_rest_time(m: &Machine, batch: usize, n: usize, d_model: usize,
                     fused_rest: bool) -> f64 {
    let tokens = (batch * n) as f64;
    let dm = d_model as f64;
    let d_ff = 4.0 * dm;
    // GEMM FLOPs: 4 projections (dm×dm) + 2 FFN (dm×d_ff)
    let gemm_flops = tokens * (4.0 * 2.0 * dm * dm + 2.0 * 2.0 * dm * d_ff);
    // activation traffic: each op reads/writes token×dm (or ×d_ff) tiles
    let act = tokens * dm * 2.0;
    let traffic = if fused_rest {
        // FT-style layer fusion: bias/GELU/LN ride inside the GEMM epilogue
        6.0 * act + tokens * d_ff * 2.0
    } else {
        // separate kernels: every intermediate round-trips
        12.0 * act + 3.0 * tokens * d_ff * 2.0
    };
    let scalar = tokens * (10.0 * dm + 8.0 * d_ff);
    let (t, _) = stage_time(m, gemm_flops, scalar, traffic);
    let launches = if fused_rest { 6.0 } else { 14.0 };
    t + launches * m.launch_overhead
}

/// Encoder-layer latency under each Fig 12 variant.
///
/// `variant` ∈ {"pytorch_jit", "sparkattention", "fastertransformer"}.
pub fn project_encoder(m: &Machine, batch: usize, n: usize, d_model: usize,
                       num_heads: usize, variant: &str) -> Projection {
    let d = d_model / num_heads;
    let s = MhaShape::new(batch * num_heads, n, d);
    let attn = match variant {
        // FT's generic MHA materialises S/P like PyTorch (its fully-fused
        // MHA only covers short sequences); its edge is layer fusion of
        // the *rest* — which is exactly how §4.2.4 explains Fig 12.
        "pytorch_jit" | "fastertransformer" => {
            project_unfused_fwd(m, s, false)
        }
        "sparkattention" => project_fused_fwd(m, s, false, 128),
        other => panic!("unknown encoder variant {other:?}"),
    };
    if attn.bound == Bound::Oom {
        return attn;
    }
    let rest = encoder_rest_time(m, batch, n, d_model,
                                 variant == "fastertransformer");
    let seconds = attn.seconds + rest;
    Projection { seconds, bound: attn.bound, tflops: 0.0,
                 hbm_bytes: attn.hbm_bytes }
}

/// Paper Fig 12 grid: hidden 2048, batch = 16384/n.
pub fn paper_encoder_point(n: usize, d_head: usize) -> (usize, usize, usize) {
    let d_model = 2048;
    let num_heads = d_model / d_head;
    let batch = (16384 / n).max(1);
    (batch, d_model, num_heads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fused_beats_unfused_everywhere_on_the_grid() {
        for d in [64, 128] {
            for n in [512, 1024, 2048, 4096] {
                let s = paper_shape(n, d);
                let f = project_fused_fwd(&V100, s, false, 128);
                let u = project_unfused_fwd(&V100, s, false);
                assert!(f.seconds < u.seconds,
                        "fused must win at n={n} d={d}");
            }
        }
    }

    #[test]
    fn speedup_magnitude_matches_paper_band() {
        // Paper: forward average 4.55× (up to 9.17×).  The projection
        // should land in the same regime (≳3× average, single digits).
        let mut ratios = vec![];
        for d in [64, 128] {
            for n in [512, 1024, 2048, 4096] {
                let s = paper_shape(n, d);
                let f = project_fused_fwd(&V100, s, false, 128);
                let u = project_unfused_fwd(&V100, s, false);
                if u.bound != Bound::Oom {
                    ratios.push(u.seconds / f.seconds);
                }
            }
        }
        let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!(avg > 2.5 && avg < 12.0, "avg projected speedup {avg}");
        let max = ratios.iter().cloned().fold(0.0, f64::max);
        assert!(max < 20.0, "max projected speedup {max}");
    }

    #[test]
    fn long_sequences_oom_only_unfused() {
        // n = 16384: paper reports PyTorch OOM, SparkAttention fine.
        let s = paper_shape(16384, 64);
        let u = project_unfused_fwd(&V100, s, false);
        let f = project_fused_fwd(&V100, s, false, 128);
        assert_eq!(u.bound, Bound::Oom);
        assert!(f.seconds.is_finite());
    }

    #[test]
    fn unfused_is_memory_or_scalar_bound_at_long_seq() {
        let s = paper_shape(4096, 64);
        let u = project_unfused_fwd(&V100, s, false);
        assert_eq!(u.bound, Bound::Memory,
                   "N×N round-trips must dominate the unfused forward");
    }

    #[test]
    fn causal_halves_fused_compute() {
        let s = paper_shape(2048, 128);
        let full = project_fused_fwd(&V100, s, false, 128);
        let causal = project_fused_fwd(&V100, s, true, 128);
        assert!(causal.seconds < full.seconds);
    }

    #[test]
    fn backward_speedup_band() {
        // Paper: backward average 3.44× (up to 7.91×).
        let mut ratios = vec![];
        for d in [64, 128] {
            for n in [512, 1024, 2048] {
                let s = paper_shape(n, d);
                let f = project_fused_bwd(&V100, s, false);
                let u = project_unfused_bwd(&V100, s, false);
                ratios.push(u.seconds / f.seconds);
            }
        }
        let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!(avg > 1.8 && avg < 9.0, "avg projected bwd speedup {avg}");
    }

    #[test]
    fn tflops_rise_with_sequence_length() {
        // Fig 10's visual: SparkAttention utilisation grows with n.
        let a = project_fused_fwd(&V100, paper_shape(512, 64), false, 128);
        let b = project_fused_fwd(&V100, paper_shape(4096, 64), false, 128);
        assert!(b.tflops >= a.tflops * 0.9,
                "tflops should not collapse with n: {} vs {}",
                a.tflops, b.tflops);
    }

    #[test]
    fn encoder_projection_matches_fig12_story() {
        // SparkAttention beats PyTorch-JIT end-to-end, in the paper's band.
        let mut ratios = vec![];
        for d_head in [64usize, 128] {
            for n in [512usize, 1024, 2048, 4096] {
                let (b, dm, h) = paper_encoder_point(n, d_head);
                let py = project_encoder(&V100, b, n, dm, h, "pytorch_jit");
                let ours = project_encoder(&V100, b, n, dm, h,
                                           "sparkattention");
                if py.bound != Bound::Oom {
                    ratios.push(py.seconds / ours.seconds);
                }
            }
        }
        let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!(avg > 1.2 && avg < 3.5,
                "e2e projected speedup {avg} (paper: 1.80)");
    }

    #[test]
    fn ft_analog_is_the_closest_competitor() {
        // §4.2.4's robust part: FT (layer fusion + unfused generic MHA)
        // beats plain PyTorch-JIT everywhere and SparkAttention leads it
        // at head-dim 128.  The paper's FT-wins-at-d64 crossover depends
        // on FT's autotuned GEMM details that a traffic roofline cannot
        // capture — documented as a non-reproduced nuance in
        // EXPERIMENTS.md §E4.
        let n = 2048;
        for d_head in [64usize, 128] {
            let (b, dm, h) = paper_encoder_point(n, d_head);
            let py = project_encoder(&V100, b, n, dm, h, "pytorch_jit");
            let ft = project_encoder(&V100, b, n, dm, h,
                                     "fastertransformer");
            assert!(ft.seconds < py.seconds,
                    "FT must beat PyTorch-JIT at d_head={d_head}");
        }
        let (b, dm, h) = paper_encoder_point(n, 128);
        let ft = project_encoder(&V100, b, n, dm, h, "fastertransformer");
        let ours = project_encoder(&V100, b, n, dm, h, "sparkattention");
        assert!(ours.seconds < ft.seconds,
                "SparkAttention should lead FT at head-dim 128");
    }

    #[test]
    fn encoder_oom_cells_at_long_sequence() {
        let (b, dm, h) = paper_encoder_point(16384, 64);
        let py = project_encoder(&V100, b, 16384, dm, h, "pytorch_jit");
        let ours = project_encoder(&V100, b, 16384, dm, h, "sparkattention");
        assert_eq!(py.bound, Bound::Oom);
        assert!(ours.seconds.is_finite());
    }

    #[test]
    fn head_dim_128_uses_hardware_better() {
        // §4.2.1: larger head dim → more compute per byte → higher TFLOPs.
        let a = project_fused_fwd(&V100, paper_shape(2048, 64), false, 128);
        let b = project_fused_fwd(&V100, paper_shape(2048, 128), false, 128);
        assert!(b.tflops > a.tflops);
    }
}
