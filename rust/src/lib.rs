//! SparkAttention — fused multi-head attention for large-model training.
//!
//! Reproduction of "SparkAttention: High-Performance Multi-Head Attention for
//! Large Models on Volta GPU Architecture" (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 1** — Pallas flash-attention kernels (build-time Python, see
//!   `python/compile/kernels/`), AOT-lowered to HLO text.
//! * **Layer 2** — JAX transformer model + train step (`python/compile/`).
//! * **Layer 3** — this crate: the runtime coordinator that loads the AOT
//!   artifacts via PJRT and drives training, benchmarking, and the paper's
//!   evaluation harness. Python never runs on the request path.
//!
//! See `DESIGN.md` for the hardware-adaptation mapping (Volta `m8n8k4` TCU →
//! MXU-style Pallas BlockSpecs), the execution-backend seam, and the
//! per-experiment index.

// Every public item carries documentation; CI denies rustdoc warnings
// (`cargo doc --no-deps` with RUSTDOCFLAGS=-D warnings) so regressions
// fail the build.
#![warn(missing_docs)]
// The tree predates clippy enforcement in CI; these style lints fire on
// the deliberately loop-heavy numeric kernels and stay allowed.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::many_single_char_names)]
#![allow(clippy::manual_memcpy)]

pub mod analysis;
pub mod attention;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod iomodel;
pub mod jsonio;
pub mod logging;
pub mod metrics;
pub mod perfmodel;
pub mod proptest;
pub mod runtime;
pub mod tensor;

/// Crate version, re-exported for the CLI `--version` flag.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
