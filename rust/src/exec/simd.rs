//! Vectorized, mixed-precision execution backend — the host-side
//! emulation of the paper's Tensor-Core numerics.
//!
//! [`Simd`] vectorizes the `Blocked` microkernels with AVX2/FMA
//! intrinsics (runtime `is_x86_feature_detected!` dispatch, with a
//! portable chunked-unrolled fallback on every other target) and
//! supports two numeric modes, selected by [`Precision`]:
//!
//! * **`f32`** — full-precision operands and the exact per-element
//!   operation order of the `Scalar` reference (multiply, then add,
//!   k ascending, same zero-skips).  Results are **bitwise identical**
//!   to `Scalar` and `Blocked` on every target: the AVX path uses
//!   separate `mul`/`add` instructions (never FMA, which would skip the
//!   intermediate rounding), and lanes never reassociate the k-chain.
//! * **`mixed`** — the paper's TCU contract (§3.1): every GEMM operand
//!   is quantized to bf16 (`tensor::bf16::quantize`, round-to-nearest-
//!   even) as it is staged for the kernels, while every accumulator
//!   stays f32.  The FMA form is used where available.  Results deviate
//!   from f32 by a bounded, bf16-epsilon-derived error (see
//!   `rust/tests/exec_backend.rs`) but remain bitwise-deterministic
//!   across thread counts, because quantization is elementwise and the
//!   accumulation order is fixed by the tile partition alone.
//!
//! The quantization point mirrors where a Volta kernel converts to
//! fp16 fragments before an `mma` issue: once per operand element
//! before it enters a kernel (quantization is elementwise, so staging
//! a whole operand up front equals quantizing per tile while doing the
//! conversion once), never on accumulators, never on softmax
//! statistics.

use anyhow::{bail, Result};

use super::{available_threads, par_batch_row_tiles, run_pool, tune,
            Backend, Task, KC, MC};
use crate::tensor::{bf16, dims3, Tensor};

/// Lane width of the packed panels (AVX2 = 8 × f32).
const LANES: usize = 8;

/// Numeric mode of the [`Simd`] backend.  Orderable and hashable so it
/// can key the autotuner's per-problem-class tables (`exec::tune`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash,
         Default)]
pub enum Precision {
    /// Full-precision f32 operands and accumulators; bitwise-matching
    /// the `Scalar` reference (the existing accumulation-order
    /// determinism contract).
    #[default]
    F32,
    /// TCU emulation: operands quantized to bf16 at kernel-staging
    /// time, f32 accumulators — the paper's FP16-in/FP32-accumulate
    /// contract mapped onto this port's bf16 interchange dtype.
    Mixed,
}

impl Precision {
    /// Parse the config/CLI spelling (`"f32"` or `"mixed"`).
    pub fn parse(s: &str) -> Result<Precision> {
        match s {
            "f32" => Ok(Precision::F32),
            "mixed" => Ok(Precision::Mixed),
            other => bail!("unknown precision {other:?} \
                            (expected \"f32\" or \"mixed\")"),
        }
    }

    /// Canonical config spelling (inverse of [`Precision::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Mixed => "mixed",
        }
    }
}

/// Vectorized execution backend with selectable numerics.
#[derive(Debug, Clone, Copy)]
pub struct Simd {
    threads: usize,
    precision: Precision,
    mc: usize,
    kc: usize,
    use_avx: bool,
    fixed: bool,
}

impl Simd {
    /// Backend with the default (`MC`×`KC`) blocking, overridden per
    /// problem class by the installed `exec::tune` table, when there is
    /// one.  `threads == 0` resolves to the machine's available
    /// parallelism.
    pub fn new(threads: usize, precision: Precision) -> Self {
        Simd { fixed: false,
               ..Simd::with_blocks(threads, precision, MC, KC) }
    }

    /// Pinned custom block sizes (the tuner and the block-sweep
    /// property tests use this) — never consults the tuning table.
    pub fn with_blocks(threads: usize, precision: Precision, mc: usize,
                       kc: usize) -> Self {
        let threads = if threads == 0 {
            available_threads()
        } else {
            threads
        };
        Simd {
            threads,
            precision,
            mc: mc.max(1),
            kc: kc.max(1),
            use_avx: detect_avx(),
            fixed: true,
        }
    }

    /// Block shapes for one `(m, k, n)` matmul: pinned values, or the
    /// installed tuning table's winner (keyed on this backend's numeric
    /// mode) with the defaults as fallback.  Block shape never changes
    /// bits (see `exec::tune`), only speed.
    fn blocks(&self, m: usize, k: usize, n: usize) -> (usize, usize) {
        if self.fixed {
            return (self.mc, self.kc);
        }
        let bl = tune::blocks_for(m, k, n, self.precision,
                                  tune::Blocks { mc: self.mc,
                                                 kc: self.kc });
        (bl.mc, bl.kc)
    }

    /// Whether the AVX2+FMA code path was selected at construction
    /// (false on non-x86_64 targets or older CPUs — the portable
    /// fallback preserves the same numerics either way).
    pub fn avx(&self) -> bool {
        self.use_avx
    }

    /// Mixed mode fuses multiply-add (no intermediate rounding); f32
    /// mode must not, to stay bitwise-equal to `Scalar`.
    fn fused(&self) -> bool {
        self.precision == Precision::Mixed
    }

    /// `acc[i] += a * b[i]` over the full slice, honouring this
    /// backend's rounding mode.
    #[inline]
    fn axpy(&self, acc: &mut [f32], a: f32, b: &[f32]) {
        debug_assert_eq!(acc.len(), b.len());
        #[cfg(target_arch = "x86_64")]
        if self.use_avx {
            // SAFETY: `use_avx` is only true when AVX2 and FMA were
            // detected at construction (`detect_avx`).
            unsafe { avx::axpy(acc, a, b, self.fused()) };
            return;
        }
        portable::axpy(acc, a, b, self.fused());
    }

    /// `accrow[j] += arow[k] * packb[k*LANES + j]` for all k, over one
    /// 8-lane accumulator row (`accrow.len() == LANES`,
    /// `packb.len() == arow.len() * LANES`).
    #[inline]
    fn panel(&self, accrow: &mut [f32], arow: &[f32], packb: &[f32]) {
        debug_assert_eq!(accrow.len(), LANES);
        debug_assert_eq!(packb.len(), arow.len() * LANES);
        #[cfg(target_arch = "x86_64")]
        if self.use_avx {
            // SAFETY: gated on the construction-time AVX2+FMA probe;
            // slice lengths are asserted above.
            unsafe { avx::panel(accrow, arow, packb, self.fused()) };
            return;
        }
        portable::panel(accrow, arow, packb, self.fused());
    }

    /// NN tile: rows `i0..i0+rows` of A·B, k-blocked, vectorized axpy
    /// rows.  Per output element the k-terms accumulate ascending with
    /// a zero-skip — the `tensor::batch_matmul` order exactly.
    /// Operands arrive already staged (quantized in mixed mode).
    fn nn_tile(&self, ap: &[f32], bp: &[f32], tile: &mut [f32], i0: usize,
               rows: usize, ka: usize, n: usize, kc: usize) {
        for kk in (0..ka).step_by(kc) {
            let kend = (kk + kc).min(ka);
            for r in 0..rows {
                let arow = &ap[(i0 + r) * ka + kk..(i0 + r) * ka + kend];
                let orow = &mut tile[r * n..(r + 1) * n];
                for (k, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    self.axpy(orow, av,
                              &bp[(kk + k) * n..(kk + k + 1) * n]);
                }
            }
        }
    }

    /// NT tile: rows `i0..i0+rows` of A·Bᵀ.  The B panel is
    /// transpose-packed into k-major 8-wide lanes, so the inner loop is
    /// a contiguous broadcast-multiply-accumulate.  Each output element
    /// remains a single k-ascending chain, matching
    /// `tensor::batch_matmul_nt` bitwise in f32 mode.
    fn nt_tile(&self, ap: &[f32], bp: &[f32], tile: &mut [f32], i0: usize,
               rows: usize, ka: usize, n: usize, kc: usize) {
        let kc = kc.min(ka.max(1));
        let mut packb = vec![0.0f32; kc * LANES];
        let mut acc = vec![0.0f32; rows * LANES];
        let mut j0 = 0;
        while j0 < n {
            let nr = LANES.min(n - j0);
            acc.fill(0.0);
            for kk in (0..ka).step_by(kc) {
                let kend = (kk + kc).min(ka);
                // transpose-pack B[j0..j0+nr][kk..kend], k-major
                for k in kk..kend {
                    let dst = &mut packb[(k - kk) * LANES
                                         ..(k - kk + 1) * LANES];
                    for (jj, d) in dst[..nr].iter_mut().enumerate() {
                        *d = bp[(j0 + jj) * ka + k];
                    }
                    dst[nr..].fill(0.0);
                }
                for r in 0..rows {
                    let arow =
                        &ap[(i0 + r) * ka + kk..(i0 + r) * ka + kend];
                    let accrow = &mut acc[r * LANES..(r + 1) * LANES];
                    self.panel(accrow, arow, &packb[..(kend - kk) * LANES]);
                }
            }
            for r in 0..rows {
                tile[r * n + j0..r * n + j0 + nr]
                    .copy_from_slice(&acc[r * LANES..r * LANES + nr]);
            }
            j0 += nr;
        }
    }

    /// TN tile: output rows `i0..i0+rows` (columns of A), k-ascending
    /// vectorized axpy with the `tensor::batch_matmul_tn` zero-skip.
    fn tn_tile(&self, ap: &[f32], bp: &[f32], tile: &mut [f32], i0: usize,
               rows: usize, ka: usize, m: usize, n: usize) {
        for k in 0..ka {
            let arow = &ap[k * m..(k + 1) * m];
            let brow = &bp[k * n..(k + 1) * n];
            for r in 0..rows {
                let av = arow[i0 + r];
                if av == 0.0 {
                    continue;
                }
                let orow = &mut tile[r * n..(r + 1) * n];
                self.axpy(orow, av, brow);
            }
        }
    }

    /// Stage a pair of operands for the kernels: in mixed mode both are
    /// bf16-quantized **once per matmul** here (the operand-pack point
    /// of the TCU contract — quantization is elementwise, so staging up
    /// front is numerically identical to quantizing per tile while
    /// doing the conversion work exactly once); in f32 mode the inputs
    /// are borrowed untouched.
    fn stage<'a>(&self, a: &'a [f32], b: &'a [f32],
                 store: &'a mut Option<(Vec<f32>, Vec<f32>)>)
                 -> (&'a [f32], &'a [f32]) {
        if !self.fused() {
            return (a, b);
        }
        let quant = |xs: &[f32]| -> Vec<f32> {
            xs.iter().map(|&x| bf16::quantize(x)).collect()
        };
        let pair = store.insert((quant(a), quant(b)));
        (&pair.0, &pair.1)
    }
}

impl Backend for Simd {
    fn name(&self) -> String {
        match self.precision {
            Precision::F32 => format!("simd_t{}", self.threads),
            Precision::Mixed => format!("simd_t{}_mixed", self.threads),
        }
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn batch_matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (ba, m, ka) = dims3(a);
        let (bb, kb, n) = dims3(b);
        assert_eq!(ba, bb, "batch mismatch");
        assert_eq!(ka, kb, "inner dim mismatch");
        let mut out = vec![0.0f32; ba * m * n];
        let mut staged = None;
        let (ad, bd) = self.stage(a.data(), b.data(), &mut staged);
        let this = *self;
        let (mc, kc) = self.blocks(m, ka, n);
        par_batch_row_tiles(self.threads, ba, m, n, mc, &mut out,
                            |bi, i0, rows, tile| {
            let ap = &ad[bi * m * ka..(bi + 1) * m * ka];
            let bp = &bd[bi * ka * n..(bi + 1) * ka * n];
            this.nn_tile(ap, bp, tile, i0, rows, ka, n, kc);
        });
        Tensor::new(vec![ba, m, n], out)
    }

    fn batch_matmul_nt(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (ba, m, ka) = dims3(a);
        let (bb, n, kb) = dims3(b);
        assert_eq!(ba, bb, "batch mismatch");
        assert_eq!(ka, kb, "inner dim mismatch");
        let mut out = vec![0.0f32; ba * m * n];
        let mut staged = None;
        let (ad, bd) = self.stage(a.data(), b.data(), &mut staged);
        let this = *self;
        let (mc, kc) = self.blocks(m, ka, n);
        par_batch_row_tiles(self.threads, ba, m, n, mc, &mut out,
                            |bi, i0, rows, tile| {
            let ap = &ad[bi * m * ka..(bi + 1) * m * ka];
            let bp = &bd[bi * n * ka..(bi + 1) * n * ka];
            this.nt_tile(ap, bp, tile, i0, rows, ka, n, kc);
        });
        Tensor::new(vec![ba, m, n], out)
    }

    fn batch_matmul_tn(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (ba, ka, m) = dims3(a);
        let (bb, kb, n) = dims3(b);
        assert_eq!(ba, bb, "batch mismatch");
        assert_eq!(ka, kb, "inner dim mismatch");
        let mut out = vec![0.0f32; ba * m * n];
        let mut staged = None;
        let (ad, bd) = self.stage(a.data(), b.data(), &mut staged);
        let this = *self;
        let (mc, _) = self.blocks(m, ka, n);
        par_batch_row_tiles(self.threads, ba, m, n, mc, &mut out,
                            |bi, i0, rows, tile| {
            let ap = &ad[bi * ka * m..(bi + 1) * ka * m];
            let bp = &bd[bi * ka * n..(bi + 1) * ka * n];
            this.tn_tile(ap, bp, tile, i0, rows, ka, m, n);
        });
        Tensor::new(vec![ba, m, n], out)
    }

    fn run_tasks<'s>(&self, tasks: Vec<Task<'s>>) {
        run_pool(self.threads, tasks);
    }
}

/// Runtime CPU-feature probe: AVX2 + FMA on x86_64, always false
/// elsewhere (the portable kernels carry the same numerics).
fn detect_avx() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::is_x86_feature_detected!("avx2")
            && std::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

#[cfg(target_arch = "x86_64")]
mod avx {
    //! AVX2/FMA microkernels.  Every function here is `unsafe` because
    //! callers must guarantee the features exist (checked once at
    //! backend construction); slice accesses themselves stay in bounds
    //! by the length contracts documented on each kernel.

    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_mul_ps,
        _mm256_set1_ps, _mm256_storeu_ps,
    };

    /// `acc[i] += a * b[i]` (`acc.len() == b.len()`).  `fused` selects
    /// FMA; otherwise separate mul/add keep Scalar's per-element
    /// rounding.
    ///
    /// # Safety
    /// AVX2 and FMA must be available on the running CPU.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(acc: &mut [f32], a: f32, b: &[f32], fused: bool) {
        let n = acc.len().min(b.len());
        let av = _mm256_set1_ps(a);
        let mut i = 0;
        // SAFETY: the unaligned loads/stores below touch lanes
        // `i..i + 8` with `i + 8 <= n <= acc.len(), b.len()`, so every
        // pointer offset stays inside both slices; the tail loop uses
        // checked indexing.
        while i + 8 <= n {
            let bv = _mm256_loadu_ps(b.as_ptr().add(i));
            let ov = _mm256_loadu_ps(acc.as_ptr().add(i));
            let r = if fused {
                _mm256_fmadd_ps(av, bv, ov)
            } else {
                _mm256_add_ps(ov, _mm256_mul_ps(av, bv))
            };
            _mm256_storeu_ps(acc.as_mut_ptr().add(i), r);
            i += 8;
        }
        while i < n {
            // tail lanes: identical per-element operation order
            acc[i] = if fused {
                a.mul_add(b[i], acc[i])
            } else {
                acc[i] + a * b[i]
            };
            i += 1;
        }
    }

    /// One 8-lane accumulator row over a k-major packed panel
    /// (`accrow.len() == 8`, `packb.len() == arow.len() * 8`).
    ///
    /// # Safety
    /// AVX2 and FMA must be available; the length contracts above must
    /// hold (the caller debug-asserts them).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn panel(accrow: &mut [f32], arow: &[f32], packb: &[f32],
                        fused: bool) {
        // SAFETY: by the length contract, `accrow` holds exactly 8
        // lanes (one vector load/store) and `packb` holds 8 lanes per
        // `arow` element, so each `add(k * 8)` load reads lanes
        // `k*8..k*8 + 8` inside `packb`.
        let mut acc = _mm256_loadu_ps(accrow.as_ptr());
        for (k, &a) in arow.iter().enumerate() {
            let av = _mm256_set1_ps(a);
            let bv = _mm256_loadu_ps(packb.as_ptr().add(k * 8));
            acc = if fused {
                _mm256_fmadd_ps(av, bv, acc)
            } else {
                _mm256_add_ps(acc, _mm256_mul_ps(av, bv))
            };
        }
        _mm256_storeu_ps(accrow.as_mut_ptr(), acc);
    }
}

mod portable {
    //! Arch-neutral fallback: 8-lane chunked loops the autovectorizer
    //! can lift, with the same per-element operation order as the AVX
    //! path (mul-then-add in f32 mode, `mul_add` in mixed mode).

    use super::LANES;

    /// `acc[i] += a * b[i]` (`acc.len() == b.len()`).
    pub fn axpy(acc: &mut [f32], a: f32, b: &[f32], fused: bool) {
        let mut ac = acc.chunks_exact_mut(LANES);
        let mut bc = b.chunks_exact(LANES);
        for (arow, brow) in (&mut ac).zip(&mut bc) {
            for (o, &bv) in arow.iter_mut().zip(brow) {
                *o = if fused { a.mul_add(bv, *o) } else { *o + a * bv };
            }
        }
        for (o, &bv) in ac.into_remainder().iter_mut().zip(bc.remainder()) {
            *o = if fused { a.mul_add(bv, *o) } else { *o + a * bv };
        }
    }

    /// One 8-lane accumulator row over a k-major packed panel
    /// (`accrow.len() == LANES`, `packb.len() == arow.len() * LANES`).
    pub fn panel(accrow: &mut [f32], arow: &[f32], packb: &[f32],
                 fused: bool) {
        let mut lanes = [0.0f32; LANES];
        lanes.copy_from_slice(accrow);
        for (k, &a) in arow.iter().enumerate() {
            let brow = &packb[k * LANES..(k + 1) * LANES];
            for (o, &bv) in lanes.iter_mut().zip(brow) {
                *o = if fused { a.mul_add(bv, *o) } else { *o + a * bv };
            }
        }
        accrow.copy_from_slice(&lanes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Scalar;
    use crate::tensor::Rng;

    fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        Tensor::randn(shape.to_vec(), &mut r)
    }

    #[test]
    fn precision_parses_and_names() {
        assert_eq!(Precision::parse("f32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("mixed").unwrap(), Precision::Mixed);
        assert!(Precision::parse("fp16").is_err());
        assert_eq!(Precision::F32.name(), "f32");
        assert_eq!(Precision::Mixed.name(), "mixed");
        assert_eq!(Precision::default(), Precision::F32);
    }

    #[test]
    fn names_carry_threads_and_mode() {
        assert_eq!(Simd::new(3, Precision::F32).name(), "simd_t3");
        assert_eq!(Simd::new(2, Precision::Mixed).name(), "simd_t2_mixed");
        assert!(Simd::new(0, Precision::F32).threads() >= 1);
        // the probe is just a flag read; value depends on the machine
        let _ = Simd::new(1, Precision::F32).avx();
    }

    #[test]
    fn f32_mode_is_bitwise_scalar_all_flavours() {
        for (ba, m, k, n, seed) in [(1, 1, 1, 1, 1u64), (2, 7, 13, 5, 2),
                                    (3, 64, 96, 33, 3), (1, 130, 17, 9, 4)] {
            let a_nn = randn(&[ba, m, k], seed);
            let b_nn = randn(&[ba, k, n], seed + 100);
            let b_nt = randn(&[ba, n, k], seed + 200);
            let a_tn = randn(&[ba, k, m], seed + 300);
            for be in [Simd::with_blocks(1, Precision::F32, 3, 4),
                       Simd::with_blocks(4, Precision::F32, 64, 256)] {
                assert_eq!(be.batch_matmul(&a_nn, &b_nn).data(),
                           Scalar.batch_matmul(&a_nn, &b_nn).data(),
                           "nn ({ba},{m},{k},{n}) via {}", be.name());
                assert_eq!(be.batch_matmul_nt(&a_nn, &b_nt).data(),
                           Scalar.batch_matmul_nt(&a_nn, &b_nt).data(),
                           "nt ({ba},{m},{k},{n}) via {}", be.name());
                assert_eq!(be.batch_matmul_tn(&a_tn, &b_nn).data(),
                           Scalar.batch_matmul_tn(&a_tn, &b_nn).data(),
                           "tn ({ba},{m},{k},{n}) via {}", be.name());
            }
        }
    }

    #[test]
    fn mixed_mode_matches_scalar_on_quantized_inputs() {
        // Mixed semantics = f32 accumulation over bf16-quantized
        // operands; differences from Scalar-on-quantized-inputs come
        // only from FMA's skipped intermediate rounding.
        let a = randn(&[2, 33, 21], 7);
        let b = randn(&[2, 21, 18], 8);
        let aq = a.clone().quantize_bf16();
        let bq = b.clone().quantize_bf16();
        let want = Scalar.batch_matmul(&aq, &bq);
        let got = Simd::new(2, Precision::Mixed).batch_matmul(&a, &b);
        assert!(got.max_abs_diff(&want) < 1e-4,
                "fma-vs-mul/add drift should be tiny, got {}",
                got.max_abs_diff(&want));
    }

    #[test]
    fn mixed_mode_is_thread_invariant() {
        let a = randn(&[2, 50, 30], 9);
        let b = randn(&[2, 30, 41], 10);
        let base = Simd::with_blocks(1, Precision::Mixed, 16, 8)
            .batch_matmul(&a, &b);
        for t in [2usize, 3, 8] {
            let got = Simd::with_blocks(t, Precision::Mixed, 16, 8)
                .batch_matmul(&a, &b);
            assert_eq!(got.data(), base.data(), "threads={t}");
        }
    }

    #[test]
    fn empty_shapes_are_fine() {
        let a = Tensor::zeros(vec![0, 4, 3]);
        let b = Tensor::zeros(vec![0, 3, 2]);
        let be = Simd::new(2, Precision::F32);
        assert_eq!(be.batch_matmul(&a, &b).shape(), &[0, 4, 2]);
        let a = Tensor::zeros(vec![2, 0, 3]);
        let b = Tensor::zeros(vec![2, 3, 4]);
        assert_eq!(be.batch_matmul(&a, &b).len(), 0);
        let a = Tensor::zeros(vec![1, 4, 0]);
        let b = Tensor::zeros(vec![1, 5, 0]);
        assert_eq!(be.batch_matmul_nt(&a, &b).shape(), &[1, 4, 5]);
    }
}
