//! Microkernel autotuner and tuning table for the host backends.
//!
//! `Blocked` and `Simd` hardwire their (MC, KC) cache blocking; the
//! paper's Volta kernels pick tile shapes per problem class instead
//! (§3.2), and FlashAttention shows tile choice dominates IO-bound
//! attention throughput.  This module is the host analogue: an
//! autotuner that sweeps candidate (MC, KC) pairs over the two GEMM
//! classes of the attention layer — QKᵀ `(n, d, n)` and P·V `(n, n, d)`
//! — using the same `bench::measure_wallclock` machinery as
//! `benches/ablation_blocks.rs`, and a serializable [`TuningTable`]
//! mapping [`ProblemKey`]s to the winning [`Blocks`].
//!
//! ## How a table takes effect
//!
//! The table is installed process-wide ([`install`] /
//! [`install_from_path`], fed by `[exec] tuning_table`,
//! `--tuning-table`, or `SPARK_EXEC_TUNING_TABLE`).  Backends built
//! with `Blocked::new` / `Simd::new` consult it per matmul via
//! [`blocks_for`]; backends built with `with_blocks` are **pinned** and
//! never consult it — that is what the tuner itself (and the block-
//! sweep property tests) use, so candidate timings can't be rewritten
//! by a previously installed table.
//!
//! ## Why substituting blocks is safe
//!
//! Block shape never changes bits on any backend: `mc` only partitions
//! output rows into tiles, and every kernel accumulates each output
//! element's k-terms in ascending order regardless of `kc` panelling
//! (f32 modes match `Scalar` bitwise; mixed mode keeps one fixed-order
//! FMA chain per element).  So a tuned table is purely a performance
//! choice — `rust/tests/exec_pool.rs` property-tests this for every
//! candidate the tuner can emit.
//!
//! ## Table format (JSON, version 1)
//!
//! ```json
//! {"version": 1,
//!  "entries": [{"m": 256, "k": 64, "n": 256, "precision": "f32",
//!               "mc": 32, "kc": 128}]}
//! ```

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use anyhow::{bail, Context, Result};

use crate::bench;
use crate::jsonio::{self, Value};
use crate::tensor::{Rng, Tensor};

use super::{Backend, BackendKind, Blocked, Precision, Simd, KC, MC};

/// A cache-blocking choice: `mc` rows per task tile, `kc`-deep k-panels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Blocks {
    /// Row-block assigned to one worker task (`exec::MC` analogue).
    pub mc: usize,
    /// k-panel kept hot in cache between row sweeps (`exec::KC`).
    pub kc: usize,
}

impl Blocks {
    /// The hardwired defaults the backends fall back to.
    pub fn default_blocks() -> Blocks {
        Blocks { mc: MC, kc: KC }
    }
}

/// A GEMM problem class the tuner keys its table on: the `(m, k, n)`
/// shape of one batch entry plus the numeric mode it runs in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ProblemKey {
    /// Output rows of one batch entry.
    pub m: usize,
    /// Inner (contraction) dimension.
    pub k: usize,
    /// Output columns of one batch entry.
    pub n: usize,
    /// Numeric mode the measurement ran in.
    pub precision: Precision,
}

/// Winning block shapes per problem class, serializable to JSON.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct TuningTable {
    entries: BTreeMap<ProblemKey, Blocks>,
}

impl TuningTable {
    /// Record (or overwrite) the winner for one problem class.
    pub fn insert(&mut self, key: ProblemKey, blocks: Blocks) {
        self.entries.insert(key, blocks);
    }

    /// Exact-match lookup for one problem class.
    pub fn lookup(&self, key: ProblemKey) -> Option<Blocks> {
        self.entries.get(&key).copied()
    }

    /// Number of recorded problem classes.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the table has no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize as the version-1 table format (see the module docs).
    pub fn to_json(&self) -> Value {
        let entries: Vec<Value> = self
            .entries
            .iter()
            .map(|(key, bl)| {
                jsonio::obj(vec![
                    ("m", jsonio::num(key.m as f64)),
                    ("k", jsonio::num(key.k as f64)),
                    ("n", jsonio::num(key.n as f64)),
                    ("precision", jsonio::s(key.precision.name())),
                    ("mc", jsonio::num(bl.mc as f64)),
                    ("kc", jsonio::num(bl.kc as f64)),
                ])
            })
            .collect();
        jsonio::obj(vec![
            ("version", jsonio::num(1.0)),
            ("entries", Value::Arr(entries)),
        ])
    }

    /// Parse a version-1 table, rejecting unknown versions and
    /// malformed entries.
    pub fn from_json(v: &Value) -> Result<TuningTable> {
        let version = v
            .get("version")
            .and_then(Value::as_usize)
            .context("tuning table: missing numeric \"version\"")?;
        if version != 1 {
            bail!("tuning table: unsupported version {version} \
                   (expected 1)");
        }
        let entries = v
            .get("entries")
            .and_then(Value::as_arr)
            .context("tuning table: missing \"entries\" array")?;
        let mut table = TuningTable::default();
        for (i, e) in entries.iter().enumerate() {
            let field = |name: &str| -> Result<usize> {
                e.get(name).and_then(Value::as_usize).with_context(|| {
                    format!("tuning table entry {i}: missing numeric \
                             \"{name}\"")
                })
            };
            let precision = e
                .get("precision")
                .and_then(Value::as_str)
                .with_context(|| {
                    format!("tuning table entry {i}: missing \
                             \"precision\"")
                })?;
            let precision = Precision::parse(precision)
                .with_context(|| format!("tuning table entry {i}"))?;
            let key = ProblemKey {
                m: field("m")?,
                k: field("k")?,
                n: field("n")?,
                precision,
            };
            let blocks = Blocks {
                mc: field("mc")?.max(1),
                kc: field("kc")?.max(1),
            };
            table.insert(key, blocks);
        }
        Ok(table)
    }

    /// Write the table as JSON to `path`.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, jsonio::to_string(&self.to_json()))
            .with_context(|| format!("writing tuning table {path:?}"))
    }

    /// Read a table back from a JSON file written by [`save`](Self::save).
    pub fn load(path: &str) -> Result<TuningTable> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tuning table {path:?}"))?;
        let v = jsonio::parse(&text)
            .with_context(|| format!("parsing tuning table {path:?}"))?;
        TuningTable::from_json(&v)
            .with_context(|| format!("tuning table {path:?}"))
    }
}

/// The process-wide installed table consulted by `Blocked::new` /
/// `Simd::new` backends (never by pinned `with_blocks` ones).
static SLOT: RwLock<Option<Arc<TuningTable>>> = RwLock::new(None);

/// Install `table` process-wide, replacing any previous one; returns
/// its entry count.
pub fn install(table: TuningTable) -> usize {
    let n = table.len();
    *SLOT.write().unwrap_or_else(|e| e.into_inner()) =
        Some(Arc::new(table));
    n
}

/// The currently installed table, if any.
pub fn installed() -> Option<Arc<TuningTable>> {
    SLOT.read().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Remove the installed table (backends fall back to the defaults).
pub fn uninstall() {
    *SLOT.write().unwrap_or_else(|e| e.into_inner()) = None;
}

/// Load a table from `path` and [`install`] it; returns the entry
/// count.  This is the one implementation behind `[exec] tuning_table`,
/// `--tuning-table`, and `SPARK_EXEC_TUNING_TABLE`.
pub fn install_from_path(path: &str) -> Result<usize> {
    Ok(install(TuningTable::load(path)?))
}

/// Block shapes for one matmul: the installed table's winner for this
/// exact problem class, or `default` when no table is installed or the
/// class is unknown.
pub fn blocks_for(m: usize, k: usize, n: usize, precision: Precision,
                  default: Blocks) -> Blocks {
    match installed() {
        Some(table) => table
            .lookup(ProblemKey { m, k, n, precision })
            .unwrap_or(default),
        None => default,
    }
}

/// The stock candidate grid the tuner sweeps: every (mc, kc) in
/// {16, 32, 64, 128} × {64, 128, 256, 512} — the `ablation_blocks`
/// sweep extended along kc, defaults (64, 256) included.
pub fn default_candidates() -> Vec<Blocks> {
    let mut out = Vec::new();
    for mc in [16usize, 32, 64, 128] {
        for kc in [64usize, 128, 256, 512] {
            out.push(Blocks { mc, kc });
        }
    }
    out
}

/// One tuned problem class: the winner and its timing next to the
/// hardwired defaults' timing.
#[derive(Debug, Clone, Copy)]
pub struct TuneRow {
    /// The problem class that was swept.
    pub key: ProblemKey,
    /// Fastest candidate.
    pub best: Blocks,
    /// Mean seconds of the fastest candidate.
    pub best_s: f64,
    /// Mean seconds of the default (MC, KC) blocking.
    pub default_s: f64,
}

impl TuneRow {
    /// Speedup of the winner over the defaults (1.0 = no gain).
    pub fn speedup(&self) -> f64 {
        if self.best_s > 0.0 {
            self.default_s / self.best_s
        } else {
            1.0
        }
    }
}

/// A backend pinned to candidate blocks (never table-consulting).
/// `Scalar` has no block parameters and mixed precision only exists in
/// `Simd`, so those combinations are errors.
fn fixed_backend(kind: BackendKind, threads: usize, precision: Precision,
                 bl: Blocks) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Scalar => {
            bail!("the scalar backend has no block parameters to tune")
        }
        BackendKind::Blocked => {
            if precision == Precision::Mixed {
                bail!("precision \"mixed\" requires backend = \"simd\"");
            }
            Ok(Box::new(Blocked::with_blocks(threads, bl.mc, bl.kc)))
        }
        BackendKind::Simd => {
            Ok(Box::new(Simd::with_blocks(threads, precision, bl.mc,
                                          bl.kc)))
        }
    }
}

/// Sweep `candidates` over one `(ba, m, k, n)` problem at `precision`
/// and return the winner.  Each candidate times one NN and one NT
/// matmul (the two flavours on the attention forward path) over
/// shape-seeded random operands; the defaults (MC, KC) are timed too so
/// the row carries a defaults-relative speedup.
pub fn tune_problem(kind: BackendKind, threads: usize,
                    precision: Precision, ba: usize, m: usize, k: usize,
                    n: usize, candidates: &[Blocks],
                    opts: bench::Options) -> Result<TuneRow> {
    if candidates.is_empty() {
        bail!("tune_problem: empty candidate list");
    }
    let seed = 0x5AB1_u64
        ^ ((m as u64) << 40) ^ ((k as u64) << 20) ^ n as u64;
    let mut rng = Rng::new(seed);
    let a = Tensor::randn(vec![ba, m, k], &mut rng);
    let b = Tensor::randn(vec![ba, k, n], &mut rng);
    let bt = Tensor::randn(vec![ba, n, k], &mut rng);
    let time_blocks = |bl: Blocks| -> Result<f64> {
        let be = fixed_backend(kind, threads, precision, bl)?;
        let series = bench::measure_wallclock(opts, || {
            let _ = be.batch_matmul(&a, &b);
            let _ = be.batch_matmul_nt(&a, &bt);
            Ok(())
        })?;
        Ok(series.mean())
    };
    let mut best = candidates[0];
    let mut best_s = f64::INFINITY;
    let mut default_s = None;
    for &bl in candidates {
        let mean = time_blocks(bl)?;
        if mean < best_s {
            best = bl;
            best_s = mean;
        }
        if bl == Blocks::default_blocks() {
            default_s = Some(mean);
        }
    }
    let default_s = match default_s {
        Some(s) => s,
        None => time_blocks(Blocks::default_blocks())?,
    };
    Ok(TuneRow {
        key: ProblemKey { m, k, n, precision },
        best,
        best_s,
        default_s,
    })
}

/// Tune the attention layer's GEMM classes for every sequence length in
/// `ns`: QKᵀ `(n, d, n)` and P·V `(n, n, d)` at batch `bh`
/// (batch × heads), in every numeric mode `kind` supports (`Simd`: f32
/// and mixed; `Blocked`: f32).  Returns the winners as an installable
/// [`TuningTable`] plus the per-class rows for reporting.
pub fn tune_attention(kind: BackendKind, threads: usize, ns: &[usize],
                      bh: usize, d: usize, candidates: &[Blocks],
                      opts: bench::Options)
                      -> Result<(TuningTable, Vec<TuneRow>)> {
    if kind == BackendKind::Scalar {
        bail!("the scalar backend has no block parameters to tune \
               (pick blocked or simd)");
    }
    let precisions: &[Precision] = if kind == BackendKind::Simd {
        &[Precision::F32, Precision::Mixed]
    } else {
        &[Precision::F32]
    };
    let mut table = TuningTable::default();
    let mut rows = Vec::new();
    for &n in ns {
        for &precision in precisions {
            for (m, k, nn) in [(n, d, n), (n, n, d)] {
                let row = tune_problem(kind, threads, precision, bh, m,
                                       k, nn, candidates, opts)
                    .with_context(|| {
                        format!("tuning ({m}, {k}, {nn}) at {}",
                                precision.name())
                    })?;
                table.insert(row.key, row.best);
                rows.push(row);
            }
        }
    }
    Ok((table, rows))
}

/// Serializes lib tests that install into the process-wide slot (the
/// table is global state shared across the test harness's threads).
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_table() -> TuningTable {
        let mut t = TuningTable::default();
        t.insert(
            ProblemKey { m: 256, k: 64, n: 256,
                         precision: Precision::F32 },
            Blocks { mc: 32, kc: 128 },
        );
        t.insert(
            ProblemKey { m: 256, k: 256, n: 64,
                         precision: Precision::Mixed },
            Blocks { mc: 128, kc: 64 },
        );
        t
    }

    #[test]
    fn json_round_trip_preserves_entries() {
        let t = sample_table();
        let back = TuningTable::from_json(&t.to_json()).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.len(), 2);
        assert_eq!(
            back.lookup(ProblemKey { m: 256, k: 64, n: 256,
                                     precision: Precision::F32 }),
            Some(Blocks { mc: 32, kc: 128 })
        );
    }

    #[test]
    fn file_round_trip_preserves_entries() {
        let path = std::env::temp_dir().join(format!(
            "spark_tune_test_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let t = sample_table();
        t.save(&path).unwrap();
        let back = TuningTable::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back, t);
    }

    #[test]
    fn from_json_rejects_malformed_tables() {
        for bad in [
            r#"{"entries": []}"#,
            r#"{"version": 2, "entries": []}"#,
            r#"{"version": 1}"#,
            r#"{"version": 1, "entries": [{"m": 1}]}"#,
            r#"{"version": 1, "entries": [{"m": 1, "k": 1, "n": 1,
                "precision": "fp8", "mc": 4, "kc": 4}]}"#,
        ] {
            let v = jsonio::parse(bad).unwrap();
            assert!(TuningTable::from_json(&v).is_err(),
                    "should reject {bad}");
        }
    }

    #[test]
    fn install_lookup_uninstall() {
        let _guard = test_lock();
        uninstall();
        let key = ProblemKey { m: 256, k: 64, n: 256,
                               precision: Precision::F32 };
        let default = Blocks::default_blocks();
        assert_eq!(blocks_for(256, 64, 256, Precision::F32, default),
                   default, "no table → defaults");
        assert_eq!(install(sample_table()), 2);
        assert_eq!(installed().unwrap().lookup(key),
                   Some(Blocks { mc: 32, kc: 128 }));
        assert_eq!(blocks_for(256, 64, 256, Precision::F32, default),
                   Blocks { mc: 32, kc: 128 });
        // unknown class and wrong precision fall back to defaults
        assert_eq!(blocks_for(512, 64, 512, Precision::F32, default),
                   default);
        assert_eq!(blocks_for(256, 64, 256, Precision::Mixed, default),
                   default);
        uninstall();
        assert!(installed().is_none());
        assert_eq!(blocks_for(256, 64, 256, Precision::F32, default),
                   default);
    }

    #[test]
    fn candidate_grid_covers_the_defaults() {
        let cands = default_candidates();
        assert_eq!(cands.len(), 16);
        assert!(cands.contains(&Blocks::default_blocks()));
    }

    #[test]
    fn tune_problem_picks_a_candidate() {
        let cands = [Blocks { mc: 8, kc: 16 }, Blocks { mc: 16, kc: 8 }];
        let opts = bench::Options { warmup_iters: 0, iters: 1 };
        let row = tune_problem(BackendKind::Blocked, 1, Precision::F32,
                               1, 16, 8, 16, &cands, opts).unwrap();
        assert!(cands.contains(&row.best));
        assert!(row.best_s.is_finite() && row.best_s >= 0.0);
        assert!(row.default_s.is_finite());
        assert!(row.speedup() > 0.0);
    }

    #[test]
    fn tune_rejects_scalar_and_mixed_blocked() {
        let opts = bench::Options { warmup_iters: 0, iters: 1 };
        assert!(tune_attention(BackendKind::Scalar, 1, &[8], 1, 4,
                               &default_candidates(), opts).is_err());
        assert!(tune_problem(BackendKind::Blocked, 1, Precision::Mixed,
                             1, 8, 4, 8, &[Blocks { mc: 4, kc: 4 }],
                             opts).is_err());
    }
}
