//! Persistent worker pool shared by the parallel execution backends.
//!
//! The original `exec::run_pool` spun up a fresh `std::thread::scope`
//! on every `Backend::run_tasks` call — one `clone(2)` per worker per
//! matmul.  The paper's throughput argument (§3) is exactly about
//! keeping compute units fed without per-call launch overhead, so the
//! host analogue gets the same treatment: a process-wide pool of
//! long-lived workers ([`global`]), spawned lazily on first demand and
//! reused by every subsequent `run_pool` call from any backend.
//!
//! ## Scheduling
//!
//! Each [`Pool::run`] call forms one [`Job`]: the task list is dealt
//! round-robin into per-participant deques (the submitting thread is
//! participant 0), and every participant pops its own queue from the
//! front, then **steals from the back** of the other queues once its
//! own runs dry.  The initial partition is identical to the old scoped
//! pool's static round-robin split, so the common case (uniform tiles,
//! idle workers) executes the same schedule; stealing only changes who
//! *runs* a task under load, never what the task writes.
//!
//! ## Determinism
//!
//! Bitwise determinism across thread counts remains the repo's
//! contract.  It never depended on the pool: tasks built by
//! `par_batch_row_tiles`/`par_row_chunks` own disjoint output tiles
//! (`exec::carve`) and fix their accumulation order internally, so any
//! execution order — including work-stealing's timing-dependent one —
//! produces identical bits.  `rust/tests/exec_pool.rs` property-tests
//! the persistent pool against the retained scoped implementation
//! (`exec::run_scoped`) across 1/2/8 threads and repeated reuse.
//!
//! ## Soundness of the lifetime erasure
//!
//! `exec::Task<'s>` borrows caller state; long-lived workers require
//! `'static`.  [`Pool::run`] transmutes the task list to `'static` but
//! blocks on a completion barrier (`remaining == 0`) before returning,
//! so every borrow a task captures strictly outlives its execution.
//! Workers hold only the `Arc<Job>`, never the caller's frame.
//!
//! ## Race detector
//!
//! Debug builds back the disjoint-writes contract with an executable
//! check: task builders declare each task's output byte ranges
//! ([`declare_task_writes`]), and every runner entry point
//! ([`Pool::run`], `exec::run_scoped`, the inline `Scalar` path)
//! drains the declarations and panics on any cross-task overlap
//! ([`verify_declared_disjoint`]) before a single task executes.
//! Release builds compile both hooks to nothing.  DESIGN.md §7
//! documents the semantics alongside the static `spark check` rules.
//!
//! ## Panics
//!
//! A panicking task is caught on the worker, recorded, and re-thrown
//! from the submitting thread after the barrier — the same observable
//! behaviour as the scoped pool (which re-threw at scope exit).  The
//! pool itself survives and stays usable.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use super::Task;

/// A task whose borrowed captures have been lifetime-erased so it can
/// cross into the long-lived workers.  Sound only under [`Pool::run`]'s
/// completion barrier (see the module docs).
type ErasedTask = Box<dyn FnOnce() + Send + 'static>;

/// Lock that shrugs off poisoning: the pool's mutexes only guard
/// queues/flags and are never held across user code (tasks run outside
/// the locks, wrapped in `catch_unwind`), so a poisoned guard's data is
/// still consistent.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One `Pool::run` call in flight: per-participant task queues plus the
/// completion barrier the submitting thread blocks on.
struct Job {
    /// One deque per participant (slot 0 = the submitting thread).
    queues: Vec<Mutex<VecDeque<ErasedTask>>>,
    /// Tasks not yet finished; the decrement to zero signals `done`.
    remaining: AtomicUsize,
    /// Completion flag guarded for the condvar handshake.
    done: Mutex<bool>,
    /// Signalled once `remaining` hits zero.
    signal: Condvar,
    /// First panic payload captured from a task; re-thrown by `run`.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl Job {
    /// Next task for `slot`: own queue front-first, then steal from the
    /// back of the other queues.  `None` means the job is drained (for
    /// this participant).
    fn pop(&self, slot: usize) -> Option<ErasedTask> {
        if let Some(t) = lock(&self.queues[slot]).pop_front() {
            return Some(t);
        }
        let k = self.queues.len();
        for off in 1..k {
            let victim = (slot + off) % k;
            if let Some(t) = lock(&self.queues[victim]).pop_back() {
                return Some(t);
            }
        }
        None
    }

    /// Run tasks from `slot`'s perspective until every queue is dry,
    /// catching panics and maintaining the completion barrier.
    fn work(&self, slot: usize) {
        while let Some(task) = self.pop(slot) {
            if let Err(payload) = catch_unwind(AssertUnwindSafe(task)) {
                let mut first = lock(&self.panic);
                if first.is_none() {
                    *first = Some(payload);
                }
            }
            if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                *lock(&self.done) = true;
                self.signal.notify_all();
            }
        }
    }
}

/// One dispatch to a worker: the job and the queue slot it owns.
struct Assignment {
    job: Arc<Job>,
    slot: usize,
}

/// Handle to one long-lived worker: the channel its assignments arrive
/// on.  Workers never terminate; an abandoned `Sender` (process
/// teardown) ends the worker's `recv` loop.
struct Worker {
    tx: Sender<Assignment>,
}

fn spawn_worker(index: usize) -> Worker {
    let (tx, rx) = channel::<Assignment>();
    std::thread::Builder::new()
        .name(format!("spark-exec-{index}"))
        .spawn(move || {
            while let Ok(Assignment { job, slot }) = rx.recv() {
                job.work(slot);
            }
        })
        .expect("spawning exec pool worker");
    Worker { tx }
}

/// The persistent, lazily-grown worker pool.  One process-wide instance
/// lives behind [`global`]; separate instances exist only in tests.
pub struct Pool {
    workers: Mutex<Vec<Worker>>,
}

impl Pool {
    /// An empty pool; workers are spawned lazily by [`Pool::run`], up
    /// to the largest `threads - 1` ever requested.
    pub const fn new() -> Self {
        Pool { workers: Mutex::new(Vec::new()) }
    }

    /// Number of workers currently alive (diagnostics/tests).
    pub fn worker_count(&self) -> usize {
        lock(&self.workers).len()
    }

    /// Execute `tasks` over up to `threads` participants (the calling
    /// thread included) and return once **all** of them have finished.
    /// Tasks must touch disjoint data (the [`Task`] contract); in
    /// debug builds, write sets declared via [`declare_task_writes`]
    /// are verified pairwise-disjoint before anything runs.  The
    /// first task panic, if any, is re-thrown here after the barrier.
    ///
    /// Re-entrant calls (a task submitting its own job) are safe: the
    /// inner submitter participates as slot 0 and can drain the entire
    /// inner job itself via stealing, so progress never depends on a
    /// worker being free.
    pub fn run<'s>(&self, threads: usize, tasks: Vec<Task<'s>>) {
        verify_declared_disjoint();
        let count = tasks.len();
        let t = threads.min(count).max(1);
        if t == 1 {
            for task in tasks {
                task();
            }
            return;
        }
        // SAFETY: tasks may borrow caller state ('s); they are erased
        // to 'static only to cross into the long-lived workers.  The
        // barrier below keeps this frame (and thus every borrow) alive
        // until `remaining` hits zero, i.e. until no task can execute
        // anymore.  Workers retain only the Arc<Job> afterwards.
        let tasks = unsafe {
            std::mem::transmute::<Vec<Task<'s>>, Vec<ErasedTask>>(tasks)
        };
        let mut queues: Vec<VecDeque<ErasedTask>> =
            (0..t).map(|_| VecDeque::new()).collect();
        for (i, task) in tasks.into_iter().enumerate() {
            queues[i % t].push_back(task);
        }
        let job = Arc::new(Job {
            queues: queues.into_iter().map(Mutex::new).collect(),
            remaining: AtomicUsize::new(count),
            done: Mutex::new(false),
            signal: Condvar::new(),
            panic: Mutex::new(None),
        });
        {
            let mut workers = lock(&self.workers);
            while workers.len() < t - 1 {
                workers.push(spawn_worker(workers.len() + 1));
            }
            for (i, w) in workers[..t - 1].iter().enumerate() {
                // a send only fails if the worker died (process
                // teardown); slot 0's stealing drains its queue anyway
                let _ = w.tx.send(Assignment {
                    job: Arc::clone(&job),
                    slot: i + 1,
                });
            }
        }
        // the submitting thread is participant 0
        job.work(0);
        let mut done = lock(&job.done);
        while !*done {
            done = job.signal.wait(done).unwrap_or_else(|e| e.into_inner());
        }
        drop(done);
        if let Some(payload) = lock(&job.panic).take() {
            resume_unwind(payload);
        }
    }
}

impl Default for Pool {
    fn default() -> Self {
        Pool::new()
    }
}

/// The process-wide pool used by `exec::run_pool` — shared by every
/// backend instance; its workers survive across calls.
pub fn global() -> &'static Pool {
    static POOL: Pool = Pool::new();
    &POOL
}

// ---------------------------------------------------------------------
// Write-set race detector (debug builds only)
//
// The pool's lifetime erasure and its determinism story both rest on
// one prose contract: tasks submitted to a single `run` call write
// disjoint data.  The detector turns that contract into an executable
// check.  Task builders call [`declare_task_writes`] once per task (in
// push order, on the building thread) with the byte ranges the task
// will write; every runner entry point calls
// [`verify_declared_disjoint`], which drains the pending declarations
// and panics if any two tasks' ranges overlap.  Release builds compile
// both calls to nothing.
// ---------------------------------------------------------------------

/// Byte-address range `[start, end)` that one task will write.
pub type WriteRange = (usize, usize);

/// The byte range covered by `slice`, for [`declare_task_writes`].
pub fn span<T>(slice: &[T]) -> WriteRange {
    let start = slice.as_ptr() as usize;
    (start, start + std::mem::size_of_val(slice))
}

#[cfg(debug_assertions)]
mod racecheck {
    use std::cell::RefCell;

    use super::WriteRange;

    thread_local! {
        /// Write sets declared since the last verify: one entry per
        /// task, in push order, on the thread that built the tasks.
        static DECLARED: RefCell<Vec<Vec<WriteRange>>> =
            RefCell::new(Vec::new());
    }

    pub fn declare(ranges: &[WriteRange]) {
        let set: Vec<WriteRange> = ranges
            .iter()
            .copied()
            .filter(|&(s, e)| e > s)
            .collect();
        DECLARED.with(|d| d.borrow_mut().push(set));
    }

    pub fn verify() {
        // Drain first: a panic below must still leave the thread-local
        // state clean for subsequent runs (tests rely on this).
        let sets =
            DECLARED.with(|d| std::mem::take(&mut *d.borrow_mut()));
        if sets.len() < 2 {
            return;
        }
        let mut flat: Vec<(usize, usize, usize)> = Vec::new();
        for (task, set) in sets.iter().enumerate() {
            for &(s, e) in set {
                flat.push((s, e, task));
            }
        }
        if flat.len() < 2 {
            return;
        }
        flat.sort_unstable();
        // Sweep in start order, tracking the interval with the largest
        // end seen so far and which task owns it.  A range starting
        // before that end overlaps it; same-task self-overlap is not a
        // race and is ignored.
        let mut max = flat[0];
        for &(s, e, task) in &flat[1..] {
            if s < max.1 && task != max.2 {
                panic!(
                    "exec pool race detector: tasks #{} and #{} declared \
                     overlapping write ranges [{:#x}, {:#x}) vs \
                     [{:#x}, {:#x}) — run_pool tasks must write \
                     disjoint data",
                    max.2, task, max.0, max.1, s, e
                );
            }
            if e > max.1 {
                max = (s, e, task);
            }
        }
    }
}

#[cfg(not(debug_assertions))]
mod racecheck {
    use super::WriteRange;

    #[inline(always)]
    pub fn declare(_ranges: &[WriteRange]) {}

    #[inline(always)]
    pub fn verify() {}
}

/// Declare the write set of the task about to be pushed.  Call once
/// per task, from the thread building the task list, with the byte
/// ranges ([`span`]) the task will write; empty ranges are ignored.
/// Debug builds record the set for [`verify_declared_disjoint`];
/// release builds compile this to nothing.
pub fn declare_task_writes(ranges: &[WriteRange]) {
    racecheck::declare(ranges);
}

/// Drain the write sets declared on this thread since the last call
/// and panic if any two tasks' ranges overlap.  Invoked at the entry
/// of every task runner ([`Pool::run`], `exec::run_scoped`, and the
/// inline `Scalar` path), so a declared racy task list never executes
/// in a debug build.  A no-op in release builds, and when fewer than
/// two tasks declared anything.
pub fn verify_declared_disjoint() {
    racecheck::verify();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill_tasks(data: &mut [f32], chunk: usize) -> Vec<Task<'_>> {
        data.chunks_mut(chunk)
            .enumerate()
            .map(|(ci, c)| {
                Box::new(move || {
                    for (j, x) in c.iter_mut().enumerate() {
                        *x = (ci * 100 + j) as f32;
                    }
                }) as Task<'_>
            })
            .collect()
    }

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = Pool::new();
        for threads in [1usize, 2, 4, 9] {
            let mut hits = vec![0u8; 23];
            {
                let tasks: Vec<Task<'_>> = hits
                    .iter_mut()
                    .map(|h| Box::new(move || *h += 1) as Task<'_>)
                    .collect();
                pool.run(threads, tasks);
            }
            assert!(hits.iter().all(|&h| h == 1), "threads={threads}");
        }
    }

    #[test]
    fn reuse_is_deterministic() {
        let pool = Pool::new();
        let mut want = vec![0.0f32; 6 * 7];
        {
            let tasks = fill_tasks(&mut want, 7);
            pool.run(1, tasks);
        }
        for round in 0..10 {
            let mut got = vec![0.0f32; 6 * 7];
            {
                let tasks = fill_tasks(&mut got, 7);
                pool.run(4, tasks);
            }
            assert_eq!(got, want, "round={round}");
        }
    }

    #[test]
    fn workers_grow_lazily_and_are_reused() {
        let pool = Pool::new();
        assert_eq!(pool.worker_count(), 0);
        pool.run(3, (0..8).map(|_| Box::new(|| ()) as Task<'_>).collect());
        assert_eq!(pool.worker_count(), 2);
        pool.run(2, (0..8).map(|_| Box::new(|| ()) as Task<'_>).collect());
        assert_eq!(pool.worker_count(), 2, "smaller runs spawn nothing");
        pool.run(5, (0..8).map(|_| Box::new(|| ()) as Task<'_>).collect());
        assert_eq!(pool.worker_count(), 4, "grows to the new high-water");
    }

    #[test]
    fn single_task_runs_inline() {
        let pool = Pool::new();
        let mut hit = false;
        {
            let tasks: Vec<Task<'_>> = vec![Box::new(|| hit = true)];
            pool.run(8, tasks);
        }
        assert!(hit);
        assert_eq!(pool.worker_count(), 0, "one task never needs workers");
    }

    #[test]
    fn panics_propagate_and_pool_survives() {
        let pool = Pool::new();
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Task<'static>> = (0..8)
                .map(|i| {
                    Box::new(move || {
                        if i == 5 {
                            panic!("task 5 exploded");
                        }
                    }) as Task<'static>
                })
                .collect();
            pool.run(4, tasks);
        }));
        assert!(caught.is_err(), "the submitter must observe the panic");
        // the pool keeps working after a task panicked
        let mut hits = vec![0u8; 16];
        {
            let tasks: Vec<Task<'_>> = hits
                .iter_mut()
                .map(|h| Box::new(move || *h += 1) as Task<'_>)
                .collect();
            pool.run(4, tasks);
        }
        assert!(hits.iter().all(|&h| h == 1));
    }

    #[test]
    fn global_pool_is_shared() {
        let p1 = global() as *const Pool;
        let p2 = global() as *const Pool;
        assert_eq!(p1, p2);
    }
}
