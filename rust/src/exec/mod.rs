//! Execution backends for the host compute path.
//!
//! The pure-Rust attention oracle and algorithm witness (`attention/`)
//! run their linear algebra through a [`Backend`]:
//!
//! * [`Scalar`] — the original single-threaded reference loops from
//!   `tensor/`.  Ground truth; never changes behaviour.
//! * [`Blocked`] — cache-blocked (MC×KC×NR) microkernels fanned out over
//!   a `std::thread::scope` worker pool.  Deterministic by construction:
//!   every output element accumulates its k-terms in the same ascending
//!   order as `Scalar`, and the tile partition never depends on the
//!   thread count, so results are bitwise-identical across
//!   `exec.threads ∈ {1, 2, 8, …}` (and match `Scalar` exactly).
//!
//! The backend seam is what future scaling PRs (sharding, device
//! backends, batched serving) plug into: anything that can run three
//! batched matmul flavours and a task pool can host the attention path.

use anyhow::{bail, Result};

use crate::tensor::{self, dims3, Tensor};

/// Row-block assigned to one worker task.
pub const MC: usize = 64;
/// k-panel kept hot in cache between row sweeps.
pub const KC: usize = 256;
/// Register-tile width (accumulator lanes per row).
pub const NR: usize = 8;
/// Register-tile height (rows sharing one B panel load).
pub const MR: usize = 4;

/// A unit of work for the backend's pool.  Tasks passed to one
/// [`Backend::run_tasks`] call must touch disjoint data.
pub type Task<'s> = Box<dyn FnOnce() + Send + 's>;

/// An execution backend for host-side batched linear algebra.
pub trait Backend: Sync {
    /// Label used in bench reports (e.g. `scalar`, `blocked_t8`).
    fn name(&self) -> String;

    /// Worker-pool width (1 for serial backends).
    fn threads(&self) -> usize;

    /// (b, m, k) × (b, k, n) → (b, m, n).
    fn batch_matmul(&self, a: &Tensor, b: &Tensor) -> Tensor;

    /// (b, m, k) × (b, n, k) → (b, m, n)  (B transposed).
    fn batch_matmul_nt(&self, a: &Tensor, b: &Tensor) -> Tensor;

    /// (b, k, m) × (b, k, n) → (b, m, n)  (A transposed).
    fn batch_matmul_tn(&self, a: &Tensor, b: &Tensor) -> Tensor;

    /// Execute independent tasks, possibly in parallel.  Completion of
    /// every task is guaranteed on return; ordering is not.
    fn run_tasks<'s>(&self, tasks: Vec<Task<'s>>);
}

/// Carve `count` elements off the front of `*rest`, shrinking it in
/// place — how output buffers are handed out as disjoint task tiles.
pub fn carve<'a>(rest: &mut &'a mut [f32], count: usize) -> &'a mut [f32] {
    let tmp = std::mem::take(rest);
    let (head, tail) = tmp.split_at_mut(count);
    *rest = tail;
    head
}

/// Split `data` into contiguous chunks of `rows_per_task` rows of length
/// `row_len` and run `f(chunk_index, chunk)` over the backend's pool.
/// Chunk `i` starts at global row `i * rows_per_task`.
pub fn par_row_chunks<F>(be: &dyn Backend, data: &mut [f32], row_len: usize,
                         rows_per_task: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if data.is_empty() || row_len == 0 {
        return;
    }
    debug_assert_eq!(data.len() % row_len, 0);
    let chunk = rows_per_task.max(1) * row_len;
    let fr = &f;
    let mut tasks: Vec<Task<'_>> = Vec::new();
    for (i, c) in data.chunks_mut(chunk).enumerate() {
        tasks.push(Box::new(move || fr(i, c)));
    }
    be.run_tasks(tasks);
}

// ---------------------------------------------------------------------------
// Scalar — the single-threaded reference
// ---------------------------------------------------------------------------

/// The original single-threaded loops from `tensor/`; the oracle backend.
#[derive(Debug, Clone, Copy, Default)]
pub struct Scalar;

impl Backend for Scalar {
    fn name(&self) -> String {
        "scalar".into()
    }

    fn threads(&self) -> usize {
        1
    }

    fn batch_matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        tensor::batch_matmul(a, b)
    }

    fn batch_matmul_nt(&self, a: &Tensor, b: &Tensor) -> Tensor {
        tensor::batch_matmul_nt(a, b)
    }

    fn batch_matmul_tn(&self, a: &Tensor, b: &Tensor) -> Tensor {
        tensor::batch_matmul_tn(a, b)
    }

    fn run_tasks<'s>(&self, tasks: Vec<Task<'s>>) {
        for task in tasks {
            task();
        }
    }
}

// ---------------------------------------------------------------------------
// Blocked — cache-blocked microkernels + scoped worker pool
// ---------------------------------------------------------------------------

/// Parallel cache-blocked backend.
#[derive(Debug, Clone, Copy)]
pub struct Blocked {
    threads: usize,
    mc: usize,
    kc: usize,
}

impl Blocked {
    /// `threads == 0` resolves to the machine's available parallelism.
    pub fn new(threads: usize) -> Self {
        Blocked::with_blocks(threads, MC, KC)
    }

    /// Custom block sizes (property tests sweep these).
    pub fn with_blocks(threads: usize, mc: usize, kc: usize) -> Self {
        let threads = if threads == 0 {
            available_threads()
        } else {
            threads
        };
        Blocked { threads, mc: mc.max(1), kc: kc.max(1) }
    }
}

/// Detected worker count (≥ 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl Backend for Blocked {
    fn name(&self) -> String {
        format!("blocked_t{}", self.threads)
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn batch_matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (ba, m, ka) = dims3(a);
        let (bb, kb, n) = dims3(b);
        assert_eq!(ba, bb, "batch mismatch");
        assert_eq!(ka, kb, "inner dim mismatch");
        let mut out = vec![0.0f32; ba * m * n];
        let (ad, bd) = (a.data(), b.data());
        let (mc, kc) = (self.mc, self.kc);
        {
            let mut tasks: Vec<Task<'_>> = Vec::new();
            let mut rest: &mut [f32] = &mut out;
            for bi in 0..ba {
                let ap = &ad[bi * m * ka..(bi + 1) * m * ka];
                let bp = &bd[bi * ka * n..(bi + 1) * ka * n];
                for i0 in (0..m).step_by(mc) {
                    let rows = mc.min(m - i0);
                    let tile = carve(&mut rest, rows * n);
                    tasks.push(Box::new(move || {
                        nn_tile(ap, bp, tile, i0, rows, ka, n, kc);
                    }));
                }
            }
            self.run_tasks(tasks);
        }
        Tensor::new(vec![ba, m, n], out)
    }

    fn batch_matmul_nt(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (ba, m, ka) = dims3(a);
        let (bb, n, kb) = dims3(b);
        assert_eq!(ba, bb, "batch mismatch");
        assert_eq!(ka, kb, "inner dim mismatch");
        let mut out = vec![0.0f32; ba * m * n];
        let (ad, bd) = (a.data(), b.data());
        let (mc, kc) = (self.mc, self.kc);
        {
            let mut tasks: Vec<Task<'_>> = Vec::new();
            let mut rest: &mut [f32] = &mut out;
            for bi in 0..ba {
                let ap = &ad[bi * m * ka..(bi + 1) * m * ka];
                let bp = &bd[bi * n * ka..(bi + 1) * n * ka];
                for i0 in (0..m).step_by(mc) {
                    let rows = mc.min(m - i0);
                    let tile = carve(&mut rest, rows * n);
                    tasks.push(Box::new(move || {
                        nt_tile(ap, bp, tile, i0, rows, ka, n, kc);
                    }));
                }
            }
            self.run_tasks(tasks);
        }
        Tensor::new(vec![ba, m, n], out)
    }

    fn batch_matmul_tn(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (ba, ka, m) = dims3(a);
        let (bb, kb, n) = dims3(b);
        assert_eq!(ba, bb, "batch mismatch");
        assert_eq!(ka, kb, "inner dim mismatch");
        let mut out = vec![0.0f32; ba * m * n];
        let (ad, bd) = (a.data(), b.data());
        let mc = self.mc;
        {
            let mut tasks: Vec<Task<'_>> = Vec::new();
            let mut rest: &mut [f32] = &mut out;
            for bi in 0..ba {
                let ap = &ad[bi * ka * m..(bi + 1) * ka * m];
                let bp = &bd[bi * ka * n..(bi + 1) * ka * n];
                for i0 in (0..m).step_by(mc) {
                    let rows = mc.min(m - i0);
                    let tile = carve(&mut rest, rows * n);
                    tasks.push(Box::new(move || {
                        tn_tile(ap, bp, tile, i0, rows, ka, m, n);
                    }));
                }
            }
            self.run_tasks(tasks);
        }
        Tensor::new(vec![ba, m, n], out)
    }

    fn run_tasks<'s>(&self, tasks: Vec<Task<'s>>) {
        let t = self.threads.min(tasks.len()).max(1);
        if t == 1 {
            for task in tasks {
                task();
            }
            return;
        }
        // Static round-robin keeps the partition independent of timing;
        // tiles are uniform so this balances well without a work queue.
        let mut buckets: Vec<Vec<Task<'s>>> =
            (0..t).map(|_| Vec::new()).collect();
        for (i, task) in tasks.into_iter().enumerate() {
            buckets[i % t].push(task);
        }
        let mine = buckets.remove(0);
        std::thread::scope(|scope| {
            for bucket in buckets {
                scope.spawn(move || {
                    for task in bucket {
                        task();
                    }
                });
            }
            for task in mine {
                task();
            }
        });
    }
}

/// NN tile: rows `i0..i0+rows` of A·B, k-blocked, axpy inner loop.
/// Accumulation order per output element matches `tensor::batch_matmul`
/// (k ascending, zero-skip), so results are bitwise-equal to Scalar.
fn nn_tile(ap: &[f32], bp: &[f32], tile: &mut [f32], i0: usize, rows: usize,
           ka: usize, n: usize, kc: usize) {
    for kk in (0..ka).step_by(kc) {
        let kend = (kk + kc).min(ka);
        for r in 0..rows {
            let arow = &ap[(i0 + r) * ka + kk..(i0 + r) * ka + kend];
            let orow = &mut tile[r * n..(r + 1) * n];
            for (k, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &bp[(kk + k) * n..(kk + k + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
}

/// NT tile: rows `i0..i0+rows` of A·Bᵀ with an MR×NR register tile —
/// `NR` independent accumulator lanes per row so the dot products
/// vectorise, `MR` rows sharing each B panel load.  Per-element k order
/// is ascending, matching `tensor::batch_matmul_nt` bitwise.
fn nt_tile(ap: &[f32], bp: &[f32], tile: &mut [f32], i0: usize, rows: usize,
           ka: usize, n: usize, kc: usize) {
    let mut r0 = 0;
    while r0 < rows {
        let mr = MR.min(rows - r0);
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            let mut acc = [[0.0f32; NR]; MR];
            for kk in (0..ka).step_by(kc) {
                let kend = (kk + kc).min(ka);
                for k in kk..kend {
                    let mut bvals = [0.0f32; NR];
                    for (jj, bv) in bvals[..nr].iter_mut().enumerate() {
                        *bv = bp[(j0 + jj) * ka + k];
                    }
                    for (ri, accrow) in acc[..mr].iter_mut().enumerate() {
                        let av = ap[(i0 + r0 + ri) * ka + k];
                        for (jj, acc1) in accrow[..nr].iter_mut()
                            .enumerate()
                        {
                            *acc1 += av * bvals[jj];
                        }
                    }
                }
            }
            for (ri, accrow) in acc[..mr].iter().enumerate() {
                let orow = &mut tile[(r0 + ri) * n + j0
                                     ..(r0 + ri) * n + j0 + nr];
                orow.copy_from_slice(&accrow[..nr]);
            }
            j0 += nr;
        }
        r0 += mr;
    }
}

/// TN tile: output rows `i0..i0+rows` (columns of A), k-ascending axpy —
/// bitwise-equal to `tensor::batch_matmul_tn`.
fn tn_tile(ap: &[f32], bp: &[f32], tile: &mut [f32], i0: usize, rows: usize,
           ka: usize, m: usize, n: usize) {
    for k in 0..ka {
        let arow = &ap[k * m..(k + 1) * m];
        let brow = &bp[k * n..(k + 1) * n];
        for r in 0..rows {
            let av = arow[i0 + r];
            if av == 0.0 {
                continue;
            }
            let orow = &mut tile[r * n..(r + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Configuration plumbing
// ---------------------------------------------------------------------------

/// Which backend family to instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Scalar,
    Blocked,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "scalar" => Ok(BackendKind::Scalar),
            "blocked" => Ok(BackendKind::Blocked),
            other => bail!("unknown exec backend {other:?} \
                            (expected \"scalar\" or \"blocked\")"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Scalar => "scalar",
            BackendKind::Blocked => "blocked",
        }
    }
}

/// Backend selection carried through config / CLI / harness options.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecOptions {
    pub kind: BackendKind,
    /// Worker threads; 0 = auto-detect.  Ignored by `Scalar`.
    pub threads: usize,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions { kind: BackendKind::Blocked, threads: 0 }
    }
}

impl ExecOptions {
    pub fn scalar() -> Self {
        ExecOptions { kind: BackendKind::Scalar, threads: 1 }
    }

    pub fn blocked(threads: usize) -> Self {
        ExecOptions { kind: BackendKind::Blocked, threads }
    }

    /// Instantiate the configured backend.
    pub fn build(self) -> Box<dyn Backend> {
        match self.kind {
            BackendKind::Scalar => Box::new(Scalar),
            BackendKind::Blocked => Box::new(Blocked::new(self.threads)),
        }
    }
}

/// Cheap startup self-check: the backend's three matmul flavours must
/// reproduce the Scalar reference on a non-trivial case.  Run by
/// `spark train` before committing to a long run.
pub fn self_check(be: &dyn Backend) -> Result<()> {
    let mut rng = crate::tensor::Rng::new(0xC0FFEE);
    let a = Tensor::randn(vec![3, 37, 19], &mut rng);
    let b = Tensor::randn(vec![3, 19, 23], &mut rng);
    let bt = Tensor::randn(vec![3, 23, 19], &mut rng);
    let at = Tensor::randn(vec![3, 19, 37], &mut rng);
    let checks = [
        ("nn", be.batch_matmul(&a, &b), Scalar.batch_matmul(&a, &b)),
        ("nt", be.batch_matmul_nt(&a, &bt),
         Scalar.batch_matmul_nt(&a, &bt)),
        ("tn", be.batch_matmul_tn(&at, &b),
         Scalar.batch_matmul_tn(&at, &b)),
    ];
    for (name, got, want) in &checks {
        let err = got.max_abs_diff(want);
        if err > 1e-5 {
            bail!("backend {} failed the {name} self-check (max err {err})",
                  be.name());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        Tensor::randn(shape.to_vec(), &mut r)
    }

    #[test]
    fn blocked_nn_matches_scalar_bitwise() {
        for (ba, m, k, n, seed) in [(1, 1, 1, 1, 1u64), (2, 7, 13, 5, 2),
                                    (3, 64, 96, 33, 3), (1, 130, 17, 9, 4)] {
            let a = randn(&[ba, m, k], seed);
            let b = randn(&[ba, k, n], seed + 100);
            let want = Scalar.batch_matmul(&a, &b);
            for be in [Blocked::with_blocks(2, 3, 4),
                       Blocked::with_blocks(4, 64, 256)] {
                let got = be.batch_matmul(&a, &b);
                assert_eq!(got.data(), want.data(),
                           "nn ({ba},{m},{k},{n}) via {}", be.name());
            }
        }
    }

    #[test]
    fn blocked_nt_matches_scalar_bitwise() {
        for (ba, m, k, n, seed) in [(1, 1, 3, 1, 1u64), (2, 9, 13, 7, 2),
                                    (2, 65, 40, 31, 3), (1, 4, 1, 21, 4)] {
            let a = randn(&[ba, m, k], seed);
            let b = randn(&[ba, n, k], seed + 100);
            let want = Scalar.batch_matmul_nt(&a, &b);
            for be in [Blocked::with_blocks(2, 5, 3),
                       Blocked::with_blocks(8, 64, 256)] {
                let got = be.batch_matmul_nt(&a, &b);
                assert_eq!(got.data(), want.data(),
                           "nt ({ba},{m},{k},{n}) via {}", be.name());
            }
        }
    }

    #[test]
    fn blocked_tn_matches_scalar_bitwise() {
        for (ba, m, k, n, seed) in [(1, 2, 3, 4, 1u64), (2, 11, 6, 13, 2),
                                    (2, 70, 24, 18, 3)] {
            let a = randn(&[ba, k, m], seed);
            let b = randn(&[ba, k, n], seed + 100);
            let want = Scalar.batch_matmul_tn(&a, &b);
            for be in [Blocked::with_blocks(3, 7, 2),
                       Blocked::with_blocks(2, 64, 256)] {
                let got = be.batch_matmul_tn(&a, &b);
                assert_eq!(got.data(), want.data(),
                           "tn ({ba},{m},{k},{n}) via {}", be.name());
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let a = randn(&[2, 50, 30], 7);
        let b = randn(&[2, 30, 41], 8);
        let base = Blocked::with_blocks(1, 16, 8).batch_matmul(&a, &b);
        for t in [2, 3, 8, 32] {
            let got = Blocked::with_blocks(t, 16, 8).batch_matmul(&a, &b);
            assert_eq!(got.data(), base.data(), "threads={t}");
        }
    }

    #[test]
    fn run_tasks_executes_everything() {
        let mut hits = vec![0u8; 23];
        {
            let be = Blocked::new(4);
            let mut tasks: Vec<Task<'_>> = Vec::new();
            for h in hits.iter_mut() {
                tasks.push(Box::new(move || {
                    *h += 1;
                }));
            }
            be.run_tasks(tasks);
        }
        assert!(hits.iter().all(|&h| h == 1));
    }

    #[test]
    fn par_row_chunks_covers_all_rows() {
        let mut data = vec![0.0f32; 7 * 5];
        par_row_chunks(&Blocked::new(3), &mut data, 5, 2, |ci, chunk| {
            for (r, row) in chunk.chunks_exact_mut(5).enumerate() {
                for x in row.iter_mut() {
                    *x = (ci * 2 + r) as f32;
                }
            }
        });
        for (r, row) in data.chunks_exact(5).enumerate() {
            assert!(row.iter().all(|&x| x == r as f32), "row {r}");
        }
    }

    #[test]
    fn empty_shapes_are_fine() {
        let a = Tensor::zeros(vec![0, 4, 3]);
        let b = Tensor::zeros(vec![0, 3, 2]);
        assert_eq!(Blocked::new(2).batch_matmul(&a, &b).shape(),
                   &[0, 4, 2]);
        let a = Tensor::zeros(vec![2, 0, 3]);
        let b = Tensor::zeros(vec![2, 3, 4]);
        assert_eq!(Blocked::new(2).batch_matmul(&a, &b).len(), 0);
    }

    #[test]
    fn options_build_and_parse() {
        assert_eq!(BackendKind::parse("scalar").unwrap(),
                   BackendKind::Scalar);
        assert_eq!(BackendKind::parse("blocked").unwrap(),
                   BackendKind::Blocked);
        assert!(BackendKind::parse("gpu").is_err());
        let be = ExecOptions::blocked(2).build();
        assert_eq!(be.threads(), 2);
        assert_eq!(be.name(), "blocked_t2");
        assert_eq!(ExecOptions::scalar().build().name(), "scalar");
        assert!(ExecOptions::default().build().threads() >= 1);
    }

    #[test]
    fn self_check_passes_for_both() {
        self_check(&Scalar).unwrap();
        self_check(&Blocked::new(0)).unwrap();
    }
}
