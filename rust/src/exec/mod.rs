//! Execution backends for the host compute path.
//!
//! The pure-Rust attention oracle and algorithm witness (`attention/`)
//! run their linear algebra through a [`Backend`]:
//!
//! * [`Scalar`] — the original single-threaded reference loops from
//!   `tensor/`.  Ground truth; never changes behaviour.
//! * [`Blocked`] — cache-blocked (MC×KC×NR) microkernels fanned out over
//!   the persistent process-wide worker pool ([`pool`]).  Deterministic
//!   by construction:
//!   every output element accumulates its k-terms in the same ascending
//!   order as `Scalar`, and the tile partition never depends on the
//!   thread count, so results are bitwise-identical across
//!   `exec.threads ∈ {1, 2, 8, …}` (and match `Scalar` exactly).
//! * [`Simd`] — the `Blocked` tiling vectorized with AVX2/FMA
//!   (runtime-detected, portable fallback elsewhere), with a numeric
//!   mode switch: [`Precision::F32`] keeps the bitwise contract above,
//!   [`Precision::Mixed`] emulates the paper's TCU numerics (bf16
//!   operands quantized at tile-pack time, f32 accumulators).
//!
//! The backend seam is what future scaling PRs (sharding, device
//! backends, batched serving) plug into: anything that can run three
//! batched matmul flavours and a task pool can host the attention path.
//! `Simd`'s [`Precision`] is likewise the seam future quantized
//! backends (int8, fp8) thread their numerics through.
//!
//! Two supporting modules round out the raw-speed story: [`pool`] keeps
//! the worker threads alive across `run_tasks` calls (work-stealing,
//! lazily spawned), and [`tune`] sweeps (MC, KC) block shapes per
//! problem class and feeds the winners back to `Blocked::new` /
//! `Simd::new` through an installable tuning table.  Backends built
//! with `with_blocks` are pinned and ignore the table.

pub mod pool;
pub mod simd;
pub mod tune;

pub use simd::{Precision, Simd};

use anyhow::{bail, Result};

use crate::tensor::{self, bf16, dims3, Tensor};

/// Row-block assigned to one worker task.
pub const MC: usize = 64;
/// k-panel kept hot in cache between row sweeps.
pub const KC: usize = 256;
/// Register-tile width (accumulator lanes per row).
pub const NR: usize = 8;
/// Register-tile height (rows sharing one B panel load).
pub const MR: usize = 4;

/// A unit of work for the backend's pool.  Tasks passed to one
/// [`Backend::run_tasks`] call must touch disjoint data.
pub type Task<'s> = Box<dyn FnOnce() + Send + 's>;

/// An execution backend for host-side batched linear algebra.
pub trait Backend: Sync {
    /// Label used in bench reports (e.g. `scalar`, `blocked_t8`).
    fn name(&self) -> String;

    /// Worker-pool width (1 for serial backends).
    fn threads(&self) -> usize;

    /// Numeric mode this backend computes in.  Everything except the
    /// mixed-precision `Simd` runs full f32; consumers (the streaming
    /// attention paths) use this to decide whether to quantize their
    /// tile operands the way the backend's own matmuls do.
    fn precision(&self) -> Precision {
        Precision::F32
    }

    /// (b, m, k) × (b, k, n) → (b, m, n).
    fn batch_matmul(&self, a: &Tensor, b: &Tensor) -> Tensor;

    /// (b, m, k) × (b, n, k) → (b, m, n)  (B transposed).
    fn batch_matmul_nt(&self, a: &Tensor, b: &Tensor) -> Tensor;

    /// (b, k, m) × (b, k, n) → (b, m, n)  (A transposed).
    fn batch_matmul_tn(&self, a: &Tensor, b: &Tensor) -> Tensor;

    /// Execute independent tasks, possibly in parallel.  Completion of
    /// every task is guaranteed on return; ordering is not.
    fn run_tasks<'s>(&self, tasks: Vec<Task<'s>>);
}

/// Carve `count` elements off the front of `*rest`, shrinking it in
/// place — how output buffers are handed out as disjoint task tiles.
pub fn carve<'a>(rest: &mut &'a mut [f32], count: usize) -> &'a mut [f32] {
    let tmp = std::mem::take(rest);
    let (head, tail) = tmp.split_at_mut(count);
    *rest = tail;
    head
}

/// Split `data` into contiguous chunks of `rows_per_task` rows of length
/// `row_len` and run `f(chunk_index, chunk)` over the backend's pool.
/// Chunk `i` starts at global row `i * rows_per_task`.
pub fn par_row_chunks<F>(be: &dyn Backend, data: &mut [f32], row_len: usize,
                         rows_per_task: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if data.is_empty() || row_len == 0 {
        return;
    }
    debug_assert_eq!(data.len() % row_len, 0);
    let chunk = rows_per_task.max(1) * row_len;
    let fr = &f;
    let mut tasks: Vec<Task<'_>> = Vec::new();
    for (i, c) in data.chunks_mut(chunk).enumerate() {
        pool::declare_task_writes(&[pool::span(&*c)]);
        tasks.push(Box::new(move || fr(i, c)));
    }
    be.run_tasks(tasks);
}

// ---------------------------------------------------------------------------
// Scalar — the single-threaded reference
// ---------------------------------------------------------------------------

/// The original single-threaded loops from `tensor/`; the oracle backend.
#[derive(Debug, Clone, Copy, Default)]
pub struct Scalar;

impl Backend for Scalar {
    fn name(&self) -> String {
        "scalar".into()
    }

    fn threads(&self) -> usize {
        1
    }

    fn batch_matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        tensor::batch_matmul(a, b)
    }

    fn batch_matmul_nt(&self, a: &Tensor, b: &Tensor) -> Tensor {
        tensor::batch_matmul_nt(a, b)
    }

    fn batch_matmul_tn(&self, a: &Tensor, b: &Tensor) -> Tensor {
        tensor::batch_matmul_tn(a, b)
    }

    fn run_tasks<'s>(&self, tasks: Vec<Task<'s>>) {
        pool::verify_declared_disjoint();
        for task in tasks {
            task();
        }
    }
}

// ---------------------------------------------------------------------------
// Blocked — cache-blocked microkernels + scoped worker pool
// ---------------------------------------------------------------------------

/// Parallel cache-blocked backend.
#[derive(Debug, Clone, Copy)]
pub struct Blocked {
    threads: usize,
    mc: usize,
    kc: usize,
    fixed: bool,
}

impl Blocked {
    /// `threads == 0` resolves to the machine's available parallelism.
    /// Uses the default (MC, KC) blocking, overridden per problem class
    /// by the installed [`tune`] table, when there is one.
    pub fn new(threads: usize) -> Self {
        Blocked { fixed: false, ..Blocked::with_blocks(threads, MC, KC) }
    }

    /// Pinned custom block sizes (the tuner and the block-sweep
    /// property tests use this) — never consults the tuning table.
    pub fn with_blocks(threads: usize, mc: usize, kc: usize) -> Self {
        let threads = if threads == 0 {
            available_threads()
        } else {
            threads
        };
        Blocked { threads, mc: mc.max(1), kc: kc.max(1), fixed: true }
    }

    /// Block shapes for one `(m, k, n)` matmul: pinned values, or the
    /// installed tuning table's winner with the defaults as fallback.
    /// Block shape never changes bits (see [`tune`]), only speed.
    fn blocks(&self, m: usize, k: usize, n: usize) -> (usize, usize) {
        if self.fixed {
            return (self.mc, self.kc);
        }
        let bl = tune::blocks_for(m, k, n, Precision::F32,
                                  tune::Blocks { mc: self.mc,
                                                 kc: self.kc });
        (bl.mc, bl.kc)
    }
}

/// Detected worker count (≥ 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl Backend for Blocked {
    fn name(&self) -> String {
        format!("blocked_t{}", self.threads)
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn batch_matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (ba, m, ka) = dims3(a);
        let (bb, kb, n) = dims3(b);
        assert_eq!(ba, bb, "batch mismatch");
        assert_eq!(ka, kb, "inner dim mismatch");
        let mut out = vec![0.0f32; ba * m * n];
        let (ad, bd) = (a.data(), b.data());
        let (mc, kc) = self.blocks(m, ka, n);
        par_batch_row_tiles(self.threads, ba, m, n, mc, &mut out,
                            |bi, i0, rows, tile| {
            let ap = &ad[bi * m * ka..(bi + 1) * m * ka];
            let bp = &bd[bi * ka * n..(bi + 1) * ka * n];
            nn_tile(ap, bp, tile, i0, rows, ka, n, kc);
        });
        Tensor::new(vec![ba, m, n], out)
    }

    fn batch_matmul_nt(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (ba, m, ka) = dims3(a);
        let (bb, n, kb) = dims3(b);
        assert_eq!(ba, bb, "batch mismatch");
        assert_eq!(ka, kb, "inner dim mismatch");
        let mut out = vec![0.0f32; ba * m * n];
        let (ad, bd) = (a.data(), b.data());
        let (mc, kc) = self.blocks(m, ka, n);
        par_batch_row_tiles(self.threads, ba, m, n, mc, &mut out,
                            |bi, i0, rows, tile| {
            let ap = &ad[bi * m * ka..(bi + 1) * m * ka];
            let bp = &bd[bi * n * ka..(bi + 1) * n * ka];
            nt_tile(ap, bp, tile, i0, rows, ka, n, kc);
        });
        Tensor::new(vec![ba, m, n], out)
    }

    fn batch_matmul_tn(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (ba, ka, m) = dims3(a);
        let (bb, kb, n) = dims3(b);
        assert_eq!(ba, bb, "batch mismatch");
        assert_eq!(ka, kb, "inner dim mismatch");
        let mut out = vec![0.0f32; ba * m * n];
        let (ad, bd) = (a.data(), b.data());
        let (mc, _) = self.blocks(m, ka, n);
        par_batch_row_tiles(self.threads, ba, m, n, mc, &mut out,
                            |bi, i0, rows, tile| {
            let ap = &ad[bi * ka * m..(bi + 1) * ka * m];
            let bp = &bd[bi * ka * n..(bi + 1) * ka * n];
            tn_tile(ap, bp, tile, i0, rows, ka, m, n);
        });
        Tensor::new(vec![ba, m, n], out)
    }

    fn run_tasks<'s>(&self, tasks: Vec<Task<'s>>) {
        run_pool(self.threads, tasks);
    }
}

/// Execute `tasks` over the persistent process-wide worker pool
/// ([`pool::global`]) with up to `threads` participants (the calling
/// thread included) — the shared fan-out of the parallel backends.
/// Workers survive across calls, so steady-state matmuls pay no thread
/// spawn cost; see [`pool`] for scheduling and determinism notes.
pub fn run_pool<'s>(threads: usize, tasks: Vec<Task<'s>>) {
    pool::global().run(threads, tasks);
}

/// The original transient `std::thread::scope` pool, retained as the
/// reference implementation the persistent pool is property-tested
/// against (`rust/tests/exec_pool.rs`).  Static round-robin assignment
/// keeps the partition independent of timing.
pub fn run_scoped<'s>(threads: usize, tasks: Vec<Task<'s>>) {
    pool::verify_declared_disjoint();
    let t = threads.min(tasks.len()).max(1);
    if t == 1 {
        for task in tasks {
            task();
        }
        return;
    }
    let mut buckets: Vec<Vec<Task<'s>>> =
        (0..t).map(|_| Vec::new()).collect();
    for (i, task) in tasks.into_iter().enumerate() {
        buckets[i % t].push(task);
    }
    let mine = buckets.remove(0);
    std::thread::scope(|scope| {
        for bucket in buckets {
            scope.spawn(move || {
                for task in bucket {
                    task();
                }
            });
        }
        for task in mine {
            task();
        }
    });
}

/// The shared matmul fan-out of the parallel backends: partition a
/// `(ba, m, n)` output into `mc`-row tiles per batch entry and run
/// `tile_fn(bi, i0, rows, tile)` over a `run_pool` of `threads`
/// workers.  Tile creation order (batch-major, rows ascending) and the
/// `carve` hand-out never depend on the thread count, which is half of
/// the backends' determinism contract (the other half is each tile
/// kernel's fixed accumulation order).
pub fn par_batch_row_tiles<F>(threads: usize, ba: usize, m: usize,
                              n: usize, mc: usize, out: &mut [f32],
                              tile_fn: F)
where
    F: Fn(usize, usize, usize, &mut [f32]) + Sync,
{
    let mut tasks: Vec<Task<'_>> = Vec::new();
    let mut rest: &mut [f32] = out;
    let f = &tile_fn;
    for bi in 0..ba {
        for i0 in (0..m).step_by(mc.max(1)) {
            let rows = mc.min(m - i0);
            let tile = carve(&mut rest, rows * n);
            pool::declare_task_writes(&[pool::span(&*tile)]);
            tasks.push(Box::new(move || f(bi, i0, rows, tile)));
        }
    }
    run_pool(threads, tasks);
}

/// NN tile: rows `i0..i0+rows` of A·B, k-blocked, axpy inner loop.
/// Accumulation order per output element matches `tensor::batch_matmul`
/// (k ascending, zero-skip), so results are bitwise-equal to Scalar.
fn nn_tile(ap: &[f32], bp: &[f32], tile: &mut [f32], i0: usize, rows: usize,
           ka: usize, n: usize, kc: usize) {
    for kk in (0..ka).step_by(kc) {
        let kend = (kk + kc).min(ka);
        for r in 0..rows {
            let arow = &ap[(i0 + r) * ka + kk..(i0 + r) * ka + kend];
            let orow = &mut tile[r * n..(r + 1) * n];
            for (k, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &bp[(kk + k) * n..(kk + k + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
}

/// NT tile: rows `i0..i0+rows` of A·Bᵀ with an MR×NR register tile —
/// `NR` independent accumulator lanes per row so the dot products
/// vectorise, `MR` rows sharing each B panel load.  Per-element k order
/// is ascending, matching `tensor::batch_matmul_nt` bitwise.
fn nt_tile(ap: &[f32], bp: &[f32], tile: &mut [f32], i0: usize, rows: usize,
           ka: usize, n: usize, kc: usize) {
    let mut r0 = 0;
    while r0 < rows {
        let mr = MR.min(rows - r0);
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            let mut acc = [[0.0f32; NR]; MR];
            for kk in (0..ka).step_by(kc) {
                let kend = (kk + kc).min(ka);
                for k in kk..kend {
                    let mut bvals = [0.0f32; NR];
                    for (jj, bv) in bvals[..nr].iter_mut().enumerate() {
                        *bv = bp[(j0 + jj) * ka + k];
                    }
                    for (ri, accrow) in acc[..mr].iter_mut().enumerate() {
                        let av = ap[(i0 + r0 + ri) * ka + k];
                        for (jj, acc1) in accrow[..nr].iter_mut()
                            .enumerate()
                        {
                            *acc1 += av * bvals[jj];
                        }
                    }
                }
            }
            for (ri, accrow) in acc[..mr].iter().enumerate() {
                let orow = &mut tile[(r0 + ri) * n + j0
                                     ..(r0 + ri) * n + j0 + nr];
                orow.copy_from_slice(&accrow[..nr]);
            }
            j0 += nr;
        }
        r0 += mr;
    }
}

/// TN tile: output rows `i0..i0+rows` (columns of A), k-ascending axpy —
/// bitwise-equal to `tensor::batch_matmul_tn`.
fn tn_tile(ap: &[f32], bp: &[f32], tile: &mut [f32], i0: usize, rows: usize,
           ka: usize, m: usize, n: usize) {
    for k in 0..ka {
        let arow = &ap[k * m..(k + 1) * m];
        let brow = &bp[k * n..(k + 1) * n];
        for r in 0..rows {
            let av = arow[i0 + r];
            if av == 0.0 {
                continue;
            }
            let orow = &mut tile[r * n..(r + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Configuration plumbing
// ---------------------------------------------------------------------------

/// Which backend family to instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// The single-threaded reference loops ([`Scalar`]).
    Scalar,
    /// The parallel cache-blocked backend ([`Blocked`]).
    Blocked,
    /// The vectorized backend with selectable numerics ([`Simd`]).
    Simd,
}

impl BackendKind {
    /// Parse the config/CLI spelling (`"scalar"`, `"blocked"`, or
    /// `"simd"`).
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "scalar" => Ok(BackendKind::Scalar),
            "blocked" => Ok(BackendKind::Blocked),
            "simd" => Ok(BackendKind::Simd),
            other => bail!("unknown exec backend {other:?} \
                            (expected \"scalar\", \"blocked\", or \
                            \"simd\")"),
        }
    }

    /// Canonical config spelling (inverse of [`BackendKind::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Scalar => "scalar",
            BackendKind::Blocked => "blocked",
            BackendKind::Simd => "simd",
        }
    }
}

/// Backend selection carried through config / CLI / harness options.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecOptions {
    /// Backend family to instantiate.
    pub kind: BackendKind,
    /// Worker threads; 0 = auto-detect.  Ignored by `Scalar`.
    pub threads: usize,
    /// Numeric mode; `Mixed` is only honoured by the `Simd` backend
    /// (see [`ExecOptions::validate`]).
    pub precision: Precision,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            kind: BackendKind::Blocked,
            threads: 0,
            precision: Precision::F32,
        }
    }
}

impl ExecOptions {
    /// The single-threaded reference selection.
    pub fn scalar() -> Self {
        ExecOptions {
            kind: BackendKind::Scalar,
            threads: 1,
            precision: Precision::F32,
        }
    }

    /// The parallel cache-blocked selection (0 = auto threads).
    pub fn blocked(threads: usize) -> Self {
        ExecOptions {
            kind: BackendKind::Blocked,
            threads,
            precision: Precision::F32,
        }
    }

    /// The vectorized selection at a given numeric mode.
    pub fn simd(threads: usize, precision: Precision) -> Self {
        ExecOptions { kind: BackendKind::Simd, threads, precision }
    }

    /// Apply an explicit `precision` choice to this selection — the one
    /// shared implementation of the "mixed implies simd" rule for the
    /// CLI and the bench environment.  `Mixed` exists only in the
    /// `Simd` backend, so when the backend itself was **not**
    /// explicitly chosen (`backend_explicit == false`) a mixed request
    /// selects `Simd` instead of erroring against a default nobody
    /// picked; an explicitly chosen non-simd backend is left alone and
    /// fails [`ExecOptions::validate`].
    pub fn with_precision(mut self, precision: Precision,
                          backend_explicit: bool) -> Self {
        self.precision = precision;
        if !backend_explicit && precision == Precision::Mixed {
            self.kind = BackendKind::Simd;
        }
        self
    }

    /// Reject combinations the backends cannot honour: mixed precision
    /// is a property of the `Simd` kernels, so `precision = "mixed"`
    /// with any other backend is a configuration error rather than a
    /// silent full-precision run.
    pub fn validate(self) -> Result<()> {
        if self.precision == Precision::Mixed
            && self.kind != BackendKind::Simd
        {
            bail!("precision \"mixed\" requires backend = \"simd\" \
                   (got backend = {:?})", self.kind.name());
        }
        Ok(())
    }

    /// Instantiate the configured backend.
    pub fn build(self) -> Box<dyn Backend> {
        match self.kind {
            BackendKind::Scalar => Box::new(Scalar),
            BackendKind::Blocked => Box::new(Blocked::new(self.threads)),
            BackendKind::Simd => {
                Box::new(Simd::new(self.threads, self.precision))
            }
        }
    }
}

/// One instance of every available backend at the configured thread
/// count: the `Scalar` reference, `Blocked`, and `Simd` in both
/// numeric modes.  This is the cross-check set of [`self_check`] /
/// `attention::witness_self_check`, and the side-by-side roster of the
/// host bench figures — whatever `opts.kind` selects is always a
/// member.
pub fn roster(opts: ExecOptions) -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(Scalar),
        Box::new(Blocked::new(opts.threads)),
        Box::new(Simd::new(opts.threads, Precision::F32)),
        Box::new(Simd::new(opts.threads, Precision::Mixed)),
    ]
}

/// Cheap startup cross-check: every available backend (the full
/// [`roster`], not just the configured one) runs the three matmul
/// flavours on a non-trivial case, and all results are compared
/// **pairwise** so a failure names the diverging pair.  Pure-f32 pairs
/// must agree to ~1 ulp; pairs involving the mixed backend get a loose
/// bf16-scaled sanity bound (the rigorous per-element bound lives in
/// `rust/tests/exec_backend.rs`).  Run by `spark train` before
/// committing to a long run.
pub fn self_check(opts: ExecOptions) -> Result<()> {
    let backends = roster(opts);
    let mut rng = crate::tensor::Rng::new(0xC0FFEE);
    let a = Tensor::randn(vec![3, 37, 19], &mut rng);
    let b = Tensor::randn(vec![3, 19, 23], &mut rng);
    let bt = Tensor::randn(vec![3, 23, 19], &mut rng);
    let at = Tensor::randn(vec![3, 19, 37], &mut rng);
    for flavour in ["nn", "nt", "tn"] {
        let outs: Vec<Tensor> = backends
            .iter()
            .map(|be| match flavour {
                "nn" => be.batch_matmul(&a, &b),
                "nt" => be.batch_matmul_nt(&a, &bt),
                _ => be.batch_matmul_tn(&at, &b),
            })
            .collect();
        let scale = outs[0].data().iter()
            .fold(0.0f32, |m, &x| m.max(x.abs()));
        let mixed_tol = scale * bf16::EPSILON * 16.0 + 1e-6;
        for i in 0..backends.len() {
            for j in i + 1..backends.len() {
                let same_mode =
                    backends[i].precision() == backends[j].precision();
                let tol = if same_mode { 1e-5 } else { mixed_tol };
                let err = outs[i].max_abs_diff(&outs[j]);
                if err > tol {
                    bail!("exec self-check: backends {} and {} diverge \
                           on {flavour} (max err {err}, tol {tol})",
                          backends[i].name(), backends[j].name());
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        Tensor::randn(shape.to_vec(), &mut r)
    }

    #[test]
    fn blocked_nn_matches_scalar_bitwise() {
        for (ba, m, k, n, seed) in [(1, 1, 1, 1, 1u64), (2, 7, 13, 5, 2),
                                    (3, 64, 96, 33, 3), (1, 130, 17, 9, 4)] {
            let a = randn(&[ba, m, k], seed);
            let b = randn(&[ba, k, n], seed + 100);
            let want = Scalar.batch_matmul(&a, &b);
            for be in [Blocked::with_blocks(2, 3, 4),
                       Blocked::with_blocks(4, 64, 256)] {
                let got = be.batch_matmul(&a, &b);
                assert_eq!(got.data(), want.data(),
                           "nn ({ba},{m},{k},{n}) via {}", be.name());
            }
        }
    }

    #[test]
    fn blocked_nt_matches_scalar_bitwise() {
        for (ba, m, k, n, seed) in [(1, 1, 3, 1, 1u64), (2, 9, 13, 7, 2),
                                    (2, 65, 40, 31, 3), (1, 4, 1, 21, 4)] {
            let a = randn(&[ba, m, k], seed);
            let b = randn(&[ba, n, k], seed + 100);
            let want = Scalar.batch_matmul_nt(&a, &b);
            for be in [Blocked::with_blocks(2, 5, 3),
                       Blocked::with_blocks(8, 64, 256)] {
                let got = be.batch_matmul_nt(&a, &b);
                assert_eq!(got.data(), want.data(),
                           "nt ({ba},{m},{k},{n}) via {}", be.name());
            }
        }
    }

    #[test]
    fn blocked_tn_matches_scalar_bitwise() {
        for (ba, m, k, n, seed) in [(1, 2, 3, 4, 1u64), (2, 11, 6, 13, 2),
                                    (2, 70, 24, 18, 3)] {
            let a = randn(&[ba, k, m], seed);
            let b = randn(&[ba, k, n], seed + 100);
            let want = Scalar.batch_matmul_tn(&a, &b);
            for be in [Blocked::with_blocks(3, 7, 2),
                       Blocked::with_blocks(2, 64, 256)] {
                let got = be.batch_matmul_tn(&a, &b);
                assert_eq!(got.data(), want.data(),
                           "tn ({ba},{m},{k},{n}) via {}", be.name());
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let a = randn(&[2, 50, 30], 7);
        let b = randn(&[2, 30, 41], 8);
        let base = Blocked::with_blocks(1, 16, 8).batch_matmul(&a, &b);
        for t in [2, 3, 8, 32] {
            let got = Blocked::with_blocks(t, 16, 8).batch_matmul(&a, &b);
            assert_eq!(got.data(), base.data(), "threads={t}");
        }
    }

    #[test]
    fn run_tasks_executes_everything() {
        let mut hits = vec![0u8; 23];
        {
            let be = Blocked::new(4);
            let mut tasks: Vec<Task<'_>> = Vec::new();
            for h in hits.iter_mut() {
                tasks.push(Box::new(move || {
                    *h += 1;
                }));
            }
            be.run_tasks(tasks);
        }
        assert!(hits.iter().all(|&h| h == 1));
    }

    #[test]
    fn par_row_chunks_covers_all_rows() {
        let mut data = vec![0.0f32; 7 * 5];
        par_row_chunks(&Blocked::new(3), &mut data, 5, 2, |ci, chunk| {
            for (r, row) in chunk.chunks_exact_mut(5).enumerate() {
                for x in row.iter_mut() {
                    *x = (ci * 2 + r) as f32;
                }
            }
        });
        for (r, row) in data.chunks_exact(5).enumerate() {
            assert!(row.iter().all(|&x| x == r as f32), "row {r}");
        }
    }

    #[test]
    fn empty_shapes_are_fine() {
        let a = Tensor::zeros(vec![0, 4, 3]);
        let b = Tensor::zeros(vec![0, 3, 2]);
        assert_eq!(Blocked::new(2).batch_matmul(&a, &b).shape(),
                   &[0, 4, 2]);
        let a = Tensor::zeros(vec![2, 0, 3]);
        let b = Tensor::zeros(vec![2, 3, 4]);
        assert_eq!(Blocked::new(2).batch_matmul(&a, &b).len(), 0);
    }

    #[test]
    fn options_build_and_parse() {
        assert_eq!(BackendKind::parse("scalar").unwrap(),
                   BackendKind::Scalar);
        assert_eq!(BackendKind::parse("blocked").unwrap(),
                   BackendKind::Blocked);
        assert_eq!(BackendKind::parse("simd").unwrap(), BackendKind::Simd);
        assert!(BackendKind::parse("gpu").is_err());
        let be = ExecOptions::blocked(2).build();
        assert_eq!(be.threads(), 2);
        assert_eq!(be.name(), "blocked_t2");
        assert_eq!(ExecOptions::scalar().build().name(), "scalar");
        assert_eq!(ExecOptions::simd(4, Precision::F32).build().name(),
                   "simd_t4");
        assert_eq!(ExecOptions::simd(4, Precision::Mixed).build().name(),
                   "simd_t4_mixed");
        assert!(ExecOptions::default().build().threads() >= 1);
    }

    #[test]
    fn validate_rejects_mixed_on_non_simd() {
        assert!(ExecOptions::simd(2, Precision::Mixed).validate().is_ok());
        assert!(ExecOptions::blocked(2).validate().is_ok());
        let bad = ExecOptions {
            precision: Precision::Mixed,
            ..ExecOptions::blocked(2)
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn with_precision_implies_simd_only_for_implicit_backends() {
        // default backend + mixed → simd is implied
        let opts = ExecOptions::default()
            .with_precision(Precision::Mixed, false);
        assert_eq!(opts.kind, BackendKind::Simd);
        assert!(opts.validate().is_ok());
        // explicitly chosen blocked + mixed → left alone, fails validate
        let opts = ExecOptions::blocked(2)
            .with_precision(Precision::Mixed, true);
        assert_eq!(opts.kind, BackendKind::Blocked);
        assert!(opts.validate().is_err());
        // f32 never rewrites the backend
        let opts = ExecOptions::blocked(2)
            .with_precision(Precision::F32, false);
        assert_eq!(opts.kind, BackendKind::Blocked);
    }

    #[test]
    fn backend_precision_defaults_to_f32() {
        assert_eq!(Scalar.precision(), Precision::F32);
        assert_eq!(Blocked::new(1).precision(), Precision::F32);
        assert_eq!(Simd::new(1, Precision::Mixed).precision(),
                   Precision::Mixed);
    }

    #[test]
    fn roster_covers_every_configured_kind() {
        for opts in [ExecOptions::scalar(), ExecOptions::blocked(2),
                     ExecOptions::simd(2, Precision::F32),
                     ExecOptions::simd(2, Precision::Mixed)] {
            let names: Vec<String> =
                roster(opts).iter().map(|b| b.name()).collect();
            assert!(names.contains(&opts.build().name()),
                    "{names:?} missing {}", opts.build().name());
        }
    }

    #[test]
    fn self_check_passes_for_all_backends_pairwise() {
        self_check(ExecOptions::default()).unwrap();
        self_check(ExecOptions::simd(2, Precision::Mixed)).unwrap();
    }
}
