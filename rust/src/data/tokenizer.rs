//! Byte-level tokenizer (vocab 256) — matches the exported LM's vocab.
//!
//! Deliberately simple: the train-step artifact bakes `vocab = 256`, and a
//! byte tokenizer needs no learned merges, keeping the Rust request path
//! free of Python-trained state.  Round-trips arbitrary bytes exactly.

/// Byte-level tokenizer with an optional BOS byte convention.
#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    /// Vocabulary size (one id per byte value).
    pub const VOCAB: usize = 256;

    /// The (stateless) tokenizer.
    pub fn new() -> Self {
        ByteTokenizer
    }

    /// Bytes → token ids (identity embedding into i32).
    pub fn encode(&self, text: &[u8]) -> Vec<i32> {
        text.iter().map(|&b| b as i32).collect()
    }

    /// UTF-8 text → token ids over its bytes.
    pub fn encode_str(&self, text: &str) -> Vec<i32> {
        self.encode(text.as_bytes())
    }

    /// Token ids → bytes (ids are masked to 0..=255).
    pub fn decode(&self, tokens: &[i32]) -> Vec<u8> {
        tokens.iter().map(|&t| {
            debug_assert!((0..256).contains(&t), "token {t} out of range");
            (t & 0xFF) as u8
        }).collect()
    }

    /// Token ids → text, replacing invalid UTF-8 sequences.
    pub fn decode_lossy(&self, tokens: &[i32]) -> String {
        String::from_utf8_lossy(&self.decode(tokens)).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer::new();
        let ids = t.encode_str("hello spark");
        assert_eq!(ids.len(), 11);
        assert_eq!(t.decode_lossy(&ids), "hello spark");
    }

    #[test]
    fn roundtrip_all_bytes() {
        let t = ByteTokenizer::new();
        let bytes: Vec<u8> = (0..=255).collect();
        let ids = t.encode(&bytes);
        assert!(ids.iter().all(|&i| (0..256).contains(&i)));
        assert_eq!(t.decode(&ids), bytes);
    }

    #[test]
    fn utf8_multibyte_survives() {
        let t = ByteTokenizer::new();
        let s = "héllo 世界";
        assert_eq!(t.decode_lossy(&t.encode_str(s)), s);
    }
}
