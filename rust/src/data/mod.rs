//! Data pipeline: synthetic corpus generation, byte-level tokenisation,
//! and deterministic batching for the training loop.
//!
//! The paper uses random data ("we use random numbers as the dataset",
//! §4.1); for the end-to-end training example we go one step further and
//! synthesise a corpus with *learnable structure* — a Zipf-distributed
//! unigram mix over Markov bigram templates — so the loss curve in
//! EXPERIMENTS.md demonstrably decreases for a reason.

pub mod corpus;
pub mod tokenizer;

pub use corpus::CorpusGenerator;
pub use tokenizer::ByteTokenizer;

use crate::tensor::Rng;

/// Deterministic batcher: shuffles window starts and yields (batch, seq+1)
/// token blocks (inputs ∥ next-token targets share the block).
#[derive(Debug)]
pub struct Batcher {
    tokens: Vec<i32>,
    batch: usize,
    /// Window length in tokens = model seq + 1 (input + shifted target).
    window: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl Batcher {
    /// Batcher over `tokens` with `batch` rows of `seq + 1` tokens each
    /// (panics if the corpus holds fewer than `batch` windows).
    pub fn new(tokens: Vec<i32>, batch: usize, seq: usize, seed: u64)
               -> Self {
        let window = seq + 1;
        assert!(tokens.len() >= window,
                "corpus ({} tokens) shorter than one window ({window})",
                tokens.len());
        let n_windows = tokens.len() - window + 1;
        // non-overlapping stride = window keeps batches decorrelated
        let starts: Vec<usize> = (0..n_windows).step_by(window).collect();
        assert!(starts.len() >= batch,
                "corpus too small: {} windows < batch {batch}",
                starts.len());
        let mut b = Batcher {
            tokens,
            batch,
            window,
            order: starts,
            cursor: 0,
            rng: Rng::new(seed),
        };
        b.shuffle();
        b
    }

    fn shuffle(&mut self) {
        // Fisher–Yates on the window starts
        for i in (1..self.order.len()).rev() {
            let j = self.rng.below(i + 1);
            self.order.swap(i, j);
        }
        self.cursor = 0;
    }

    /// Number of full batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.order.len() / self.batch
    }

    /// Next (batch × window) block, row-major; reshuffles at epoch end.
    pub fn next_batch(&mut self) -> Vec<i32> {
        if self.cursor + self.batch > self.order.len() {
            self.shuffle();
        }
        let mut out = Vec::with_capacity(self.batch * self.window);
        for r in 0..self.batch {
            let start = self.order[self.cursor + r];
            out.extend_from_slice(&self.tokens[start..start + self.window]);
        }
        self.cursor += self.batch;
        out
    }

    /// Window length in tokens (seq + 1).
    pub fn window(&self) -> usize {
        self.window
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_tokens(n: usize) -> Vec<i32> {
        (0..n as i32).collect()
    }

    #[test]
    fn batch_shape_and_range() {
        let mut b = Batcher::new(toy_tokens(1000), 4, 16, 1);
        let batch = b.next_batch();
        assert_eq!(batch.len(), 4 * 17);
        assert!(batch.iter().all(|&t| (0..1000).contains(&t)));
    }

    #[test]
    fn windows_are_contiguous_runs() {
        let mut b = Batcher::new(toy_tokens(1000), 2, 8, 2);
        let batch = b.next_batch();
        for row in batch.chunks_exact(9) {
            for w in row.windows(2) {
                assert_eq!(w[1], w[0] + 1, "window must be contiguous");
            }
        }
    }

    #[test]
    fn epochs_reshuffle_deterministically() {
        let mk = || Batcher::new(toy_tokens(500), 2, 9, 7);
        let mut b1 = mk();
        let mut b2 = mk();
        for _ in 0..40 {
            assert_eq!(b1.next_batch(), b2.next_batch(),
                       "same seed → same batch stream");
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Batcher::new(toy_tokens(500), 2, 9, 1);
        let mut b = Batcher::new(toy_tokens(500), 2, 9, 2);
        assert_ne!(a.next_batch(), b.next_batch());
    }

    #[test]
    #[should_panic(expected = "corpus too small")]
    fn rejects_tiny_corpus() {
        Batcher::new(toy_tokens(20), 8, 16, 0);
    }
}
