//! Synthetic corpus generator with learnable structure.
//!
//! A pure-random byte stream has ln(256) ≈ 5.55 nats of irreducible
//! per-token entropy — a model trained on it can only learn the unigram
//! margin.  To make the end-to-end training example meaningful, the
//! generator emits a **Markov bigram process over a Zipf template set**:
//!
//! * a small set of "word" templates (byte strings) drawn once per seed,
//! * words sampled by Zipf rank with bigram coupling (each word biases the
//!   next), separated by spaces, wrapped to lines.
//!
//! A transformer LM can drive its loss well below the unigram entropy by
//! learning the templates and their transitions — visible in the loss
//! curve recorded in EXPERIMENTS.md (experiment E7).

use crate::tensor::Rng;

/// Configurable generator (deterministic per seed).
#[derive(Debug, Clone)]
pub struct CorpusGenerator {
    /// Number of distinct word templates.
    pub n_words: usize,
    /// Zipf exponent over word ranks.
    pub zipf: f64,
    /// Probability of following the bigram chain vs drawing fresh.
    pub bigram_coupling: f64,
    /// Target line width in bytes.
    pub line_width: usize,
}

impl Default for CorpusGenerator {
    fn default() -> Self {
        CorpusGenerator {
            n_words: 512,
            zipf: 1.1,
            bigram_coupling: 0.6,
            line_width: 64,
        }
    }
}

impl CorpusGenerator {
    /// Generate ~`n_bytes` of corpus text (may overshoot by one word).
    pub fn generate(&self, n_bytes: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        let words = self.make_words(&mut rng);
        // successor table: each word has a preferred follower
        let succ: Vec<usize> =
            (0..self.n_words).map(|_| rng.below(self.n_words)).collect();

        let mut out = Vec::with_capacity(n_bytes + 16);
        let mut col = 0usize;
        let mut prev = rng.below(self.n_words);
        while out.len() < n_bytes {
            let w = if rng.uniform() < self.bigram_coupling {
                succ[prev]
            } else {
                rng.zipf(self.n_words, self.zipf)
            };
            let bytes = &words[w];
            out.extend_from_slice(bytes);
            col += bytes.len() + 1;
            if col >= self.line_width {
                out.push(b'\n');
                col = 0;
            } else {
                out.push(b' ');
            }
            prev = w;
        }
        out.truncate(n_bytes);
        out
    }

    /// Word templates: lowercase strings with Zipf-rank-correlated length
    /// (frequent words are short, like natural language).
    fn make_words(&self, rng: &mut Rng) -> Vec<Vec<u8>> {
        (0..self.n_words).map(|rank| {
            let len = 2 + (rank * 8 / self.n_words.max(1))
                + rng.below(3);
            (0..len).map(|_| b'a' + rng.below(26) as u8).collect()
        }).collect()
    }

    /// Empirical per-byte entropy (nats) of a sample — used by tests to
    /// prove the corpus is compressible (structure exists to learn).
    pub fn unigram_entropy_nats(sample: &[u8]) -> f64 {
        let mut counts = [0usize; 256];
        for &b in sample {
            counts[b as usize] += 1;
        }
        let n = sample.len() as f64;
        counts.iter().filter(|&&c| c > 0).map(|&c| {
            let p = c as f64 / n;
            -p * p.ln()
        }).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let g = CorpusGenerator::default();
        assert_eq!(g.generate(4096, 7), g.generate(4096, 7));
        assert_ne!(g.generate(4096, 7), g.generate(4096, 8));
    }

    #[test]
    fn requested_length() {
        let g = CorpusGenerator::default();
        assert_eq!(g.generate(10_000, 1).len(), 10_000);
    }

    #[test]
    fn corpus_is_ascii_text() {
        let g = CorpusGenerator::default();
        let c = g.generate(8192, 3);
        assert!(c.iter().all(|&b| b == b' ' || b == b'\n'
                             || b.is_ascii_lowercase()));
    }

    #[test]
    fn entropy_well_below_uniform() {
        // ln(256) ≈ 5.55; text over {a-z, space, \n} with Zipf words must
        // be far more predictable even at the unigram level.
        let g = CorpusGenerator::default();
        let c = g.generate(1 << 16, 5);
        let h = CorpusGenerator::unigram_entropy_nats(&c);
        assert!(h < 3.4, "unigram entropy {h} nats — not text-like");
        assert!(h > 1.5, "entropy {h} suspiciously low — degenerate corpus");
    }

    #[test]
    fn zipf_head_dominates() {
        let g = CorpusGenerator { bigram_coupling: 0.0,
                                  ..CorpusGenerator::default() };
        let c = g.generate(1 << 16, 9);
        // most frequent word should appear much more often than median
        let text = String::from_utf8(c).unwrap();
        let mut counts = std::collections::BTreeMap::new();
        for w in text.split_whitespace() {
            *counts.entry(w.to_string()).or_insert(0usize) += 1;
        }
        let mut freqs: Vec<usize> = counts.values().cloned().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        assert!(freqs[0] > 4 * freqs[freqs.len() / 2],
                "head {} vs median {}", freqs[0], freqs[freqs.len() / 2]);
    }
}
