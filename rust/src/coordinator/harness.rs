//! The evaluation harness: regenerates every figure/table of the paper.
//!
//! * `fig10_forward`  — MHA-Forward TFLOP/s sweep (fused f32/bf16-ACC vs
//!   the unfused PyTorch-FP16 analog), grouped by (head-dim, causal).
//! * `fig11_backward` — MHA-Backward sweep.  PyTorch times its backward
//!   kernels alone, so the unfused backward is reported as
//!   `t(fwd+bwd) − t(fwd)`; the fused backward artifact is pure backward
//!   (recomputation included, as in the paper).
//! * `fig12_e2e`      — single-encoder-layer forward latency across fusion
//!   scopes, with OOM/NS cells from the host memory budget.
//! * `accuracy_report` — §4.2.3: rel/abs error of every variant against
//!   the f32 oracle.
//! * `io_report` / `projected_fig10` — the §2.3 I/O claim and the V100
//!   roofline projection of the paper-scale grid (E5, E1-projection).
//!
//! Measured CPU numbers demonstrate the *shape* (who wins, how the gap
//! scales with n); the projection carries the paper-scale magnitudes.

use anyhow::{bail, Context, Result};
use log::{info, warn};

use super::inputs::synth_inputs;
use crate::attention::{self, AttnParams, MaskSpec};
use crate::bench::{measure, measure_wallclock, skipped_row, Options,
                   Report, Row};
use crate::exec::{self, Backend, ExecOptions, Scalar};
use crate::iomodel::{self, MhaShape};
use crate::perfmodel::{self, Bound, Machine};
use crate::runtime::{ArtifactMeta, Engine, HostValue};
use crate::tensor::{Rng, Tensor};

/// Harness knobs shared by the figure generators.
#[derive(Debug, Clone, Copy)]
pub struct HarnessOptions {
    /// Warmup/measurement iteration policy.
    pub bench: Options,
    /// Host-memory admission budget (bytes): artifacts whose modeled peak
    /// exceeds it are reported as OOM instead of executed.
    pub mem_budget: usize,
    /// Host execution backend for the pure-Rust attention path.
    pub exec: ExecOptions,
    /// The user explicitly pinned a backend (`--backend`/`--precision`
    /// or `SPARK_EXEC_BACKEND`/`SPARK_EXEC_PRECISION`): the host
    /// figures then bench only `scalar` + the configured backend
    /// instead of sweeping the full roster.
    pub exec_pinned: bool,
}

impl Default for HarnessOptions {
    fn default() -> Self {
        HarnessOptions {
            bench: Options::default(),
            mem_budget: 8 << 30,
            exec: ExecOptions::default(),
            exec_pinned: false,
        }
    }
}

/// The backend set a host figure sweeps: the full `exec::roster`
/// (scalar, blocked, simd, simd-mixed at the configured thread count)
/// by default, or just `Scalar` + the configured backend when the user
/// explicitly pinned one ([`HarnessOptions::exec_pinned`]).
pub fn report_roster(opts: HarnessOptions) -> Vec<Box<dyn Backend>> {
    if !opts.exec_pinned {
        return exec::roster(opts.exec);
    }
    let configured = opts.exec.build();
    if configured.name() == Scalar.name() {
        vec![Box::new(Scalar)]
    } else {
        vec![Box::new(Scalar), configured]
    }
}

fn mha_group(meta: &ArtifactMeta) -> String {
    format!("d{}{}", meta.attr_i64("d").unwrap_or(0),
            if meta.attr_bool("causal").unwrap_or(false) {
                "/causal"
            } else {
                "/full"
            })
}

fn mha_shape(meta: &ArtifactMeta) -> MhaShape {
    MhaShape::new(meta.attr_i64("bh").unwrap_or(1) as usize,
                  meta.attr_i64("n").unwrap_or(0) as usize,
                  meta.attr_i64("d").unwrap_or(0) as usize)
}

/// Admission check: unfused variants materialise N×N tensors on the host
/// backend too — refuse what would not fit (the Fig 10 OOM cells).
fn admit(meta: &ArtifactMeta, fused: bool, budget: usize) -> bool {
    let peak = iomodel::peak_resident_bytes(mha_shape(meta), fused);
    peak <= budget
}

fn run_mha_rows(eng: &Engine, report: &mut Report, kind: &str,
                variant_of: impl Fn(&ArtifactMeta) -> String, fused: bool,
                backward: bool, opts: HarnessOptions,
                dropout_filter: i64) -> Result<()> {
    let metas: Vec<ArtifactMeta> = eng.manifest().of_kind(kind)
        .filter(|m| (m.attr_f64("dropout").unwrap_or(0.0) * 100.0) as i64
                == dropout_filter)
        .cloned().collect();
    for meta in metas {
        let group = mha_group(&meta);
        let variant = variant_of(&meta);
        let n = meta.attr_i64("n").unwrap_or(0) as usize;
        let s = mha_shape(&meta);
        let causal = meta.attr_bool("causal").unwrap_or(false);
        if !admit(&meta, fused, opts.mem_budget) {
            report.push(skipped_row(&group, &variant, n, "oom"));
            continue;
        }
        let ins = prepare_inputs(eng, &meta)?;
        let time = measure(opts.bench, || {
            Ok(eng.execute_timed(&meta.name, &ins)?.1)
        }).with_context(|| format!("benching {}", meta.name))?;
        report.push(Row {
            group, variant, x: n, time,
            flops: attention::attention_flops(s.bh, s.n, s.d, causal,
                                              backward),
            status: "ok".into(),
        });
    }
    Ok(())
}

/// Inputs for MHA artifacts; backward artifacts get a real (o, lse) pair
/// by running the matching forward once (not timed).
fn prepare_inputs(eng: &Engine, meta: &ArtifactMeta)
                  -> Result<Vec<HostValue>> {
    let base = synth_inputs(meta, 42)?;
    if meta.kind != "mha_bwd" {
        return Ok(base);
    }
    // Find the forward twin: same d/n/bh/causal/dropout, any acc.
    let twin = eng.manifest().of_kind("mha_fwd").find(|f| {
        ["d", "n", "bh"].iter().all(
            |k| f.attr_i64(k) == meta.attr_i64(k))
            && f.attr_bool("causal") == meta.attr_bool("causal")
            && f.attr_f64("dropout") == meta.attr_f64("dropout")
    }).with_context(|| format!("no forward twin for {}", meta.name))?
        .clone();
    // bwd inputs: seed, q, k, v, o, lse, do — reuse the synth q,k,v.
    let fwd_out = eng.execute(&twin.name, &base[..4])?;
    let mut ins = base[..4].to_vec();
    ins.push(fwd_out[0].clone()); // o
    ins.push(fwd_out[1].clone()); // lse
    // dO: a fresh normal tensor, bf16-quantised
    let mut rng = Rng::new(43);
    let shape = meta.inputs[6].shape.clone();
    let n: usize = shape.iter().product();
    ins.push(HostValue::F32 {
        shape,
        data: rng.normal_vec(n).into_iter()
            .map(crate::tensor::bf16::quantize).collect(),
    });
    Ok(ins)
}

/// Figure 10: MHA-Forward performance sweep.
pub fn fig10_forward(eng: &Engine, opts: HarnessOptions) -> Result<Report> {
    let mut report = Report::new(
        "Fig 10 — MHA-Forward (TFLOP/s, higher is better)");
    run_mha_rows(eng, &mut report, "mha_fwd", |m| {
        format!("spark_{}acc", m.attr_str("acc").unwrap_or("?"))
    }, true, false, opts, 10)?;
    run_mha_rows(eng, &mut report, "mha_fwd_unf",
                 |_| "pytorch_fp16".into(), false, false, opts, 10)?;
    if let Some((mean, max)) =
        report.speedup_summary("spark_f32acc", "pytorch_fp16") {
        info!("fig10: fused f32-ACC vs unfused: avg {mean:.2}× (max {max:.2}×)");
    }
    Ok(report)
}

/// Figure 11: MHA-Backward performance sweep.
///
/// Unfused backward = t(fwd+bwd) − t(fwd), clamped at 10% of the combined
/// time to guard against noise inversion.
pub fn fig11_backward(eng: &Engine, opts: HarnessOptions) -> Result<Report> {
    let mut report = Report::new(
        "Fig 11 — MHA-Backward (TFLOP/s, higher is better)");
    run_mha_rows(eng, &mut report, "mha_bwd", |m| {
        format!("spark_{}acc", m.attr_str("acc").unwrap_or("?"))
    }, true, true, opts, 10)?;

    // Unfused: measure fwd and fwd+bwd, difference the means.
    let combos: Vec<ArtifactMeta> = eng.manifest().of_kind("mha_fwdbwd_unf")
        .filter(|m| (m.attr_f64("dropout").unwrap_or(0.0) * 100.0) as i64
                == 10)
        .cloned().collect();
    for meta in combos {
        let group = mha_group(&meta);
        let n = meta.attr_i64("n").unwrap_or(0) as usize;
        let s = mha_shape(&meta);
        let causal = meta.attr_bool("causal").unwrap_or(false);
        if !admit(&meta, false, opts.mem_budget) {
            report.push(skipped_row(&group, "pytorch_fp16", n, "oom"));
            continue;
        }
        let fwd_twin = eng.manifest().of_kind("mha_fwd_unf").find(|f| {
            ["d", "n", "bh"].iter().all(
                |k| f.attr_i64(k) == meta.attr_i64(k))
                && f.attr_bool("causal") == meta.attr_bool("causal")
                && f.attr_f64("dropout") == meta.attr_f64("dropout")
        }).map(|f| f.name.clone());
        let ins = synth_inputs(&meta, 42)?;
        let combined = measure(opts.bench, || {
            Ok(eng.execute_timed(&meta.name, &ins)?.1)
        })?;
        let bwd_mean = match fwd_twin {
            Some(fname) => {
                let fmeta = eng.manifest().get(&fname)?.clone();
                let fins = synth_inputs(&fmeta, 42)?;
                let fwd = measure(opts.bench, || {
                    Ok(eng.execute_timed(&fname, &fins)?.1)
                })?;
                (combined.mean() - fwd.mean()).max(combined.mean() * 0.1)
            }
            None => {
                warn!("no unfused forward twin for {}; reporting combined",
                      meta.name);
                combined.mean()
            }
        };
        let mut time = crate::metrics::Series::default();
        time.record(bwd_mean);
        report.push(Row {
            group, variant: "pytorch_fp16".into(), x: n, time,
            flops: attention::attention_flops(s.bh, s.n, s.d, causal, true),
            status: "ok".into(),
        });
    }
    if let Some((mean, max)) =
        report.speedup_summary("spark_bf16acc", "pytorch_fp16") {
        info!("fig11: fused bf16-ACC vs unfused: avg {mean:.2}× (max {max:.2}×)");
    }
    Ok(report)
}

/// Figure 12: end-to-end encoder-layer forward latency.
pub fn fig12_e2e(eng: &Engine, opts: HarnessOptions) -> Result<Report> {
    let mut report = Report::new(
        "Fig 12 — Encoder-Forward latency (ms, lower is better)");
    // Bench the paper's configuration (dropout 0.1); dropout-0 encoder
    // artifacts exist for numerical cross-checks, not for Fig 12.
    let mut metas: Vec<ArtifactMeta> = eng.manifest().of_kind("encoder_fwd")
        .filter(|m| (m.attr_f64("dropout").unwrap_or(0.0) * 100.0) as i64
                == 10)
        .cloned().collect();
    if metas.is_empty() {
        metas = eng.manifest().of_kind("encoder_fwd").cloned().collect();
    }
    for meta in metas {
        let d_head = meta.attr_i64("d_head").unwrap_or(0);
        let group = format!("head-dim {d_head}");
        let impl_name = meta.attr_str("impl").unwrap_or("?").to_string();
        let variant = match impl_name.as_str() {
            "unfused" => "pytorch_jit".to_string(),
            "fused" => "sparkattention".to_string(),
            "fully_fused" => "fastertransformer*".to_string(),
            other => other.to_string(),
        };
        let n = meta.attr_i64("n").unwrap_or(0) as usize;
        // unfused attention inside the encoder pays the N×N residency
        let fused = impl_name != "unfused";
        let bh = meta.attr_i64("batch").unwrap_or(1) as usize
            * meta.attr_i64("num_heads").unwrap_or(1) as usize;
        let peak = iomodel::peak_resident_bytes(
            MhaShape::new(bh, n, d_head as usize), fused);
        if peak > opts.mem_budget {
            report.push(skipped_row(&group, &variant, n, "oom"));
            continue;
        }
        let ins = synth_inputs(&meta, 42)?;
        let time = measure(opts.bench, || {
            Ok(eng.execute_timed(&meta.name, &ins)?.1)
        }).with_context(|| format!("benching {}", meta.name))?;
        report.push(Row {
            group, variant, x: n, time,
            flops: meta.attr_i64("flops_attn").unwrap_or(0) as u64,
            status: "ok".into(),
        });
    }
    if let Some((mean, max)) =
        report.speedup_summary("sparkattention", "pytorch_jit") {
        info!("fig12: fused encoder vs PyTorch-JIT analog: avg {mean:.2}× \
               (max {max:.2}×)");
    }
    Ok(report)
}

/// One row of the §4.2.3 accuracy table.
#[derive(Debug, Clone)]
pub struct AccuracyRow {
    /// Artifact (or artifact/gradient) being scored.
    pub name: String,
    /// Mean relative error vs the f32 oracle.
    pub mean_rel_err: f64,
    /// Mean absolute error vs the f32 oracle.
    pub mean_abs_err: f64,
    /// Worst-case absolute error vs the f32 oracle.
    pub max_abs_err: f64,
}

/// §4.2.3: accuracy of each variant against the f32 oracle, on the
/// dropout-0 accuracy artifacts.
pub fn accuracy_report(eng: &Engine) -> Result<Vec<AccuracyRow>> {
    let mut rows = Vec::new();
    let fwd_metas: Vec<ArtifactMeta> = eng.manifest().of_kind("mha_fwd")
        .chain(eng.manifest().of_kind("mha_fwd_unf"))
        .filter(|m| m.attr_f64("dropout") == Some(0.0))
        .cloned().collect();
    for meta in fwd_metas {
        let ins = synth_inputs(&meta, 42)?;
        let out = eng.execute(&meta.name, &ins)?;
        let o_dev = out[0].as_tensor()?;
        let (q, k, v) = (ins[1].as_tensor()?, ins[2].as_tensor()?,
                         ins[3].as_tensor()?);
        let d = meta.attr_i64("d").unwrap_or(64) as usize;
        let causal = meta.attr_bool("causal").unwrap_or(false);
        let p = AttnParams::new(d, causal)?;
        let oracle = attention::mha_forward(&q, &k, &v, &p, &Scalar).output;
        rows.push(accuracy_row(&meta.name, &o_dev, &oracle));
    }

    // Backward accuracy: fused bwd artifacts vs the Rust backward oracle.
    let bwd_metas: Vec<ArtifactMeta> = eng.manifest().of_kind("mha_bwd")
        .filter(|m| m.attr_f64("dropout") == Some(0.0))
        .cloned().collect();
    for meta in bwd_metas {
        let ins = prepare_inputs(eng, &meta)?;
        let out = eng.execute(&meta.name, &ins)?;
        let (q, k, v) = (ins[1].as_tensor()?, ins[2].as_tensor()?,
                         ins[3].as_tensor()?);
        let dout = ins[6].as_tensor()?;
        let d = meta.attr_i64("d").unwrap_or(64) as usize;
        let causal = meta.attr_bool("causal").unwrap_or(false);
        let p = AttnParams::new(d, causal)?;
        let g = attention::mha_backward(&q, &k, &v, &dout, &p, &Scalar);
        for (i, (gname, oracle)) in [("dq", &g.dq), ("dk", &g.dk),
                                     ("dv", &g.dv)].iter().enumerate() {
            let dev = out[i].as_tensor()?;
            rows.push(accuracy_row(&format!("{}/{gname}", meta.name),
                                   &dev, oracle));
        }
    }
    Ok(rows)
}

fn accuracy_row(name: &str, dev: &Tensor, oracle: &Tensor) -> AccuracyRow {
    AccuracyRow {
        name: name.to_string(),
        mean_rel_err: dev.mean_rel_err(oracle, 1e-3) as f64,
        mean_abs_err: dev.mean_abs_diff(oracle) as f64,
        max_abs_err: dev.max_abs_diff(oracle) as f64,
    }
}

/// Render the accuracy table.
pub fn accuracy_table(rows: &[AccuracyRow]) -> String {
    let mut s = String::from(
        "== §4.2.3 accuracy vs f32 oracle ==\n");
    s.push_str(&format!("{:<48} {:>12} {:>12} {:>12}\n",
                        "artifact", "rel_err", "abs_err", "max_abs"));
    for r in rows {
        s.push_str(&format!("{:<48} {:>11.4}% {:>12.6} {:>12.6}\n",
                            r.name, r.mean_rel_err * 100.0, r.mean_abs_err,
                            r.max_abs_err));
    }
    s
}

/// E5: the §2.3 I/O table — analytic + simulated traffic per schedule.
pub fn io_report(machine: &Machine) -> String {
    let mut s = String::from(
        "== §2.3 / §3.2 HBM traffic (per MHA forward) ==\n");
    s.push_str(&format!(
        "{:>6} {:>5} | {:>10} {:>10} {:>7} | {:>10} {:>10} {:>7} | {:>6}\n",
        "n", "d", "unf_rd_MB", "unf_wr_MB", "5r/3w", "fus_rd_MB",
        "fus_wr_MB", "3r/1w", "ratio"));
    for d in [64usize, 128] {
        for n in [512usize, 1024, 2048, 4096, 16384] {
            let shape = perfmodel::paper_shape(n, d);
            let u = iomodel::analytic_unfused_fwd(shape);
            let (f, _) = iomodel::simulate_fused_fwd(shape, 128, 128,
                                                     16 << 20);
            let us = iomodel::simulate_unfused_fwd(shape, 128 * 1024);
            debug_assert_eq!(us.read_bytes, u.read_bytes);
            let mb = |b: usize| b as f64 / (1 << 20) as f64;
            s.push_str(&format!(
                "{:>6} {:>5} | {:>10.1} {:>10.1} {:>3}r/{}w | {:>10.1} \
                 {:>10.1} {:>3}r/{}w | {:>5.1}×\n",
                n, d, mb(u.read_bytes), mb(u.write_bytes), u.tensor_reads,
                u.tensor_writes, mb(f.read_bytes), mb(f.write_bytes),
                f.tensor_reads, f.tensor_writes,
                u.total_bytes() as f64 / f.total_bytes() as f64));
        }
    }
    s.push_str(&format!("\n(machine: {:.0} TFLOP/s TCU, {:.0} GB/s HBM, \
                         {} GiB)\n",
                        machine.matrix_flops / 1e12, machine.hbm_bw / 1e9,
                        machine.hbm_capacity >> 30));
    s
}

/// V100-projected Fig 12 at paper scale (hidden 2048, batch = 16384/n).
pub fn projected_fig12(machine: &Machine) -> Report {
    let mut report = Report::new(
        "Fig 12 (V100 projection) — Encoder-Forward at paper scale");
    for d_head in [64usize, 128] {
        let group = format!("head-dim {d_head}");
        for n in [512usize, 1024, 2048, 4096, 16384] {
            let (batch, dm, heads) = perfmodel::paper_encoder_point(n, d_head);
            for variant in ["pytorch_jit", "sparkattention",
                            "fastertransformer"] {
                let proj = perfmodel::project_encoder(
                    machine, batch, n, dm, heads, variant);
                if proj.bound == Bound::Oom {
                    report.push(skipped_row(&group, variant, n, "oom"));
                } else {
                    let mut time = crate::metrics::Series::default();
                    time.record(proj.seconds);
                    report.push(Row {
                        group: group.clone(),
                        variant: variant.into(),
                        x: n,
                        time,
                        flops: 0,
                        status: "ok".into(),
                    });
                }
            }
        }
    }
    report
}

/// Host-path backend comparison: run the pure-Rust attention path
/// (oracle dataflow and block-streamed dataflow) under every execution
/// backend of [`report_roster`] — by default the `Scalar` reference,
/// `Blocked`, and `Simd` in both numeric modes at the configured
/// thread count; just `Scalar` + the configured backend when pinned —
/// on the same inputs, and report them side by side as bench rows.
///
/// This is the artifact-free figure: it needs no `make artifacts`, so CI
/// and fresh checkouts always produce it.  Full-precision outputs are
/// cross-checked against the Scalar reference before timings are
/// accepted — a bench that silently drifts numerically is worse than no
/// bench.  The mixed-precision backend deviates *by design*, so instead
/// of a pass/fail gate its error against the f32 reference is recorded
/// as report notes (max ULP distance + max abs error, mirroring the
/// paper's §4.2.3 accuracy table), alongside per-backend speedup
/// summaries.
///
/// `masks` selects the structured-attention variants to sweep.  The
/// dense mask keeps the historical `host/d{d}` group (so trajectory
/// gates keyed on it stay comparable PR-over-PR); every other mask gets
/// its own `host/d{d}/{label}` group with the *same* variant names, and
/// its rows carry exact per-mask FLOPs so TFLOP/s stays honest when
/// skip-aware tiling removes work.
pub fn host_backend_report(ns: &[usize], bh: usize, d: usize,
                           backward: bool, masks: &[MaskSpec],
                           opts: HarnessOptions)
                           -> Result<Report> {
    let pass = if backward { "backward" } else { "forward" };
    let mut report = Report::new(format!(
        "Host MHA-{} — exec backends (bh={bh}, d={d})",
        if backward { "Backward" } else { "Forward" }));
    let backends = report_roster(opts);
    // surface an installed tuning table in the report: tuned runs are
    // labeled data, not silently-different numbers
    if let Some(table) = exec::tune::installed() {
        report.note("tuning_table entries (installed)",
                    table.len() as f64);
    }
    let block = 64usize;
    for &n in ns {
        let mut rng = Rng::new(0x5A11 + n as u64);
        let q = Tensor::randn(vec![bh, n, d], &mut rng);
        let k = Tensor::randn(vec![bh, n, d], &mut rng);
        let v = Tensor::randn(vec![bh, n, d], &mut rng);
        let dout = Tensor::randn(vec![bh, n, d], &mut rng);
        // largest block ≤ 64 that divides n (streaming requires n % bq == 0)
        let bq = (1..=block.min(n)).rev().find(|b| n % b == 0).unwrap_or(1);
        for spec in masks {
            let group = if *spec == MaskSpec::Dense {
                format!("host/d{d}")
            } else {
                format!("host/d{d}/{}", spec.label())
            };
            let p = AttnParams::with_mask(d, spec.build(n)?)?;
            let p = &p;
            let flops = attention::attention_flops_masked(
                bh, n, d, &p.mask, backward);
            // the pass under one backend, for cross-checking
            let run_pass = |be: &dyn Backend| -> Tensor {
                if backward {
                    let lse = attention::mha_forward(&q, &k, &v, p, be).lse;
                    attention::mha_backward(&q, &k, &v, &dout, p, be).dq
                        .add(&attention::mha_backward_streaming(
                            &q, &k, &v, &dout, &lse, p, bq, bq, be).dq)
                } else {
                    attention::mha_forward(&q, &k, &v, p, be).output
                }
            };
            // only needed when there is a second backend to cross-check
            let reference = if backends.len() > 1 {
                Some(run_pass(&Scalar))
            } else {
                None
            };
            for (bi, be) in backends.iter().enumerate() {
                let be = be.as_ref();
                let mixed = be.precision() == exec::Precision::Mixed;
                // Numeric cross-check before timing — skipped for the
                // Scalar entry, which *is* the reference.
                if bi > 0 {
                    let reference = reference.as_ref()
                        .expect("reference exists when roster > 1");
                    let check = run_pass(be);
                    let err = check.max_abs_diff(reference);
                    if mixed {
                        // deviates by design: record, don't gate
                        report.note(
                            format!("{} vs f32 max_ulp ({pass}, n={n}, \
                                     mask={})", be.name(), spec.label()),
                            check.max_ulp_diff(reference) as f64);
                        report.note(
                            format!("{} vs f32 max_abs ({pass}, n={n}, \
                                     mask={})", be.name(), spec.label()),
                            err as f64);
                    } else if err > 1e-4 {
                        bail!("backend {} disagrees with scalar on host \
                               {pass} (n={n}, mask={}, max err {err})",
                              be.name(), spec.label());
                    }
                }
                let time = if backward {
                    let lse = attention::mha_forward(&q, &k, &v, p, be).lse;
                    measure_wallclock(opts.bench, || {
                        attention::mha_backward_streaming(
                            &q, &k, &v, &dout, &lse, p, bq, bq, be);
                        Ok(())
                    })?
                } else {
                    measure_wallclock(opts.bench, || {
                        attention::mha_forward(&q, &k, &v, p, be);
                        Ok(())
                    })?
                };
                report.push(Row {
                    group: group.clone(),
                    variant: be.name(),
                    x: n,
                    time,
                    flops,
                    status: "ok".into(),
                });
                // the streamed (flash-dataflow) variant of the same pass
                if !backward {
                    let time = measure_wallclock(opts.bench, || {
                        attention::mha_forward_streaming(&q, &k, &v, p,
                                                         bq, bq, be);
                        Ok(())
                    })?;
                    report.push(Row {
                        group: group.clone(),
                        variant: format!("{}_stream", be.name()),
                        x: n,
                        time,
                        flops,
                        status: "ok".into(),
                    });
                }
            }
        }
    }
    for be in backends.iter().skip(1) {
        let name = be.name();
        if let Some((mean, max)) = report.speedup_summary(&name, "scalar") {
            report.note(format!("speedup {name} vs scalar (mean)"), mean);
            report.note(format!("speedup {name} vs scalar (max)"), max);
            info!("host {pass}: {name} vs scalar: avg {mean:.2}× \
                   (max {max:.2}×)");
        }
    }
    Ok(report)
}

/// V100-projected Fig 10/11 at paper scale (heads = 2048/d, batch =
/// 16384/n) — the magnitudes the CPU cannot produce.
pub fn projected_fig10(machine: &Machine, backward: bool) -> Report {
    let mut report = Report::new(if backward {
        "Fig 11 (V100 projection) — MHA-Backward at paper scale"
    } else {
        "Fig 10 (V100 projection) — MHA-Forward at paper scale"
    });
    for d in [64usize, 128] {
        for causal in [false, true] {
            let group = format!("d{d}{}", if causal { "/causal" }
                                else { "/full" });
            for n in [512usize, 1024, 2048, 4096, 16384] {
                let s = perfmodel::paper_shape(n, d);
                let (ours, base) = if backward {
                    (perfmodel::project_fused_bwd(machine, s, causal),
                     perfmodel::project_unfused_bwd(machine, s, causal))
                } else {
                    (perfmodel::project_fused_fwd(machine, s, causal, 128),
                     perfmodel::project_unfused_fwd(machine, s, causal))
                };
                let flops = attention::attention_flops(s.bh, s.n, s.d,
                                                       causal, backward);
                for (name, proj) in [("spark_projected", ours),
                                     ("pytorch_projected", base)] {
                    if proj.bound == Bound::Oom {
                        report.push(skipped_row(&group, name, n, "oom"));
                    } else {
                        let mut time = crate::metrics::Series::default();
                        time.record(proj.seconds);
                        report.push(Row {
                            group: group.clone(),
                            variant: name.into(),
                            x: n,
                            time,
                            flops,
                            status: "ok".into(),
                        });
                    }
                }
            }
        }
    }
    report
}
