//! The training loop: init → (batch → train_step artifact → metrics) → ckpt.
//!
//! All heavy math is inside the AOT `train_step` HLO (forward with the
//! fused SparkAttention kernels, backward via their recomputation VJP, and
//! the Adam update).  The coordinator owns state buffers, data, logging,
//! and checkpoints — the paper's Figure 5 integration with the framework
//! loop living in Rust instead of PyTorch.

use anyhow::{bail, Context, Result};
use log::info;

use super::checkpoint::Checkpoint;
use crate::config::TrainConfig;
use crate::data::{Batcher, ByteTokenizer, CorpusGenerator};
use crate::metrics::Registry;
use crate::runtime::{Engine, HostValue};

/// Summary of a finished run.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    /// Optimizer steps executed.
    pub steps: usize,
    /// Per-step loss history.
    pub losses: Vec<f64>,
    /// Tokens consumed per step (batch × seq).
    pub tokens_per_step: usize,
    /// Mean wallclock per step, seconds.
    pub mean_step_seconds: f64,
}

impl TrainOutcome {
    /// Loss at step 1 (NaN if no steps ran).
    pub fn first_loss(&self) -> f64 {
        self.losses.first().copied().unwrap_or(f64::NAN)
    }

    /// Loss at the final step (NaN if no steps ran).
    pub fn last_loss(&self) -> f64 {
        self.losses.last().copied().unwrap_or(f64::NAN)
    }

    /// Mean of the final k losses (noise-robust convergence check).
    pub fn tail_mean(&self, k: usize) -> f64 {
        let k = k.min(self.losses.len()).max(1);
        let tail = &self.losses[self.losses.len() - k..];
        tail.iter().sum::<f64>() / k as f64
    }
}

/// LM trainer bound to an engine + config.
pub struct Trainer<'e> {
    engine: &'e Engine,
    cfg: TrainConfig,
    /// Run-time counters/timings, dumped by `--metrics-out`.
    pub metrics: Registry,
}

impl<'e> Trainer<'e> {
    /// Trainer bound to an engine and a validated config.
    pub fn new(engine: &'e Engine, cfg: TrainConfig) -> Self {
        Trainer { engine, cfg, metrics: Registry::new() }
    }

    /// Run the configured number of steps; returns the loss history.
    pub fn run(&mut self) -> Result<TrainOutcome> {
        let init_meta = self.engine.manifest().get("lm_init")?.clone();
        let step_meta = self.engine.manifest().get("train_step")?.clone();
        let batch = step_meta.attr_i64("batch")
            .context("train_step missing batch attr")? as usize;
        let seq = step_meta.attr_i64("seq")
            .context("train_step missing seq attr")? as usize;
        let n_state = init_meta.outputs.len(); // params + m + v leaves
        let n_params = n_state / 3;
        if step_meta.inputs.len() != n_state + 3 {
            bail!("train_step expects {} inputs, init provides {} state \
                   buffers (+step/tokens/seed)",
                  step_meta.inputs.len(), n_state);
        }

        info!("initializing {} params ({} leaves) via lm_init",
              step_meta.attr_i64("param_count").unwrap_or(0), n_params);
        let mut state = self.engine.execute(
            "lm_init", &[HostValue::scalar_u32(self.cfg.seed as u32)])?;

        info!("synthesizing corpus: {} tokens, zipf {}",
              self.cfg.corpus_tokens, self.cfg.corpus_zipf);
        let gen = CorpusGenerator { zipf: self.cfg.corpus_zipf,
                                    ..CorpusGenerator::default() };
        let text = gen.generate(self.cfg.corpus_tokens, self.cfg.seed);
        let tokens = ByteTokenizer::new().encode(&text);
        let mut batcher = Batcher::new(tokens, batch, seq, self.cfg.seed);
        info!("corpus ready: {} batches/epoch", batcher.batches_per_epoch());

        let mut losses = Vec::with_capacity(self.cfg.steps);
        let t_run = std::time::Instant::now();
        for step in 0..self.cfg.steps {
            let toks = batcher.next_batch();
            let mut inputs = Vec::with_capacity(state.len() + 3);
            inputs.append(&mut state);
            inputs.push(HostValue::scalar_f32((step + 1) as f32));
            inputs.push(HostValue::I32 {
                shape: vec![batch, seq + 1],
                data: toks,
            });
            inputs.push(HostValue::scalar_f32(
                self.cfg.seed as f32 + step as f32));

            let (mut out, secs) = self.engine
                .execute_timed("train_step", &inputs)
                .with_context(|| format!("train step {step}"))?;
            let loss = out.pop().context("train_step returned no loss")?;
            let loss = loss.as_f32_slice()?[0] as f64;
            if !loss.is_finite() {
                bail!("loss diverged to {loss} at step {step}");
            }
            losses.push(loss);
            state = out;

            self.metrics.time("train_step", secs);
            self.metrics.inc("steps", 1);
            self.metrics.set_gauge("loss", loss);
            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                info!("step {step:4}  loss {loss:.4}  ({:.0} ms)",
                      secs * 1e3);
            }
            if self.cfg.checkpoint_every > 0
                && (step + 1) % self.cfg.checkpoint_every == 0 {
                self.save_checkpoint(&step_meta, &state, step + 1, loss)?;
            }
        }
        let wall = t_run.elapsed().as_secs_f64();
        let outcome = TrainOutcome {
            steps: self.cfg.steps,
            tokens_per_step: batch * seq,
            mean_step_seconds: wall / self.cfg.steps.max(1) as f64,
            losses,
        };
        info!("done: loss {:.4} → {:.4} over {} steps ({:.2} s/step, \
               {:.0} tok/s)",
              outcome.first_loss(), outcome.last_loss(), outcome.steps,
              outcome.mean_step_seconds,
              outcome.tokens_per_step as f64 / outcome.mean_step_seconds);
        Ok(outcome)
    }

    fn save_checkpoint(&self, step_meta: &crate::runtime::ArtifactMeta,
                       state: &[HostValue], step: usize, loss: f64)
                       -> Result<()> {
        std::fs::create_dir_all(&self.cfg.checkpoint_dir)?;
        let names = step_meta.inputs.iter().take(state.len())
            .map(|s| s.name.clone());
        let ck = Checkpoint {
            step,
            loss,
            buffers: names.zip(state.iter().cloned()).collect(),
        };
        let path = format!("{}/step{:06}.ckpt", self.cfg.checkpoint_dir, step);
        ck.save(&path)?;
        info!("checkpoint → {path}");
        Ok(())
    }
}
