//! The training loop: init → (batch → train_step artifact → metrics) → ckpt.
//!
//! All heavy math is inside the AOT `train_step` HLO (forward with the
//! fused SparkAttention kernels, backward via their recomputation VJP, and
//! the Adam update).  The coordinator owns state buffers, data, logging,
//! and checkpoints — the paper's Figure 5 integration with the framework
//! loop living in Rust instead of PyTorch.

use anyhow::{bail, Context, Result};
use log::info;

use super::checkpoint::Checkpoint;
use crate::config::TrainConfig;
use crate::data::{Batcher, ByteTokenizer, CorpusGenerator};
use crate::metrics::Registry;
use crate::runtime::{Engine, HostValue};

/// Summary of a finished run.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    /// Optimizer steps executed.
    pub steps: usize,
    /// Per-step loss history.
    pub losses: Vec<f64>,
    /// Tokens consumed per step (batch × seq).
    pub tokens_per_step: usize,
    /// Mean wallclock per step, seconds.
    pub mean_step_seconds: f64,
}

impl TrainOutcome {
    /// Loss at step 1 (NaN if no steps ran).
    pub fn first_loss(&self) -> f64 {
        self.losses.first().copied().unwrap_or(f64::NAN)
    }

    /// Loss at the final step (NaN if no steps ran).
    pub fn last_loss(&self) -> f64 {
        self.losses.last().copied().unwrap_or(f64::NAN)
    }

    /// Mean of the final k losses (noise-robust convergence check).
    /// NaN if no steps ran, matching `first_loss`/`last_loss` — the old
    /// `k.max(1)` clamp underflowed `len - k` on an empty history and
    /// panicked instead of reporting "no data".
    pub fn tail_mean(&self, k: usize) -> f64 {
        if self.losses.is_empty() {
            return f64::NAN;
        }
        let k = k.min(self.losses.len()).max(1);
        let tail = &self.losses[self.losses.len() - k..];
        tail.iter().sum::<f64>() / k as f64
    }
}

/// Build the outcome summary from the pieces `run` collected.
///
/// `mean_step_seconds` comes from the `train_step` timing series — the
/// per-step `execute_timed` durations — never from total run wallclock:
/// the run loop also synthesizes batches and saves checkpoints, so
/// `wall / steps` overstates step time whenever `checkpoint_every > 0`
/// (the bug this replaces).  With checkpointing off the two estimates
/// must agree: each timed sample is contained in its loop iteration, so
/// the series mean can never exceed the wall-derived mean; we assert
/// that containment here as a cheap cross-check of the timing plumbing.
fn assemble_outcome(steps: usize, tokens_per_step: usize,
                    losses: Vec<f64>, wall_seconds: f64,
                    metrics: &Registry, checkpointing: bool)
                    -> TrainOutcome {
    let mean_step_seconds = metrics.series("train_step")
        .map(|s| s.mean())
        .unwrap_or(f64::NAN);
    if !checkpointing && steps > 0 {
        let wall_mean = wall_seconds / steps as f64;
        assert!(mean_step_seconds <= wall_mean + 1e-6,
                "train_step series mean {mean_step_seconds}s exceeds \
                 wall-derived mean {wall_mean}s with checkpointing off; \
                 timing samples overlap their loop iterations");
    }
    TrainOutcome { steps, losses, tokens_per_step, mean_step_seconds }
}

/// LM trainer bound to an engine + config.
pub struct Trainer<'e> {
    engine: &'e Engine,
    cfg: TrainConfig,
    /// Run-time counters/timings, dumped by `--metrics-out`.
    pub metrics: Registry,
}

impl<'e> Trainer<'e> {
    /// Trainer bound to an engine and a validated config.
    pub fn new(engine: &'e Engine, cfg: TrainConfig) -> Self {
        Trainer { engine, cfg, metrics: Registry::new() }
    }

    /// Run the configured number of steps; returns the loss history.
    pub fn run(&mut self) -> Result<TrainOutcome> {
        let init_meta = self.engine.manifest().get("lm_init")?.clone();
        let step_meta = self.engine.manifest().get("train_step")?.clone();
        let batch = step_meta.attr_i64("batch")
            .context("train_step missing batch attr")? as usize;
        let seq = step_meta.attr_i64("seq")
            .context("train_step missing seq attr")? as usize;
        let n_state = init_meta.outputs.len(); // params + m + v leaves
        let n_params = n_state / 3;
        if step_meta.inputs.len() != n_state + 3 {
            bail!("train_step expects {} inputs, init provides {} state \
                   buffers (+step/tokens/seed)",
                  step_meta.inputs.len(), n_state);
        }

        info!("initializing {} params ({} leaves) via lm_init",
              step_meta.attr_i64("param_count").unwrap_or(0), n_params);
        let mut state = self.engine.execute(
            "lm_init", &[HostValue::scalar_u32(self.cfg.seed as u32)])?;

        info!("synthesizing corpus: {} tokens, zipf {}",
              self.cfg.corpus_tokens, self.cfg.corpus_zipf);
        let gen = CorpusGenerator { zipf: self.cfg.corpus_zipf,
                                    ..CorpusGenerator::default() };
        let text = gen.generate(self.cfg.corpus_tokens, self.cfg.seed);
        let tokens = ByteTokenizer::new().encode(&text);
        let mut batcher = Batcher::new(tokens, batch, seq, self.cfg.seed);
        info!("corpus ready: {} batches/epoch", batcher.batches_per_epoch());

        let mut losses = Vec::with_capacity(self.cfg.steps);
        let t_run = std::time::Instant::now();
        for step in 0..self.cfg.steps {
            let toks = batcher.next_batch();
            let mut inputs = Vec::with_capacity(state.len() + 3);
            inputs.append(&mut state);
            inputs.push(HostValue::scalar_f32((step + 1) as f32));
            inputs.push(HostValue::I32 {
                shape: vec![batch, seq + 1],
                data: toks,
            });
            inputs.push(HostValue::scalar_f32(
                self.cfg.seed as f32 + step as f32));

            let (mut out, secs) = self.engine
                .execute_timed("train_step", &inputs)
                .with_context(|| format!("train step {step}"))?;
            let loss = out.pop().context("train_step returned no loss")?;
            let loss = loss.as_f32_slice()?[0] as f64;
            if !loss.is_finite() {
                bail!("loss diverged to {loss} at step {step}");
            }
            losses.push(loss);
            state = out;

            self.metrics.time("train_step", secs);
            self.metrics.inc("steps", 1);
            self.metrics.set_gauge("loss", loss);
            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                info!("step {step:4}  loss {loss:.4}  ({:.0} ms)",
                      secs * 1e3);
            }
            if self.cfg.checkpoint_every > 0
                && (step + 1) % self.cfg.checkpoint_every == 0 {
                self.save_checkpoint(&step_meta, &state, step + 1, loss)?;
            }
        }
        let wall = t_run.elapsed().as_secs_f64();
        let outcome = assemble_outcome(
            self.cfg.steps, batch * seq, losses, wall, &self.metrics,
            self.cfg.checkpoint_every > 0);
        info!("done: loss {:.4} → {:.4} over {} steps ({:.2} s/step, \
               {:.0} tok/s)",
              outcome.first_loss(), outcome.last_loss(), outcome.steps,
              outcome.mean_step_seconds,
              outcome.tokens_per_step as f64 / outcome.mean_step_seconds);
        Ok(outcome)
    }

    fn save_checkpoint(&self, step_meta: &crate::runtime::ArtifactMeta,
                       state: &[HostValue], step: usize, loss: f64)
                       -> Result<()> {
        std::fs::create_dir_all(&self.cfg.checkpoint_dir)?;
        let names = step_meta.inputs.iter().take(state.len())
            .map(|s| s.name.clone());
        let ck = Checkpoint {
            step,
            loss,
            buffers: names.zip(state.iter().cloned()).collect(),
        };
        let path = format!("{}/step{:06}.ckpt", self.cfg.checkpoint_dir, step);
        ck.save(&path)?;
        info!("checkpoint → {path}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome_with_losses(losses: Vec<f64>) -> TrainOutcome {
        TrainOutcome {
            steps: losses.len(),
            losses,
            tokens_per_step: 0,
            mean_step_seconds: f64::NAN,
        }
    }

    // Regression: `tail_mean` on a zero-step run used to clamp k to 1
    // and index `losses[0 - 1..]` — a usize underflow panic.  It must
    // report NaN like `first_loss`/`last_loss`.
    #[test]
    fn tail_mean_of_zero_steps_is_nan() {
        let o = outcome_with_losses(vec![]);
        assert!(o.tail_mean(5).is_nan());
        assert!(o.tail_mean(0).is_nan());
        assert!(o.first_loss().is_nan());
        assert!(o.last_loss().is_nan());
    }

    #[test]
    fn tail_mean_on_short_and_long_tails() {
        let o = outcome_with_losses(vec![4.0, 3.0, 2.0, 1.0]);
        assert_eq!(o.tail_mean(2), 1.5);
        // k larger than the history clamps to the whole history.
        assert_eq!(o.tail_mean(100), 2.5);
        // k = 0 clamps to the final loss.
        assert_eq!(o.tail_mean(0), 1.0);
    }

    // Regression: `mean_step_seconds` used to be wall / steps, so any
    // time the loop spent outside `execute_timed` — checkpoint saves,
    // batch assembly — inflated the reported step time.  It must come
    // from the `train_step` series.
    #[test]
    fn mean_step_seconds_ignores_checkpoint_time() {
        let mut m = Registry::new();
        for _ in 0..10 {
            m.time("train_step", 0.1);
        }
        // Wall includes 4 s of simulated checkpoint saves on top of the
        // 1 s of stepping; the old computation reported 0.5 s/step.
        let o = assemble_outcome(10, 64, vec![1.0; 10], 5.0, &m, true);
        assert!((o.mean_step_seconds - 0.1).abs() < 1e-12);
    }

    #[test]
    fn mean_step_seconds_agrees_with_wall_when_not_checkpointing() {
        let mut m = Registry::new();
        for _ in 0..10 {
            m.time("train_step", 0.1);
        }
        // Checkpointing off: wall ≈ series total plus loop overhead, and
        // assemble_outcome asserts series mean ≤ wall mean internally.
        let o = assemble_outcome(10, 64, vec![1.0; 10], 1.02, &m, false);
        assert!((o.mean_step_seconds - 0.1).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn overlapping_timings_trip_the_agreement_assert() {
        let mut m = Registry::new();
        for _ in 0..10 {
            m.time("train_step", 0.5);
        }
        // Series claims 5 s of stepping inside a 1 s wall with no
        // checkpointing — impossible unless samples overlap.
        let _ = assemble_outcome(10, 64, vec![1.0; 10], 1.0, &m, false);
    }

    #[test]
    fn zero_step_outcome_is_nan_not_zero() {
        let m = Registry::new();
        let o = assemble_outcome(0, 64, vec![], 0.5, &m, false);
        assert!(o.mean_step_seconds.is_nan());
    }
}
