//! Deterministic input synthesis for artifacts, driven by manifest specs.
//!
//! Benchmarks and tests need *valid* inputs with the right shapes/dtypes;
//! values are standard-normal (bf16-quantised where the artifact expects
//! bf16) from a fixed seed, so every run of every figure is reproducible.

use anyhow::{bail, Result};

use crate::runtime::{ArtifactMeta, DType, HostValue, TensorSpec};
use crate::tensor::{bf16, Rng};

/// Synthesise one input value for a spec.
pub fn synth(spec: &TensorSpec, rng: &mut Rng) -> Result<HostValue> {
    let n = spec.element_count();
    Ok(match spec.dtype {
        DType::Bf16 => HostValue::F32 {
            shape: spec.shape.clone(),
            data: rng.normal_vec(n).into_iter().map(bf16::quantize).collect(),
        },
        DType::F32 | DType::F64 => HostValue::F32 {
            shape: spec.shape.clone(),
            data: rng.normal_vec(n),
        },
        DType::S32 => HostValue::I32 {
            shape: spec.shape.clone(),
            // token-ish payload: byte vocab
            data: (0..n).map(|_| rng.below(256) as i32).collect(),
        },
        DType::U32 => HostValue::U32 {
            shape: spec.shape.clone(),
            data: (0..n).map(|_| rng.next_u64() as u32).collect(),
        },
        DType::Pred => bail!("pred inputs not supported"),
    })
}

/// Full input set for an artifact; special-cases the conventional scalar
/// names (`seed` → 0.0, `step` → 1.0) so semantics stay valid.
pub fn synth_inputs(meta: &ArtifactMeta, seed: u64) -> Result<Vec<HostValue>> {
    let mut rng = Rng::new(seed);
    meta.inputs.iter().map(|spec| {
        match (spec.name.as_str(), spec.dtype) {
            ("seed", DType::F32) => Ok(HostValue::scalar_f32(seed as f32)),
            ("seed", DType::U32) => Ok(HostValue::scalar_u32(seed as u32)),
            ("step", DType::F32) => Ok(HostValue::scalar_f32(1.0)),
            _ => synth(spec, &mut rng),
        }
    }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(shape: &[usize], dtype: DType) -> TensorSpec {
        TensorSpec { name: "x".into(), shape: shape.to_vec(), dtype }
    }

    #[test]
    fn bf16_inputs_prequantized() {
        let mut rng = Rng::new(1);
        let hv = synth(&spec(&[4, 4], DType::Bf16), &mut rng).unwrap();
        for &x in hv.as_f32_slice().unwrap() {
            assert_eq!(x, bf16::quantize(x));
        }
    }

    #[test]
    fn token_inputs_in_vocab() {
        let mut rng = Rng::new(2);
        let hv = synth(&spec(&[8, 9], DType::S32), &mut rng).unwrap();
        match hv {
            HostValue::I32 { data, .. } => {
                assert!(data.iter().all(|&t| (0..256).contains(&t)));
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn deterministic() {
        let mut a = Rng::new(3);
        let mut b = Rng::new(3);
        assert_eq!(synth(&spec(&[16], DType::F32), &mut a).unwrap(),
                   synth(&spec(&[16], DType::F32), &mut b).unwrap());
    }
}
