//! Flat-buffer checkpointing for parameters + optimizer state.
//!
//! Format: a JSON header line (names, shapes, step, loss) followed by the
//! concatenated little-endian f32 payloads in header order.  Self-describing
//! enough to resume training or inspect offline, with no serde dependency.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::jsonio::{self, Value};
use crate::runtime::HostValue;

/// Saved training state.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Step the state was captured at.
    pub step: usize,
    /// Loss at that step.
    pub loss: f64,
    /// (name, value) in artifact input order.
    pub buffers: Vec<(String, HostValue)>,
}

impl Checkpoint {
    /// Write header + payload to `path` (see module docs for format).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut header_entries = Vec::new();
        let mut payload: Vec<u8> = Vec::new();
        for (name, hv) in &self.buffers {
            let data = hv.as_f32_slice().with_context(
                || format!("checkpoint buffer {name} must be f32"))?;
            header_entries.push(jsonio::obj(vec![
                ("name", jsonio::s(name.clone())),
                ("shape", Value::Arr(hv.shape().iter()
                    .map(|&d| jsonio::num(d as f64)).collect())),
                ("offset", jsonio::num(payload.len() as f64)),
            ]));
            for x in data {
                payload.extend_from_slice(&x.to_le_bytes());
            }
        }
        let header = jsonio::obj(vec![
            ("magic", jsonio::s("spark-ckpt-v1")),
            ("step", jsonio::num(self.step as f64)),
            ("loss", jsonio::num(self.loss)),
            ("buffers", Value::Arr(header_entries)),
        ]);
        let mut f = std::fs::File::create(path.as_ref()).with_context(
            || format!("creating checkpoint {}", path.as_ref().display()))?;
        let htext = jsonio::to_string(&header);
        writeln!(f, "{htext}")?;
        f.write_all(&payload)?;
        Ok(())
    }

    /// Read and validate a checkpoint written by [`Checkpoint::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path.as_ref()).with_context(
            || format!("opening checkpoint {}", path.as_ref().display()))?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        let nl = bytes.iter().position(|&b| b == b'\n')
            .context("checkpoint missing header line")?;
        let header = jsonio::parse(std::str::from_utf8(&bytes[..nl])?)
            .context("parsing checkpoint header")?;
        if header.get("magic").and_then(Value::as_str)
            != Some("spark-ckpt-v1") {
            bail!("not a spark checkpoint");
        }
        let payload = &bytes[nl + 1..];
        let step = header.get("step").and_then(Value::as_usize)
            .context("header missing step")?;
        let loss = header.get("loss").and_then(Value::as_f64).unwrap_or(0.0);
        let mut buffers = Vec::new();
        for e in header.get("buffers").and_then(Value::as_arr)
            .context("header missing buffers")? {
            let name = e.get("name").and_then(Value::as_str)
                .context("buffer missing name")?.to_string();
            let shape: Vec<usize> = e.get("shape").and_then(Value::as_arr)
                .context("buffer missing shape")?
                .iter().filter_map(Value::as_usize).collect();
            let offset = e.get("offset").and_then(Value::as_usize)
                .context("buffer missing offset")?;
            let count: usize = shape.iter().product();
            let end = offset + 4 * count;
            if end > payload.len() {
                bail!("checkpoint truncated: {name} wants bytes {offset}..{end}, \
                       payload has {}", payload.len());
            }
            let data = payload[offset..end].chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            buffers.push((name, HostValue::F32 { shape, data }));
        }
        Ok(Checkpoint { step, loss, buffers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("spark-ckpt-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let ck = Checkpoint {
            step: 42,
            loss: 2.5,
            buffers: vec![
                ("p/w".into(), HostValue::F32 {
                    shape: vec![2, 3],
                    data: vec![1.0, -2.0, 0.5, 3.25, 0.0, -0.125],
                }),
                ("m/w".into(), HostValue::F32 {
                    shape: vec![3],
                    data: vec![0.1, 0.2, 0.3],
                }),
            ],
        };
        let p = tmpfile("roundtrip.ckpt");
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.loss, 2.5);
        assert_eq!(back.buffers.len(), 2);
        assert_eq!(back.buffers[0].1, ck.buffers[0].1);
        assert_eq!(back.buffers[1].0, "m/w");
    }

    #[test]
    fn rejects_garbage() {
        let p = tmpfile("garbage.ckpt");
        std::fs::write(&p, b"{\"magic\":\"nope\"}\nxxxx").unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let ck = Checkpoint {
            step: 1,
            loss: 0.0,
            buffers: vec![("w".into(), HostValue::F32 {
                shape: vec![8], data: vec![0.0; 8],
            })],
        };
        let p = tmpfile("trunc.ckpt");
        ck.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 8]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }
}
