//! Layer-3 coordinator: the run-time system driving the AOT artifacts.
//!
//! * `trainer` — the LM training loop (init → step → checkpoint), backed by
//!   the `train_step` artifact; Python never runs here.
//! * `checkpoint` — flat-buffer checkpoint save/load for params/opt state.
//! * `harness` — the evaluation harness regenerating every paper figure
//!   (Fig 10/11/12, the §4.2.3 accuracy table, the §2.3 I/O claim) from
//!   the artifact set + the analytic models.
//! * `inputs` — deterministic artifact input synthesis from manifest specs.
//! * `serve` — the `spark serve` inference path: continuous-batching
//!   scheduler + paged KV-cache + line-JSON TCP front-end.

pub mod checkpoint;
pub mod harness;
pub mod inputs;
pub mod serve;
pub mod trainer;

pub use harness::{accuracy_report, fig10_forward, fig11_backward,
                  fig12_e2e, host_backend_report, io_report,
                  projected_fig10, projected_fig12, report_roster};
pub use serve::{Request, Response, Scheduler, ServeConfig, TcpServer};
pub use trainer::{TrainOutcome, Trainer};
