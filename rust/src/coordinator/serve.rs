//! `spark serve`: continuous-batching inference over the paged KV-cache.
//!
//! The serving layer is three pieces:
//!
//! * [`Scheduler`] — the deterministic core.  Requests carry an
//!   *arrival ticket* assigned at submission; every scheduling decision
//!   (admission order, eviction victim, retirement) is a pure function
//!   of ticket order and cache occupancy — never of wall-clock time,
//!   which is used only to *report* latency.  Each [`Scheduler::step`]
//!   is one decode step for the whole running batch: retire finished
//!   sequences, admit from the queue up to `max_batch`, append one
//!   K/V row per sequence into the paged cache (evicting under
//!   pressure), then decode every appended row in parallel on the
//!   exec backend.
//! * [`crate::tensor::paged::KvCache`] — fixed-size blocks from one
//!   arena
//!   with LIFO free-list reuse, so block placement is reproducible.
//! * [`crate::attention::decode_step`] — the `bq = 1` streaming-attention
//!   kernel over the cached blocks; bitwise-identical to the full
//!   streaming forward (see its module docs), which is what makes the
//!   core serving property testable: **a request's output fingerprint
//!   is independent of batching** — the same request alone, batched,
//!   or evicted-and-retried produces bit-identical decode outputs.
//!
//! **Continuous batching.**  New arrivals join the running batch at
//! step boundaries; finished sequences retire immediately, freeing
//! their blocks for the same step's admissions.  Under cache pressure
//! the *youngest* arrival is evicted (released, fingerprint reset,
//! requeued at the queue front), so the oldest running request always
//! makes progress — combined with the config guarantee that a lone
//! sequence always fits (`ceil(max_gen_len / block_tokens) ≤
//! pool_blocks`), every admitted request terminates.  Evicted requests
//! restart from step 0; their synthetic rows are a pure function of
//! `(seed, step)`, so the recomputation is bitwise identical.
//!
//! **Workload.**  Requests are synthetic decode streams: step `s` of a
//! request with seed `σ` derives its query and K/V rows from
//! `Rng::new(σ).fork(s)`.  This models the memory/scheduling behaviour
//! of real decoding (the paper's host attention path per token) while
//! keeping every byte reproducible — the same property the trainer's
//! synthetic corpus relies on.
//!
//! The TCP front-end ([`TcpServer`]) speaks line-delimited JSON and
//! exists so a load generator (`spark load`) can drive thousands of
//! concurrent requests through a real socket; it assigns tickets in
//! inbox drain order, after which everything is the deterministic core.

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};
use log::{info, warn};

use crate::attention::{decode_step, AttnParams, MaskSpec};
use crate::exec::{self, Backend, ExecOptions, Precision, Task};
use crate::jsonio;
use crate::metrics::Registry;
use crate::tensor::paged::{CacheFull, KvCache, SeqKv};
use crate::tensor::Rng;

/// FNV-1a offset basis: the initial per-request output fingerprint.
const FP_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// One FNV-1a fold of a 32-bit word into a fingerprint.
fn fp_fold(h: u64, bits: u32) -> u64 {
    (h ^ bits as u64).wrapping_mul(0x0000_0100_0000_01B3)
}

/// Serving configuration (dimensions, cache sizing, batching policy).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Attention heads per request.
    pub heads: usize,
    /// Head dimension.
    pub d: usize,
    /// Tokens per KV-cache block.
    pub block_tokens: usize,
    /// Total blocks in the cache pool.
    pub pool_blocks: usize,
    /// Maximum sequences decoding concurrently.
    pub max_batch: usize,
    /// Upper bound on a request's `gen_len` (also the sequence length
    /// the mask is instantiated for).
    pub max_gen_len: usize,
    /// Attention mask applied to every request.
    pub mask: MaskSpec,
    /// Exec backend running the parallel decode tasks.
    pub exec: ExecOptions,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            heads: 4,
            d: 32,
            block_tokens: 16,
            pool_blocks: 64,
            max_batch: 8,
            max_gen_len: 64,
            mask: MaskSpec::Causal,
            exec: ExecOptions::default(),
        }
    }
}

impl ServeConfig {
    /// Reject configurations that cannot serve: zero dimensions, an
    /// exec combination the backends refuse, a mask that cannot cover
    /// `max_gen_len`, or — the liveness-critical one — a pool too
    /// small for a *lone* maximum-length sequence.  Eviction frees
    /// other sequences' blocks, so the sole-sequence bound is exactly
    /// what guarantees the oldest request always finishes.
    pub fn validate(&self) -> Result<()> {
        if self.heads == 0 || self.d == 0 || self.block_tokens == 0
            || self.pool_blocks == 0 || self.max_batch == 0
            || self.max_gen_len == 0
        {
            bail!("serve config dimensions must all be ≥ 1 (heads={} \
                   d={} block_tokens={} pool_blocks={} max_batch={} \
                   max_gen_len={})",
                  self.heads, self.d, self.block_tokens,
                  self.pool_blocks, self.max_batch, self.max_gen_len);
        }
        let need = self.max_gen_len.div_ceil(self.block_tokens);
        if need > self.pool_blocks {
            bail!("cache pool too small: a lone max-length sequence \
                   needs {need} blocks (max_gen_len={} / \
                   block_tokens={}) but the pool has {} — no eviction \
                   policy can make such a request finish",
                  self.max_gen_len, self.block_tokens,
                  self.pool_blocks);
        }
        self.exec.validate()?;
        self.mask.build(self.max_gen_len).context(
            "serve mask must instantiate at max_gen_len")?;
        Ok(())
    }
}

/// One inference request: `gen_len` synthetic decode steps whose rows
/// derive from `seed` (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Caller-chosen id, echoed in the [`Response`].
    pub id: u64,
    /// Seed of the synthetic token stream.
    pub seed: u64,
    /// Decode steps to run (must be `1..=max_gen_len`).
    pub gen_len: usize,
}

/// A completed request.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    /// The request's `id`.
    pub id: u64,
    /// The arrival ticket the scheduler assigned at submission.
    pub ticket: u64,
    /// FNV-1a fold of every decode output and LSE bit the request
    /// produced, in step order — the batching-independent identity of
    /// the computation.
    pub fingerprint: u64,
    /// Decode steps executed (== `gen_len`).
    pub steps: usize,
    /// Times this request was evicted and restarted.
    pub evictions: u64,
    /// Submission-to-completion wall time, seconds (reporting only —
    /// never consulted by scheduling).
    pub latency_s: f64,
}

/// Synthetic rows for step `step` of a request seeded `seed`: the
/// flattened `(heads·d)` query, key, and value rows, in that order.
/// Pure in `(seed, step, width)` — an evicted request regenerates
/// byte-identical rows on retry.
pub fn synth_rows(seed: u64, step: usize, width: usize)
                  -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed).fork(step as u64);
    (rng.normal_vec(width), rng.normal_vec(width),
     rng.normal_vec(width))
}

/// A submitted request the scheduler is tracking (queued or running).
#[derive(Debug)]
struct Active {
    req: Request,
    ticket: u64,
    seq: SeqKv,
    step: usize,
    fingerprint: u64,
    evictions: u64,
    submitted: Instant,
}

/// The continuous-batching scheduler (see the module docs).
pub struct Scheduler {
    cfg: ServeConfig,
    params: AttnParams,
    backend: Box<dyn Backend>,
    cache: KvCache,
    /// Waiting requests in arrival order.  Invariant: every queued
    /// ticket is greater than every running ticket *except* evicted
    /// requeues, which are pushed to the front — preserving global
    /// ascending ticket order across `running ++ queue`.
    queue: VecDeque<Active>,
    /// Running batch, ascending ticket order (admission appends,
    /// eviction pops the back, retirement removes anywhere).
    running: Vec<Active>,
    next_ticket: u64,
    /// Serving metrics: `request_latency` / `serve_step` series,
    /// admission/eviction/completion counters, occupancy gauges.
    pub metrics: Registry,
}

impl Scheduler {
    /// Build a scheduler from a validated config.
    pub fn new(cfg: ServeConfig) -> Result<Self> {
        cfg.validate()?;
        let mask = cfg.mask.build(cfg.max_gen_len)?;
        let params = AttnParams::with_mask(cfg.d, mask)?;
        let backend = cfg.exec.build();
        let cache = KvCache::new(cfg.pool_blocks, cfg.block_tokens,
                                 cfg.heads, cfg.d);
        Ok(Scheduler {
            cfg,
            params,
            backend,
            cache,
            queue: VecDeque::new(),
            running: Vec::new(),
            next_ticket: 0,
            metrics: Registry::new(),
        })
    }

    /// The configuration this scheduler was built from.
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Blocks currently free in the cache pool.
    pub fn free_blocks(&self) -> usize {
        self.cache.free_blocks()
    }

    /// Total blocks in the cache pool.
    pub fn capacity_blocks(&self) -> usize {
        self.cache.capacity_blocks()
    }

    /// Whether any request is queued or running.
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.running.is_empty()
    }

    /// Queued (not yet admitted) requests.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Currently running requests.
    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Submit a request; returns its arrival ticket.  Tickets are
    /// assigned in submission order and are the *only* input to
    /// admission/eviction ordering.
    pub fn submit(&mut self, req: Request) -> Result<u64> {
        if req.gen_len == 0 || req.gen_len > self.cfg.max_gen_len {
            bail!("request {} gen_len {} out of range 1..={}",
                  req.id, req.gen_len, self.cfg.max_gen_len);
        }
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        self.queue.push_back(Active {
            req,
            ticket,
            seq: SeqKv::new(),
            step: 0,
            fingerprint: FP_SEED,
            evictions: 0,
            submitted: Instant::now(),
        });
        self.metrics.inc("requests", 1);
        Ok(ticket)
    }

    /// Evict the youngest running request: release its blocks, reset
    /// its decode state (rows are f(seed, step), so the retry is
    /// bitwise identical), and requeue it at the *front* — youngest
    /// running is still older than everything queued, so ascending
    /// ticket order is preserved.
    fn evict_youngest(&mut self) {
        let mut r = self.running.pop()
            .expect("eviction from an empty batch");
        self.cache.release(&mut r.seq);
        r.step = 0;
        r.fingerprint = FP_SEED;
        r.evictions += 1;
        self.metrics.inc("evicted", 1);
        self.queue.push_front(r);
    }

    /// One scheduler step: admit → append (evicting under pressure) →
    /// parallel decode → fold fingerprints → retire.  Returns the
    /// requests that completed this step, in ascending ticket order.
    pub fn step(&mut self) -> Vec<Response> {
        let t_step = Instant::now();
        let (heads, d) = (self.cfg.heads, self.cfg.d);
        let width = heads * d;

        // Admission: queue front → batch back, up to max_batch.  New
        // arrivals only ever join here, at a step boundary.
        while self.running.len() < self.cfg.max_batch {
            let Some(a) = self.queue.pop_front() else { break };
            self.metrics.inc("admitted", 1);
            self.running.push(a);
        }

        // Append phase: one K/V row per running sequence, oldest
        // first.  Cache pressure evicts from the back (youngest), so
        // index i is only ever removed when it *is* the back.
        let mut decoded: Vec<usize> = Vec::new();
        let mut qrows: Vec<Vec<f32>> = Vec::new();
        let mut i = 0;
        while i < self.running.len() {
            let (qrow, krow, vrow) = synth_rows(
                self.running[i].req.seed, self.running[i].step, width);
            let appended = loop {
                match self.cache.append(&mut self.running[i].seq,
                                        &krow, &vrow) {
                    Ok(()) => break true,
                    Err(CacheFull) => {
                        if self.running.len() - 1 > i {
                            self.evict_youngest();
                        } else if i > 0 {
                            self.evict_youngest(); // i itself
                            break false;
                        } else {
                            // A lone sequence always fits by
                            // ServeConfig::validate's pool bound.
                            panic!("kv pool exhausted by a lone \
                                    sequence — validate() bound \
                                    violated");
                        }
                    }
                }
            };
            if appended {
                decoded.push(i);
                qrows.push(qrow);
                i += 1;
            }
            // else: i was the back and got evicted; loop condition
            // now fails (i == len) and the step moves on.
        }

        // Decode phase: every appended row attends to its own cached
        // prefix, fanned out over the backend pool.  Tasks write
        // disjoint carved slices (declared for the race detector);
        // the cache is only read.
        let mut outs = vec![0.0f32; decoded.len() * width];
        let mut lses = vec![0.0f32; decoded.len() * heads];
        {
            let mixed = self.backend.precision() == Precision::Mixed;
            let params = &self.params;
            let cache = &self.cache;
            let mut orest: &mut [f32] = &mut outs;
            let mut lrest: &mut [f32] = &mut lses;
            let mut tasks: Vec<Task<'_>> = Vec::new();
            for (slot, &idx) in decoded.iter().enumerate() {
                let otile = exec::carve(&mut orest, width);
                let ltile = exec::carve(&mut lrest, heads);
                let blocks = cache.blocks(&self.running[idx].seq);
                let pos = self.running[idx].seq.len() - 1;
                let qrow = std::mem::take(&mut qrows[slot]);
                exec::pool::declare_task_writes(&[
                    exec::pool::span(&*otile),
                    exec::pool::span(&*ltile),
                ]);
                tasks.push(Box::new(move || {
                    decode_step(&qrow, &blocks, heads, d, pos, params,
                                mixed, otile, ltile);
                }));
            }
            self.backend.run_tasks(tasks);
        }

        // Fold + retire.  Fingerprints accumulate every output and
        // LSE bit in step order; a finished sequence retires
        // immediately, freeing its blocks for next step's admissions.
        let mut completed: Vec<usize> = Vec::new();
        for (slot, &idx) in decoded.iter().enumerate() {
            let r = &mut self.running[idx];
            let mut fp = r.fingerprint;
            for x in &outs[slot * width..(slot + 1) * width] {
                fp = fp_fold(fp, x.to_bits());
            }
            for x in &lses[slot * heads..(slot + 1) * heads] {
                fp = fp_fold(fp, x.to_bits());
            }
            r.fingerprint = fp;
            r.step += 1;
            if r.step == r.req.gen_len {
                completed.push(idx);
            }
        }
        self.metrics.inc("decode_tokens", decoded.len() as u64);
        let mut responses = Vec::with_capacity(completed.len());
        for &idx in completed.iter().rev() {
            let mut r = self.running.remove(idx);
            self.cache.release(&mut r.seq);
            let latency_s = r.submitted.elapsed().as_secs_f64();
            self.metrics.time("request_latency", latency_s);
            self.metrics.inc("completed", 1);
            responses.push(Response {
                id: r.req.id,
                ticket: r.ticket,
                fingerprint: r.fingerprint,
                steps: r.step,
                evictions: r.evictions,
                latency_s,
            });
        }
        responses.reverse(); // ascending ticket order

        self.metrics.time("serve_step", t_step.elapsed().as_secs_f64());
        self.metrics.set_gauge("running", self.running.len() as f64);
        self.metrics.set_gauge("queued", self.queue.len() as f64);
        self.metrics.set_gauge("free_blocks",
                               self.cache.free_blocks() as f64);
        responses
    }

    /// Drive `n` synthetic requests to completion through the batching
    /// scheduler and return their responses in completion order.
    /// Request `i` gets `id = i`, a seed forked from `base_seed`, and
    /// a deterministic `gen_len` in `1..=max_gen_len`.  Errors if the
    /// run fails to drain or leaks cache blocks (free list not fully
    /// restored) — the guarantees the CI smoke job pins.
    pub fn run_synthetic(&mut self, n: usize, base_seed: u64)
                         -> Result<Vec<Response>> {
        let mut seeder = Rng::new(base_seed);
        for i in 0..n as u64 {
            let seed = seeder.next_u64();
            let gen_len =
                1 + (seed % self.cfg.max_gen_len as u64) as usize;
            self.submit(Request { id: i, seed, gen_len })?;
        }
        let mut responses = Vec::with_capacity(n);
        // Progress bound: the oldest running request advances every
        // step, so total steps ≤ Σ gen_len + admissions slack; the cap
        // below turns a scheduler livelock bug into an error instead
        // of a hang.
        let cap = 2 * n * self.cfg.max_gen_len + n + 64;
        let mut steps = 0usize;
        while self.has_work() {
            if steps > cap {
                bail!("scheduler failed to drain {n} requests within \
                       {cap} steps ({} responses so far) — livelock",
                      responses.len());
            }
            responses.extend(self.step());
            steps += 1;
        }
        if self.free_blocks() != self.capacity_blocks() {
            bail!("cache block leak after drain: {} of {} blocks free",
                  self.free_blocks(), self.capacity_blocks());
        }
        if responses.len() != n {
            bail!("drained with {} responses for {n} requests",
                  responses.len());
        }
        Ok(responses)
    }
}

/// The non-batched oracle: run one request alone, no scheduler, and
/// return the fingerprint its decode outputs fold to.  The serving
/// contract — pinned by the serve tests and the CI smoke job — is
/// that [`Scheduler`] produces *bitwise* this fingerprint for the
/// same request regardless of batching, admission order, or eviction.
pub fn single_request_fingerprint(cfg: &ServeConfig, req: &Request)
                                  -> Result<u64> {
    cfg.validate()?;
    if req.gen_len == 0 || req.gen_len > cfg.max_gen_len {
        bail!("request gen_len {} out of range 1..={}", req.gen_len,
              cfg.max_gen_len);
    }
    let mask = cfg.mask.build(cfg.max_gen_len)?;
    let params = AttnParams::with_mask(cfg.d, mask)?;
    let backend = cfg.exec.build();
    let mixed = backend.precision() == Precision::Mixed;
    let width = cfg.heads * cfg.d;
    let mut cache = KvCache::new(cfg.pool_blocks, cfg.block_tokens,
                                 cfg.heads, cfg.d);
    let mut seq = SeqKv::new();
    let mut fp = FP_SEED;
    let mut out = vec![0.0f32; width];
    let mut lse = vec![0.0f32; cfg.heads];
    for step in 0..req.gen_len {
        let (qrow, krow, vrow) = synth_rows(req.seed, step, width);
        cache.append(&mut seq, &krow, &vrow).map_err(|e| {
            anyhow!("single-request cache full at step {step}: {e}")
        })?;
        decode_step(&qrow, &cache.blocks(&seq), cfg.heads, cfg.d, step,
                    &params, mixed, &mut out, &mut lse);
        for x in &out {
            fp = fp_fold(fp, x.to_bits());
        }
        for x in &lse {
            fp = fp_fold(fp, x.to_bits());
        }
    }
    cache.release(&mut seq);
    Ok(fp)
}

/// Format a completed response as the line-JSON the TCP front-end and
/// `spark load` exchange (fingerprint in hex — it is an identity, not
/// a number).
pub fn response_json(r: &Response) -> String {
    jsonio::to_string(&jsonio::obj(vec![
        ("id", jsonio::num(r.id as f64)),
        ("fingerprint", jsonio::s(format!("{:016x}", r.fingerprint))),
        ("steps", jsonio::num(r.steps as f64)),
        ("evictions", jsonio::num(r.evictions as f64)),
        ("latency_s", jsonio::num(r.latency_s)),
    ]))
}

/// Parse one request line: `{"id": N, "seed": N, "gen_len": N}`.
/// `seed` defaults to `id`; `gen_len` defaults to `default_gen`.
pub fn parse_request_line(line: &str, default_gen: usize)
                          -> Result<Request> {
    let v = jsonio::parse(line.trim())
        .map_err(|e| anyhow!("bad request line: {e}"))?;
    let id = v.get("id").and_then(|x| x.as_i64())
        .ok_or_else(|| anyhow!("request needs an integer \"id\""))?
        as u64;
    let seed = v.get("seed").and_then(|x| x.as_i64())
        .map(|s| s as u64).unwrap_or(id);
    let gen_len = match v.get("gen_len").map(|x| x.as_i64()) {
        Some(Some(g)) if g >= 1 => g as usize,
        Some(_) => bail!("\"gen_len\" must be a positive integer"),
        None => default_gen,
    };
    Ok(Request { id, seed, gen_len })
}

/// A line-JSON TCP front-end running a [`Scheduler`] on its own
/// thread.  Connections are accepted non-blockingly from the serve
/// loop; each gets a reader thread that parses request lines into a
/// shared inbox.  The serve loop drains the inbox (assigning arrival
/// tickets in drain order), steps the scheduler while work exists,
/// and writes each response back to the connection that asked.
pub struct TcpServer {
    /// The bound port (resolves an ephemeral bind with `port = 0`).
    pub port: u16,
    stop: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<Result<Registry>>,
}

type Inbox = Arc<Mutex<VecDeque<(Request, Arc<Mutex<TcpStream>>)>>>;

/// Reader thread: one per connection.  Parses request lines into the
/// inbox until EOF, error, or server stop; malformed lines get an
/// error response immediately (they never reach the scheduler).
fn reader_loop(stream: TcpStream, writer: Arc<Mutex<TcpStream>>,
               inbox: Inbox, stop: Arc<AtomicBool>, default_gen: usize) {
    let mut br = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match br.read_line(&mut line) {
            Ok(0) => break, // client closed
            Ok(_) => match parse_request_line(&line, default_gen) {
                Ok(req) => inbox.lock().expect("inbox lock")
                    .push_back((req, Arc::clone(&writer))),
                Err(e) => {
                    let msg = jsonio::to_string(&jsonio::obj(vec![
                        ("error", jsonio::s(format!("{e}"))),
                    ]));
                    let mut w = writer.lock().expect("writer lock");
                    let _ = writeln!(w, "{msg}");
                }
            },
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

impl TcpServer {
    /// Bind `127.0.0.1:port` (0 = ephemeral) and start serving `cfg`
    /// on a background thread.
    pub fn spawn(cfg: ServeConfig, port: u16) -> Result<TcpServer> {
        cfg.validate()?;
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding 127.0.0.1:{port}"))?;
        let port = listener.local_addr()?.port();
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let thread = std::thread::spawn(move || {
            serve_loop(cfg, listener, stop2)
        });
        info!("spark serve listening on 127.0.0.1:{port}");
        Ok(TcpServer { port, stop, thread })
    }

    /// Signal the serve loop to finish in-flight work and exit, then
    /// return its final metrics.
    pub fn stop(self) -> Result<Registry> {
        self.stop.store(true, Ordering::Relaxed);
        self.thread.join()
            .map_err(|_| anyhow!("serve thread panicked"))?
    }

    /// Block until the serve loop exits on its own (it only does on
    /// an I/O error — the CLI's run-forever mode).
    pub fn join(self) -> Result<Registry> {
        self.thread.join()
            .map_err(|_| anyhow!("serve thread panicked"))?
    }
}

/// The serve-thread body: accept connections, drain the inbox into
/// the scheduler, step while work exists, route responses back.
fn serve_loop(cfg: ServeConfig, listener: TcpListener,
              stop: Arc<AtomicBool>) -> Result<Registry> {
    let default_gen = cfg.max_gen_len;
    let mut sched = Scheduler::new(cfg)?;
    let inbox: Inbox = Arc::new(Mutex::new(VecDeque::new()));
    let mut responders: BTreeMap<u64, Arc<Mutex<TcpStream>>> =
        BTreeMap::new();
    loop {
        // accept any waiting connections (non-blocking)
        loop {
            match listener.accept() {
                Ok((conn, peer)) => {
                    conn.set_read_timeout(
                        Some(Duration::from_millis(50)))?;
                    let writer = Arc::new(Mutex::new(conn.try_clone()?));
                    let inbox = Arc::clone(&inbox);
                    let stop = Arc::clone(&stop);
                    info!("serve: connection from {peer}");
                    std::thread::spawn(move || {
                        reader_loop(conn, writer, inbox, stop,
                                    default_gen);
                    });
                }
                Err(e) if e.kind()
                    == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e.into()),
            }
        }
        // drain the inbox: tickets are assigned in drain order, and
        // from here on scheduling is the deterministic core
        let drained: Vec<(Request, Arc<Mutex<TcpStream>>)> = {
            let mut q = inbox.lock().expect("inbox lock");
            q.drain(..).collect()
        };
        for (req, writer) in drained {
            match sched.submit(req) {
                Ok(ticket) => {
                    responders.insert(ticket, writer);
                }
                Err(e) => {
                    let msg = jsonio::to_string(&jsonio::obj(vec![
                        ("id", jsonio::num(req.id as f64)),
                        ("error", jsonio::s(format!("{e}"))),
                    ]));
                    let mut w = writer.lock().expect("writer lock");
                    let _ = writeln!(w, "{msg}");
                }
            }
        }
        if sched.has_work() {
            for r in sched.step() {
                let Some(writer) = responders.remove(&r.ticket) else {
                    warn!("serve: no responder for ticket {}",
                          r.ticket);
                    continue;
                };
                let mut w = writer.lock().expect("writer lock");
                if let Err(e) = writeln!(w, "{}", response_json(&r)) {
                    warn!("serve: dropping response for request {}: \
                           {e}", r.id);
                }
            }
        } else {
            if stop.load(Ordering::Relaxed) {
                return Ok(sched.metrics);
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ServeConfig {
        ServeConfig {
            heads: 2,
            d: 4,
            block_tokens: 4,
            pool_blocks: 8,
            max_batch: 4,
            max_gen_len: 12,
            mask: MaskSpec::Causal,
            exec: ExecOptions::scalar(),
        }
    }

    #[test]
    fn config_validation_rejects_unfinishable_pools() {
        let mut cfg = tiny_cfg();
        cfg.pool_blocks = 2; // max_gen_len 12 needs ceil(12/4) = 3
        assert!(cfg.validate().is_err());
        cfg.pool_blocks = 3;
        assert!(cfg.validate().is_ok());
        cfg.max_batch = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn batched_fingerprints_match_single_request_path() {
        let cfg = tiny_cfg();
        let mut sched = Scheduler::new(cfg.clone()).unwrap();
        let responses = sched.run_synthetic(8, 0xA11CE).unwrap();
        assert_eq!(responses.len(), 8);
        for r in &responses {
            // reconstruct the request run_synthetic generated
            let mut seeder = Rng::new(0xA11CE);
            let seed = (0..=r.id).map(|_| seeder.next_u64()).last()
                .unwrap();
            let gen_len =
                1 + (seed % cfg.max_gen_len as u64) as usize;
            assert_eq!(r.steps, gen_len, "request {}", r.id);
            let want = single_request_fingerprint(
                &cfg, &Request { id: r.id, seed, gen_len }).unwrap();
            assert_eq!(r.fingerprint, want,
                       "request {} batched ≠ single", r.id);
        }
    }

    #[test]
    fn eviction_under_pressure_is_bitwise_equal_to_retry() {
        // Pool of 3 blocks, max_gen_len 12 (needs 3): any batch > 1
        // fights for blocks, forcing mid-step evictions.
        let cfg = ServeConfig {
            pool_blocks: 3,
            ..tiny_cfg()
        };
        let mut sched = Scheduler::new(cfg.clone()).unwrap();
        let responses = sched.run_synthetic(6, 0xBEEF).unwrap();
        assert!(sched.metrics.counter("evicted") > 0,
                "pressure config must actually evict");
        let mut seeder = Rng::new(0xBEEF);
        let seeds: Vec<u64> = (0..6).map(|_| seeder.next_u64())
            .collect();
        for r in &responses {
            let seed = seeds[r.id as usize];
            let gen_len =
                1 + (seed % cfg.max_gen_len as u64) as usize;
            let want = single_request_fingerprint(
                &cfg, &Request { id: r.id, seed, gen_len }).unwrap();
            assert_eq!(r.fingerprint, want,
                       "request {} (evicted {}×) diverged", r.id,
                       r.evictions);
        }
        assert_eq!(sched.free_blocks(), sched.capacity_blocks());
    }

    #[test]
    fn identical_runs_are_identical() {
        let run = || {
            let mut s = Scheduler::new(ServeConfig {
                pool_blocks: 4,
                ..tiny_cfg()
            }).unwrap();
            let rs = s.run_synthetic(10, 7).unwrap();
            rs.iter().map(|r| (r.id, r.ticket, r.steps, r.fingerprint))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn submit_rejects_out_of_range_gen_len() {
        let mut s = Scheduler::new(tiny_cfg()).unwrap();
        assert!(s.submit(Request { id: 0, seed: 1, gen_len: 0 })
            .is_err());
        assert!(s.submit(Request { id: 0, seed: 1, gen_len: 13 })
            .is_err());
        assert!(s.submit(Request { id: 0, seed: 1, gen_len: 12 })
            .is_ok());
    }

    #[test]
    fn continuous_batching_admits_mid_run() {
        let mut s = Scheduler::new(tiny_cfg()).unwrap();
        s.submit(Request { id: 0, seed: 10, gen_len: 8 }).unwrap();
        // first step admits and decodes request 0 alone
        assert!(s.step().is_empty());
        assert_eq!(s.running(), 1);
        // a late arrival joins the running batch at the next boundary
        s.submit(Request { id: 1, seed: 11, gen_len: 2 }).unwrap();
        assert!(s.step().is_empty());
        assert_eq!(s.running(), 2);
        // request 1 (2 steps) retires while request 0 keeps going
        let done = s.step();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert_eq!(s.running(), 1);
        while s.has_work() {
            s.step();
        }
        assert_eq!(s.free_blocks(), s.capacity_blocks());
    }

    #[test]
    fn request_line_parsing() {
        let r = parse_request_line(
            "{\"id\": 3, \"seed\": 9, \"gen_len\": 5}", 64).unwrap();
        assert_eq!(r, Request { id: 3, seed: 9, gen_len: 5 });
        let r = parse_request_line("{\"id\": 4}", 64).unwrap();
        assert_eq!(r, Request { id: 4, seed: 4, gen_len: 64 });
        assert!(parse_request_line("not json", 64).is_err());
        assert!(parse_request_line("{\"seed\": 1}", 64).is_err());
        assert!(parse_request_line("{\"id\":1,\"gen_len\":0}", 64)
            .is_err());
    }
}
