//! `spark serve`: continuous-batching inference over the paged KV-cache.
//!
//! The serving layer is three pieces:
//!
//! * [`Scheduler`] — the deterministic core.  Requests carry an
//!   *arrival ticket* assigned at submission; every scheduling decision
//!   (admission order, eviction victim, retirement) is a pure function
//!   of ticket order and cache occupancy — never of wall-clock time,
//!   which is used only to *report* latency.  Each [`Scheduler::step`]
//!   is one decode step for the whole running batch: retire finished
//!   sequences, admit from the queue up to `max_batch`, append one
//!   K/V row per sequence into the paged cache (evicting under
//!   pressure), then decode every appended row in parallel on the
//!   exec backend.
//! * [`crate::tensor::paged::KvCache`] — fixed-size blocks from one
//!   arena
//!   with LIFO free-list reuse, so block placement is reproducible.
//! * [`crate::attention::decode_step`] — the `bq = 1` streaming-attention
//!   kernel over the cached blocks; bitwise-identical to the full
//!   streaming forward (see its module docs), which is what makes the
//!   core serving property testable: **a request's output fingerprint
//!   is independent of batching** — the same request alone, batched,
//!   or evicted-and-retried produces bit-identical decode outputs.
//!
//! **Continuous batching.**  New arrivals join the running batch at
//! step boundaries; finished sequences retire immediately, freeing
//! their blocks for the same step's admissions.  Under cache pressure
//! the *youngest* arrival is evicted (released, fingerprint reset,
//! requeued at the queue front), so the oldest running request always
//! makes progress — combined with the config guarantee that a lone
//! sequence always fits (`ceil(max_gen_len / block_tokens) ≤
//! pool_blocks`), every admitted request terminates.  Evicted requests
//! restart from step 0; their synthetic rows are a pure function of
//! `(seed, step)`, so the recomputation is bitwise identical.
//!
//! **Prefill.**  A request may carry a prompt (`prompt_len` tokens
//! seeded by `prompt_seed`).  Prompts are ingested in
//! `block_tokens`-sized chunks — one chunk per scheduler step, so a
//! long prompt never starves running decodes — through
//! [`crate::attention::prefill_chunk`], which carries the per-row
//! streaming statistics ([`crate::attention::PrefillState`]) across
//! chunks and finalizes bitwise-identically to the full streaming
//! forward over the prompt.  A mid-prefill eviction releases the
//! blocks and drops the state; the restart re-ingests the prompt
//! deterministically (rows are `f(prompt_seed, pos)`), so fingerprints
//! stay batching-independent.  The liveness bound widens accordingly:
//! `ceil((max_prompt_len + max_gen_len) / block_tokens) ≤
//! pool_blocks` guarantees a lone request — prompt *and* generation —
//! always fits.
//!
//! **Workload.**  Requests are synthetic streams: prompt token `t`
//! derives its rows from `Rng::new(prompt_seed).fork(t)` and decode
//! step `s` (at absolute position `prompt_len + s`) from
//! `Rng::new(seed).fork(s)`.  This models the memory/scheduling
//! behaviour of real serving (the paper's host attention path per
//! token) while keeping every byte reproducible — the same property
//! the trainer's synthetic corpus relies on.
//!
//! The TCP front-end ([`TcpServer`]) speaks line-delimited JSON and
//! exists so a load generator (`spark load`) can drive thousands of
//! concurrent requests through a real socket; it assigns tickets in
//! inbox drain order, after which everything is the deterministic
//! core.  The inbox is *bounded* (`inbox_cap`): a reader that finds it
//! full sheds the request with a named `busy` response instead of
//! growing the queue without bound — every line gets exactly one
//! answer, never a silent drop.

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};
use log::{info, warn};

use crate::attention::{decode_step, prefill_chunk, AttnParams,
                       MaskSpec, PrefillState};
use crate::exec::{self, Backend, ExecOptions, Precision, Task};
use crate::jsonio;
use crate::metrics::Registry;
use crate::tensor::paged::{CacheFull, KvCache, SeqKv};
use crate::tensor::Rng;

/// FNV-1a offset basis: the initial per-request output fingerprint.
const FP_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// One FNV-1a fold of a 32-bit word into a fingerprint.
fn fp_fold(h: u64, bits: u32) -> u64 {
    (h ^ bits as u64).wrapping_mul(0x0000_0100_0000_01B3)
}

/// Serving configuration (dimensions, cache sizing, batching policy).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Attention heads per request.
    pub heads: usize,
    /// Head dimension.
    pub d: usize,
    /// Tokens per KV-cache block.
    pub block_tokens: usize,
    /// Total blocks in the cache pool.
    pub pool_blocks: usize,
    /// Maximum sequences decoding concurrently.
    pub max_batch: usize,
    /// Upper bound on a request's `gen_len`.
    pub max_gen_len: usize,
    /// Upper bound on a request's `prompt_len` (0 = decode-only
    /// serving; prompts are then rejected with a named error).
    pub max_prompt_len: usize,
    /// `gen_len` assigned to request lines that omit it.  Explicit
    /// config, not an implicit alias of `max_gen_len` — must sit in
    /// `1..=max_gen_len`.
    pub default_gen_len: usize,
    /// High-water mark of the TCP inbox: requests parsed while this
    /// many are already queued are shed with a named `busy` response.
    pub inbox_cap: usize,
    /// Attention mask applied to every request (instantiated at
    /// `max_prompt_len + max_gen_len`, the longest sequence a request
    /// can reach).
    pub mask: MaskSpec,
    /// Exec backend running the parallel prefill/decode tasks.
    pub exec: ExecOptions,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            heads: 4,
            d: 32,
            block_tokens: 16,
            pool_blocks: 64,
            max_batch: 8,
            max_gen_len: 64,
            max_prompt_len: 64,
            default_gen_len: 64,
            inbox_cap: 1024,
            mask: MaskSpec::Causal,
            exec: ExecOptions::default(),
        }
    }
}

impl ServeConfig {
    /// Longest sequence a single request can occupy: full prompt plus
    /// full generation.
    pub fn max_seq_len(&self) -> usize {
        self.max_prompt_len + self.max_gen_len
    }

    /// Reject configurations that cannot serve: zero dimensions, an
    /// exec combination the backends refuse, a mask that cannot cover
    /// `max_prompt_len + max_gen_len`, a `default_gen_len` outside
    /// `1..=max_gen_len`, a zero `inbox_cap` (a front-end that could
    /// accept nothing), or — the liveness-critical one — a pool too
    /// small for a *lone* maximum-length sequence (prompt + decode).
    /// Eviction frees other sequences' blocks, so the sole-sequence
    /// bound is exactly what guarantees the oldest request always
    /// finishes.
    pub fn validate(&self) -> Result<()> {
        if self.heads == 0 || self.d == 0 || self.block_tokens == 0
            || self.pool_blocks == 0 || self.max_batch == 0
            || self.max_gen_len == 0
        {
            bail!("serve config dimensions must all be ≥ 1 (heads={} \
                   d={} block_tokens={} pool_blocks={} max_batch={} \
                   max_gen_len={})",
                  self.heads, self.d, self.block_tokens,
                  self.pool_blocks, self.max_batch, self.max_gen_len);
        }
        if self.default_gen_len == 0
            || self.default_gen_len > self.max_gen_len
        {
            bail!("default_gen_len {} out of range 1..={}",
                  self.default_gen_len, self.max_gen_len);
        }
        if self.inbox_cap == 0 {
            bail!("inbox_cap must be ≥ 1 — a zero-capacity inbox \
                   sheds every request");
        }
        let need = self.max_seq_len().div_ceil(self.block_tokens);
        if need > self.pool_blocks {
            bail!("cache pool too small: a lone max-length sequence \
                   needs {need} blocks (max_prompt_len={} + \
                   max_gen_len={} over block_tokens={}) but the pool \
                   has {} — no eviction policy can make such a \
                   request finish",
                  self.max_prompt_len, self.max_gen_len,
                  self.block_tokens, self.pool_blocks);
        }
        self.exec.validate()?;
        self.mask.build(self.max_seq_len()).context(
            "serve mask must instantiate at max_prompt_len + \
             max_gen_len")?;
        Ok(())
    }
}

/// One inference request: a `prompt_len`-token synthetic prompt
/// (ingested in chunks, rows derived from `prompt_seed`) followed by
/// `gen_len` synthetic decode steps whose rows derive from `seed`
/// (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Caller-chosen id, echoed in the [`Response`].
    pub id: u64,
    /// Seed of the synthetic decode token stream.
    pub seed: u64,
    /// Decode steps to run (must be `1..=max_gen_len`).
    pub gen_len: usize,
    /// Prompt tokens to ingest before decoding (must be
    /// `0..=max_prompt_len`; 0 = pure decode, PR-9 behaviour).
    pub prompt_len: usize,
    /// Seed of the synthetic prompt token stream.
    pub prompt_seed: u64,
}

/// A completed request.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    /// The request's `id`.
    pub id: u64,
    /// The arrival ticket the scheduler assigned at submission.
    pub ticket: u64,
    /// FNV-1a fold of every output and LSE bit the request produced —
    /// the finalized prompt rows first (row-major, outputs then LSEs),
    /// then each decode step — the batching-independent identity of
    /// the computation.
    pub fingerprint: u64,
    /// Decode steps executed (== `gen_len`).
    pub steps: usize,
    /// Prompt tokens ingested (== `prompt_len`).
    pub prompt_len: usize,
    /// Times this request was evicted and restarted.
    pub evictions: u64,
    /// Submission-to-completion wall time, seconds (reporting only —
    /// never consulted by scheduling).
    pub latency_s: f64,
}

/// Synthetic rows for step `step` of a request seeded `seed`: the
/// flattened `(heads·d)` query, key, and value rows, in that order.
/// Pure in `(seed, step, width)` — an evicted request regenerates
/// byte-identical rows on retry.
pub fn synth_rows(seed: u64, step: usize, width: usize)
                  -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed).fork(step as u64);
    (rng.normal_vec(width), rng.normal_vec(width),
     rng.normal_vec(width))
}

/// A submitted request the scheduler is tracking (queued or running).
#[derive(Debug)]
struct Active {
    req: Request,
    ticket: u64,
    seq: SeqKv,
    step: usize,
    /// Streaming statistics of a prompt mid-ingestion.  `Some` from
    /// submission until the last chunk's fingerprint fold (never for
    /// `prompt_len == 0`); an eviction re-arms it fresh.
    prefill: Option<PrefillState>,
    fingerprint: u64,
    evictions: u64,
    submitted: Instant,
}

impl Active {
    /// Whether this request is still ingesting its prompt.
    fn in_prefill(&self) -> bool {
        self.prefill.is_some()
    }
}

/// The continuous-batching scheduler (see the module docs).
pub struct Scheduler {
    cfg: ServeConfig,
    params: AttnParams,
    backend: Box<dyn Backend>,
    cache: KvCache,
    /// Waiting requests in arrival order.  Invariant: every queued
    /// ticket is greater than every running ticket *except* evicted
    /// requeues, which are pushed to the front — preserving global
    /// ascending ticket order across `running ++ queue`.
    queue: VecDeque<Active>,
    /// Running batch, ascending ticket order (admission appends,
    /// eviction pops the back, retirement removes anywhere).
    running: Vec<Active>,
    next_ticket: u64,
    /// Serving metrics: `request_latency` / `serve_step` series,
    /// admission/eviction/completion counters, occupancy gauges.
    pub metrics: Registry,
}

impl Scheduler {
    /// Build a scheduler from a validated config.
    pub fn new(cfg: ServeConfig) -> Result<Self> {
        cfg.validate()?;
        let mask = cfg.mask.build(cfg.max_seq_len())?;
        let params = AttnParams::with_mask(cfg.d, mask)?;
        let backend = cfg.exec.build();
        let cache = KvCache::new(cfg.pool_blocks, cfg.block_tokens,
                                 cfg.heads, cfg.d);
        let mut metrics = Registry::new();
        // Pre-seed every serving counter at 0 so the metrics JSON
        // always carries the full key set — the CI smoke job asserts
        // on `prefill_chunks`/`shed` even in runs that never shed.
        for c in ["requests", "admitted", "evicted", "evicted_prefill",
                  "completed", "decode_tokens", "prefill_chunks",
                  "shed"] {
            metrics.inc(c, 0);
        }
        Ok(Scheduler {
            cfg,
            params,
            backend,
            cache,
            queue: VecDeque::new(),
            running: Vec::new(),
            next_ticket: 0,
            metrics,
        })
    }

    /// The configuration this scheduler was built from.
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Blocks currently free in the cache pool.
    pub fn free_blocks(&self) -> usize {
        self.cache.free_blocks()
    }

    /// Total blocks in the cache pool.
    pub fn capacity_blocks(&self) -> usize {
        self.cache.capacity_blocks()
    }

    /// Whether any request is queued or running.
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.running.is_empty()
    }

    /// Queued (not yet admitted) requests.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Currently running requests.
    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Submit a request; returns its arrival ticket.  Tickets are
    /// assigned in submission order and are the *only* input to
    /// admission/eviction ordering.
    pub fn submit(&mut self, req: Request) -> Result<u64> {
        if req.gen_len == 0 || req.gen_len > self.cfg.max_gen_len {
            bail!("request {} gen_len {} out of range 1..={}",
                  req.id, req.gen_len, self.cfg.max_gen_len);
        }
        if req.prompt_len > self.cfg.max_prompt_len {
            bail!("request {} prompt_len {} out of range 0..={}",
                  req.id, req.prompt_len, self.cfg.max_prompt_len);
        }
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        let prefill = (req.prompt_len > 0).then(|| {
            PrefillState::new(self.cfg.heads, self.cfg.d,
                              req.prompt_len)
        });
        self.queue.push_back(Active {
            req,
            ticket,
            seq: SeqKv::new(),
            step: 0,
            prefill,
            fingerprint: FP_SEED,
            evictions: 0,
            submitted: Instant::now(),
        });
        self.metrics.inc("requests", 1);
        Ok(ticket)
    }

    /// Evict the youngest running request: release its blocks, reset
    /// its prefill/decode state (rows are pure functions of the seeds
    /// and positions, so the retry is bitwise identical), and requeue
    /// it at the *front* — youngest running is still older than
    /// everything queued, so ascending ticket order is preserved.
    /// A request caught mid-prompt drops its streaming statistics and
    /// re-ingests the prompt from token 0 on readmission.
    fn evict_youngest(&mut self) {
        let mut r = self.running.pop()
            .expect("eviction from an empty batch");
        if r.in_prefill() && !r.seq.is_empty() {
            self.metrics.inc("evicted_prefill", 1);
        }
        self.cache.release(&mut r.seq);
        r.step = 0;
        r.fingerprint = FP_SEED;
        r.prefill = (r.req.prompt_len > 0).then(|| {
            PrefillState::new(self.cfg.heads, self.cfg.d,
                              r.req.prompt_len)
        });
        r.evictions += 1;
        self.metrics.inc("evicted", 1);
        self.queue.push_front(r);
    }

    /// One scheduler step: admit → append (evicting under pressure) →
    /// parallel prefill/decode → fold fingerprints → retire.  Each
    /// running request contributes one unit of work per step — a
    /// `block_tokens`-sized prompt chunk while mid-prefill, one decode
    /// row afterwards — so prompts and decodes interleave under the
    /// same arrival-ticket order.  Returns the requests that completed
    /// this step, in ascending ticket order.
    pub fn step(&mut self) -> Vec<Response> {
        let t_step = Instant::now();
        let (heads, d) = (self.cfg.heads, self.cfg.d);
        let width = heads * d;
        let bt = self.cfg.block_tokens;

        // Admission: queue front → batch back, up to max_batch.  New
        // arrivals only ever join here, at a step boundary.
        while self.running.len() < self.cfg.max_batch {
            let Some(a) = self.queue.pop_front() else { break };
            self.metrics.inc("admitted", 1);
            self.running.push(a);
        }

        // Append phase, oldest first.  Cache pressure evicts from the
        // back (youngest), so index i is only ever removed when it
        // *is* the back.  Prompt chunks append atomically
        // (`append_rows`), so an eviction retry never sees a
        // half-landed chunk.
        let mut decoded: Vec<usize> = Vec::new();
        let mut qrows: Vec<Vec<f32>> = Vec::new();
        // (idx, state, chunk query rows): prefill states leave their
        // `Active` here so the parallel section gets disjoint &muts.
        let mut chunks: Vec<(usize, PrefillState, Vec<f32>)> =
            Vec::new();
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].in_prefill() {
                let req = self.running[i].req;
                let done = self.running[i].prefill.as_ref()
                    .expect("in_prefill").rows();
                let chunk = (req.prompt_len - done).min(bt);
                let mut qchunk = Vec::with_capacity(chunk * width);
                let mut kchunk = Vec::with_capacity(chunk * width);
                let mut vchunk = Vec::with_capacity(chunk * width);
                for t in 0..chunk {
                    let (q, k, v) =
                        synth_rows(req.prompt_seed, done + t, width);
                    qchunk.extend_from_slice(&q);
                    kchunk.extend_from_slice(&k);
                    vchunk.extend_from_slice(&v);
                }
                let appended = loop {
                    match self.cache.append_rows(
                        &mut self.running[i].seq, &kchunk, &vchunk) {
                        Ok(()) => break true,
                        Err(CacheFull) => {
                            if self.running.len() - 1 > i {
                                self.evict_youngest();
                            } else if i > 0 {
                                self.evict_youngest(); // i itself
                                break false;
                            } else {
                                // A lone sequence always fits by
                                // ServeConfig::validate's pool bound.
                                panic!("kv pool exhausted by a lone \
                                        sequence — validate() bound \
                                        violated");
                            }
                        }
                    }
                };
                if appended {
                    let st = self.running[i].prefill.take()
                        .expect("in_prefill");
                    chunks.push((i, st, qchunk));
                    i += 1;
                }
                continue;
            }
            let (qrow, krow, vrow) = synth_rows(
                self.running[i].req.seed, self.running[i].step, width);
            let appended = loop {
                match self.cache.append(&mut self.running[i].seq,
                                        &krow, &vrow) {
                    Ok(()) => break true,
                    Err(CacheFull) => {
                        if self.running.len() - 1 > i {
                            self.evict_youngest();
                        } else if i > 0 {
                            self.evict_youngest(); // i itself
                            break false;
                        } else {
                            // A lone sequence always fits by
                            // ServeConfig::validate's pool bound.
                            panic!("kv pool exhausted by a lone \
                                    sequence — validate() bound \
                                    violated");
                        }
                    }
                }
            };
            if appended {
                decoded.push(i);
                qrows.push(qrow);
                i += 1;
            }
            // else: i was the back and got evicted; loop condition
            // now fails (i == len) and the step moves on.
        }

        // Execution phase: prefill chunks fold into their per-request
        // streaming statistics, decode rows attend to their cached
        // prefixes — all fanned out over the same backend pool.
        // Tasks write disjoint data (carved slices for decode, each
        // request's own state vectors for prefill), declared for the
        // race detector; the cache is only read.
        let mut outs = vec![0.0f32; decoded.len() * width];
        let mut lses = vec![0.0f32; decoded.len() * heads];
        {
            let mixed = self.backend.precision() == Precision::Mixed;
            let params = &self.params;
            let cache = &self.cache;
            let running = &self.running;
            let mut orest: &mut [f32] = &mut outs;
            let mut lrest: &mut [f32] = &mut lses;
            let mut tasks: Vec<Task<'_>> = Vec::new();
            for (idx, st, qchunk) in chunks.iter_mut() {
                let blocks = cache.blocks(&running[*idx].seq);
                let qchunk = std::mem::take(qchunk);
                exec::pool::declare_task_writes(&st.write_spans());
                tasks.push(Box::new(move || {
                    prefill_chunk(st, &qchunk, &blocks, params, mixed);
                }));
            }
            for (slot, &idx) in decoded.iter().enumerate() {
                let otile = exec::carve(&mut orest, width);
                let ltile = exec::carve(&mut lrest, heads);
                let blocks = cache.blocks(&running[idx].seq);
                let pos = running[idx].seq.len() - 1;
                let qrow = std::mem::take(&mut qrows[slot]);
                exec::pool::declare_task_writes(&[
                    exec::pool::span(&*otile),
                    exec::pool::span(&*ltile),
                ]);
                tasks.push(Box::new(move || {
                    decode_step(&qrow, &blocks, heads, d, pos, params,
                                mixed, otile, ltile);
                }));
            }
            self.backend.run_tasks(tasks);
        }

        // Prefill fold: a completed prompt finalizes its rows into
        // the fingerprint (outputs then LSEs, row-major) and drops
        // its state — decoding starts next step.  An unfinished
        // prompt just puts its statistics back.
        self.metrics.inc("prefill_chunks", chunks.len() as u64);
        for (idx, st, _) in chunks {
            let r = &mut self.running[idx];
            if st.rows() == r.req.prompt_len {
                let rows = st.rows();
                let mut pout = vec![0.0f32; rows * width];
                let mut plse = vec![0.0f32; rows * heads];
                st.finalize(&mut pout, &mut plse);
                let mut fp = r.fingerprint;
                for x in &pout {
                    fp = fp_fold(fp, x.to_bits());
                }
                for x in &plse {
                    fp = fp_fold(fp, x.to_bits());
                }
                r.fingerprint = fp;
            } else {
                r.prefill = Some(st);
            }
        }

        // Decode fold + retire.  Fingerprints accumulate every output
        // and LSE bit in step order; a finished sequence retires
        // immediately, freeing its blocks for next step's admissions.
        let mut completed: Vec<usize> = Vec::new();
        for (slot, &idx) in decoded.iter().enumerate() {
            let r = &mut self.running[idx];
            let mut fp = r.fingerprint;
            for x in &outs[slot * width..(slot + 1) * width] {
                fp = fp_fold(fp, x.to_bits());
            }
            for x in &lses[slot * heads..(slot + 1) * heads] {
                fp = fp_fold(fp, x.to_bits());
            }
            r.fingerprint = fp;
            r.step += 1;
            if r.step == r.req.gen_len {
                completed.push(idx);
            }
        }
        self.metrics.inc("decode_tokens", decoded.len() as u64);
        let mut responses = Vec::with_capacity(completed.len());
        for &idx in completed.iter().rev() {
            let mut r = self.running.remove(idx);
            self.cache.release(&mut r.seq);
            let latency_s = r.submitted.elapsed().as_secs_f64();
            self.metrics.time("request_latency", latency_s);
            self.metrics.inc("completed", 1);
            responses.push(Response {
                id: r.req.id,
                ticket: r.ticket,
                fingerprint: r.fingerprint,
                steps: r.step,
                prompt_len: r.req.prompt_len,
                evictions: r.evictions,
                latency_s,
            });
        }
        responses.reverse(); // ascending ticket order

        self.metrics.time("serve_step", t_step.elapsed().as_secs_f64());
        self.metrics.set_gauge("running", self.running.len() as f64);
        self.metrics.set_gauge("queued", self.queue.len() as f64);
        self.metrics.set_gauge("free_blocks",
                               self.cache.free_blocks() as f64);
        responses
    }

    /// Drive `n` synthetic requests to completion through the batching
    /// scheduler and return their responses in completion order.
    /// The requests are exactly [`synthetic_requests`]`(config, n,
    /// base_seed)` — a deterministic mixed prefill/decode workload.
    /// Errors if the run fails to drain or leaks cache blocks (free
    /// list not fully restored) — the guarantees the CI smoke job
    /// pins.
    pub fn run_synthetic(&mut self, n: usize, base_seed: u64)
                         -> Result<Vec<Response>> {
        for req in synthetic_requests(&self.cfg, n, base_seed) {
            self.submit(req)?;
        }
        let mut responses = Vec::with_capacity(n);
        // Progress bound: the oldest running request advances every
        // step (a prompt chunk or a decode row), so total steps ≤
        // Σ work units + admissions slack; the cap below turns a
        // scheduler livelock bug into an error instead of a hang.
        let unit = self.cfg.max_gen_len
            + self.cfg.max_prompt_len.div_ceil(self.cfg.block_tokens);
        let cap = 2 * n * unit + n + 64;
        let mut steps = 0usize;
        while self.has_work() {
            if steps > cap {
                bail!("scheduler failed to drain {n} requests within \
                       {cap} steps ({} responses so far) — livelock",
                      responses.len());
            }
            responses.extend(self.step());
            steps += 1;
        }
        if self.free_blocks() != self.capacity_blocks() {
            bail!("cache block leak after drain: {} of {} blocks free",
                  self.free_blocks(), self.capacity_blocks());
        }
        if responses.len() != n {
            bail!("drained with {} responses for {n} requests",
                  responses.len());
        }
        Ok(responses)
    }
}

/// The deterministic synthetic workload: request `i` gets `id = i`, a
/// seed drawn sequentially from `Rng::new(base_seed)`, a `gen_len` in
/// `1..=max_gen_len`, and — when the config allows prompts — a
/// `prompt_len` in `0..=max_prompt_len` with a seed-derived
/// `prompt_seed`.  Shared by [`Scheduler::run_synthetic`] and the
/// serve tests, so the oracle side can reconstruct exactly what the
/// scheduler ran.
pub fn synthetic_requests(cfg: &ServeConfig, n: usize, base_seed: u64)
                          -> Vec<Request> {
    let mut seeder = Rng::new(base_seed);
    (0..n as u64).map(|i| {
        let seed = seeder.next_u64();
        let gen_len = 1 + (seed % cfg.max_gen_len as u64) as usize;
        let prompt_len = if cfg.max_prompt_len == 0 {
            0
        } else {
            ((seed >> 21) % (cfg.max_prompt_len as u64 + 1)) as usize
        };
        Request {
            id: i,
            seed,
            gen_len,
            prompt_len,
            // distinct from the decode stream, still pure in `seed`
            prompt_seed: seed ^ 0x9E37_79B9_7F4A_7C15,
        }
    }).collect()
}

/// The non-batched oracle: run one request alone, no scheduler, and
/// return the fingerprint its outputs fold to — the prompt phase
/// (chunked prefill, finalized rows folded outputs-then-LSEs) followed
/// by the decode steps.  The serving contract — pinned by the serve
/// tests and the CI smoke job — is that [`Scheduler`] produces
/// *bitwise* this fingerprint for the same request regardless of
/// batching, admission order, or eviction.
pub fn single_request_fingerprint(cfg: &ServeConfig, req: &Request)
                                  -> Result<u64> {
    cfg.validate()?;
    if req.gen_len == 0 || req.gen_len > cfg.max_gen_len {
        bail!("request gen_len {} out of range 1..={}", req.gen_len,
              cfg.max_gen_len);
    }
    if req.prompt_len > cfg.max_prompt_len {
        bail!("request prompt_len {} out of range 0..={}",
              req.prompt_len, cfg.max_prompt_len);
    }
    let mask = cfg.mask.build(cfg.max_seq_len())?;
    let params = AttnParams::with_mask(cfg.d, mask)?;
    let backend = cfg.exec.build();
    let mixed = backend.precision() == Precision::Mixed;
    let width = cfg.heads * cfg.d;
    let mut cache = KvCache::new(cfg.pool_blocks, cfg.block_tokens,
                                 cfg.heads, cfg.d);
    let mut seq = SeqKv::new();
    let mut fp = FP_SEED;

    // Prompt phase: the same block-sized chunk schedule the scheduler
    // uses (one streaming-statistics state across chunks).
    if req.prompt_len > 0 {
        let mut st = PrefillState::new(cfg.heads, cfg.d,
                                       req.prompt_len);
        while st.rows() < req.prompt_len {
            let done = st.rows();
            let chunk =
                (req.prompt_len - done).min(cfg.block_tokens);
            let mut qchunk = Vec::with_capacity(chunk * width);
            let mut kchunk = Vec::with_capacity(chunk * width);
            let mut vchunk = Vec::with_capacity(chunk * width);
            for t in 0..chunk {
                let (q, k, v) =
                    synth_rows(req.prompt_seed, done + t, width);
                qchunk.extend_from_slice(&q);
                kchunk.extend_from_slice(&k);
                vchunk.extend_from_slice(&v);
            }
            cache.append_rows(&mut seq, &kchunk, &vchunk)
                .map_err(|e| anyhow!(
                    "single-request cache full at prompt token \
                     {done}: {e}"))?;
            prefill_chunk(&mut st, &qchunk, &cache.blocks(&seq),
                          &params, mixed);
        }
        let mut pout = vec![0.0f32; req.prompt_len * width];
        let mut plse = vec![0.0f32; req.prompt_len * cfg.heads];
        st.finalize(&mut pout, &mut plse);
        for x in &pout {
            fp = fp_fold(fp, x.to_bits());
        }
        for x in &plse {
            fp = fp_fold(fp, x.to_bits());
        }
    }

    // Decode phase: one row per step at absolute position
    // `prompt_len + step`.
    let mut out = vec![0.0f32; width];
    let mut lse = vec![0.0f32; cfg.heads];
    for step in 0..req.gen_len {
        let (qrow, krow, vrow) = synth_rows(req.seed, step, width);
        cache.append(&mut seq, &krow, &vrow).map_err(|e| {
            anyhow!("single-request cache full at step {step}: {e}")
        })?;
        decode_step(&qrow, &cache.blocks(&seq), cfg.heads, cfg.d,
                    req.prompt_len + step, &params, mixed, &mut out,
                    &mut lse);
        for x in &out {
            fp = fp_fold(fp, x.to_bits());
        }
        for x in &lse {
            fp = fp_fold(fp, x.to_bits());
        }
    }
    cache.release(&mut seq);
    Ok(fp)
}

/// Format a completed response as the line-JSON the TCP front-end and
/// `spark load` exchange (fingerprint in hex — it is an identity, not
/// a number).
pub fn response_json(r: &Response) -> String {
    jsonio::to_string(&jsonio::obj(vec![
        ("id", jsonio::num(r.id as f64)),
        ("fingerprint", jsonio::s(format!("{:016x}", r.fingerprint))),
        ("steps", jsonio::num(r.steps as f64)),
        ("prompt_len", jsonio::num(r.prompt_len as f64)),
        ("evictions", jsonio::num(r.evictions as f64)),
        ("latency_s", jsonio::num(r.latency_s)),
    ]))
}

/// Longest request line the parser accepts.  A well-formed request is
/// under 200 bytes; anything longer is garbage (or an attack on the
/// line buffer) and gets a named rejection, never a partial parse.
pub const MAX_REQUEST_LINE_BYTES: usize = 4096;

/// Parse one request line: `{"id": N, "seed": N, "gen_len": N,
/// "prompt_len": N, "prompt_seed": N}`.  `seed` defaults to `id`,
/// `gen_len` to `cfg.default_gen_len`, `prompt_len` to 0, and
/// `prompt_seed` to `seed`.  Out-of-range values are named errors,
/// never clamps: `gen_len` must be ≥ 1 (its upper bound is enforced
/// at submit), `prompt_len` must sit in `0..=max_prompt_len`, and
/// oversized lines are rejected outright.
pub fn parse_request_line(line: &str, cfg: &ServeConfig)
                          -> Result<Request> {
    if line.len() > MAX_REQUEST_LINE_BYTES {
        bail!("request line exceeds {MAX_REQUEST_LINE_BYTES} bytes \
               ({} given)", line.len());
    }
    let v = jsonio::parse(line.trim())
        .map_err(|e| anyhow!("bad request line: {e}"))?;
    let id = v.get("id").and_then(|x| x.as_i64())
        .ok_or_else(|| anyhow!("request needs an integer \"id\""))?
        as u64;
    let seed = v.get("seed").and_then(|x| x.as_i64())
        .map(|s| s as u64).unwrap_or(id);
    let gen_len = match v.get("gen_len").map(|x| x.as_i64()) {
        Some(Some(g)) if g >= 1 => g as usize,
        Some(_) => bail!("\"gen_len\" must be a positive integer"),
        None => cfg.default_gen_len,
    };
    let prompt_len = match v.get("prompt_len").map(|x| x.as_i64()) {
        Some(Some(p)) if p >= 0 => {
            let p = p as usize;
            if p > cfg.max_prompt_len {
                bail!("\"prompt_len\" {p} out of range 0..={}",
                      cfg.max_prompt_len);
            }
            p
        }
        Some(_) => bail!("\"prompt_len\" must be a non-negative \
                          integer"),
        None => 0,
    };
    let prompt_seed = v.get("prompt_seed").and_then(|x| x.as_i64())
        .map(|s| s as u64).unwrap_or(seed);
    Ok(Request { id, seed, gen_len, prompt_len, prompt_seed })
}

/// A line-JSON TCP front-end running a [`Scheduler`] on its own
/// thread.  Connections are accepted non-blockingly from the serve
/// loop; each gets a reader thread that parses request lines into a
/// shared inbox.  The serve loop drains the inbox (assigning arrival
/// tickets in drain order), steps the scheduler while work exists,
/// and writes each response back to the connection that asked.
pub struct TcpServer {
    /// The bound port (resolves an ephemeral bind with `port = 0`).
    pub port: u16,
    stop: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<Result<Registry>>,
}

/// The bounded inbox readers fill and the serve loop drains, plus the
/// running count of requests shed at the high-water mark (synced into
/// the scheduler's metrics as the `shed` counter).
struct InboxState {
    q: VecDeque<(Request, Arc<Mutex<TcpStream>>)>,
    shed: u64,
}

type Inbox = Arc<Mutex<InboxState>>;

/// Shed-or-enqueue for one item against the high-water mark `cap`:
/// enqueues and returns `true` when below the cap, otherwise bumps
/// `shed` and returns `false` — the queue *never* grows past `cap`.
/// Generic so the policy is unit-testable without sockets.
fn inbox_offer<T>(q: &mut VecDeque<T>, shed: &mut u64, cap: usize,
                  item: T) -> bool {
    if q.len() >= cap {
        *shed += 1;
        return false;
    }
    q.push_back(item);
    debug_assert!(q.len() <= cap);
    true
}

/// Reader thread: one per connection.  Parses request lines into the
/// bounded inbox until EOF, error, or server stop; malformed lines
/// get an error response immediately, and lines that arrive while the
/// inbox is at `inbox_cap` get a named `busy` response — every line
/// is answered exactly once, nothing is silently dropped and nothing
/// reaches the scheduler unaccounted.
fn reader_loop(stream: TcpStream, writer: Arc<Mutex<TcpStream>>,
               inbox: Inbox, stop: Arc<AtomicBool>,
               cfg: Arc<ServeConfig>) {
    let mut br = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match br.read_line(&mut line) {
            Ok(0) => break, // client closed
            Ok(_) => match parse_request_line(&line, &cfg) {
                Ok(req) => {
                    let accepted = {
                        let mut st = inbox.lock().expect("inbox lock");
                        let st = &mut *st;
                        inbox_offer(&mut st.q, &mut st.shed,
                                    cfg.inbox_cap,
                                    (req, Arc::clone(&writer)))
                    };
                    if !accepted {
                        let msg = jsonio::to_string(&jsonio::obj(vec![
                            ("id", jsonio::num(req.id as f64)),
                            ("busy", jsonio::s(format!(
                                "inbox full (cap {})",
                                cfg.inbox_cap))),
                        ]));
                        let mut w = writer.lock().expect("writer lock");
                        let _ = writeln!(w, "{msg}");
                    }
                }
                Err(e) => {
                    let msg = jsonio::to_string(&jsonio::obj(vec![
                        ("error", jsonio::s(format!("{e}"))),
                    ]));
                    let mut w = writer.lock().expect("writer lock");
                    let _ = writeln!(w, "{msg}");
                }
            },
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

impl TcpServer {
    /// Bind `127.0.0.1:port` (0 = ephemeral) and start serving `cfg`
    /// on a background thread.
    pub fn spawn(cfg: ServeConfig, port: u16) -> Result<TcpServer> {
        cfg.validate()?;
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding 127.0.0.1:{port}"))?;
        let port = listener.local_addr()?.port();
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let thread = std::thread::spawn(move || {
            serve_loop(cfg, listener, stop2)
        });
        info!("spark serve listening on 127.0.0.1:{port}");
        Ok(TcpServer { port, stop, thread })
    }

    /// Signal the serve loop to finish in-flight work and exit, then
    /// return its final metrics.
    pub fn stop(self) -> Result<Registry> {
        self.stop.store(true, Ordering::Relaxed);
        self.thread.join()
            .map_err(|_| anyhow!("serve thread panicked"))?
    }

    /// Block until the serve loop exits on its own (it only does on
    /// an I/O error — the CLI's run-forever mode).
    pub fn join(self) -> Result<Registry> {
        self.thread.join()
            .map_err(|_| anyhow!("serve thread panicked"))?
    }
}

/// The serve-thread body: accept connections, drain the inbox into
/// the scheduler, step while work exists, route responses back.
fn serve_loop(cfg: ServeConfig, listener: TcpListener,
              stop: Arc<AtomicBool>) -> Result<Registry> {
    let shared_cfg = Arc::new(cfg.clone());
    let mut sched = Scheduler::new(cfg)?;
    let inbox: Inbox = Arc::new(Mutex::new(InboxState {
        q: VecDeque::new(),
        shed: 0,
    }));
    let mut shed_seen = 0u64;
    let mut responders: BTreeMap<u64, Arc<Mutex<TcpStream>>> =
        BTreeMap::new();
    loop {
        // accept any waiting connections (non-blocking)
        loop {
            match listener.accept() {
                Ok((conn, peer)) => {
                    conn.set_read_timeout(
                        Some(Duration::from_millis(50)))?;
                    let writer = Arc::new(Mutex::new(conn.try_clone()?));
                    let inbox = Arc::clone(&inbox);
                    let stop = Arc::clone(&stop);
                    let cfg = Arc::clone(&shared_cfg);
                    info!("serve: connection from {peer}");
                    std::thread::spawn(move || {
                        reader_loop(conn, writer, inbox, stop, cfg);
                    });
                }
                Err(e) if e.kind()
                    == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e.into()),
            }
        }
        // drain the inbox: tickets are assigned in drain order, and
        // from here on scheduling is the deterministic core; sync the
        // readers' shed count into the metrics while holding the lock
        let (drained, shed_total): (Vec<(Request,
                                         Arc<Mutex<TcpStream>>)>, u64) =
        {
            let mut st = inbox.lock().expect("inbox lock");
            (st.q.drain(..).collect(), st.shed)
        };
        if shed_total > shed_seen {
            sched.metrics.inc("shed", shed_total - shed_seen);
            shed_seen = shed_total;
        }
        for (req, writer) in drained {
            match sched.submit(req) {
                Ok(ticket) => {
                    responders.insert(ticket, writer);
                }
                Err(e) => {
                    let msg = jsonio::to_string(&jsonio::obj(vec![
                        ("id", jsonio::num(req.id as f64)),
                        ("error", jsonio::s(format!("{e}"))),
                    ]));
                    let mut w = writer.lock().expect("writer lock");
                    let _ = writeln!(w, "{msg}");
                }
            }
        }
        if sched.has_work() {
            for r in sched.step() {
                let Some(writer) = responders.remove(&r.ticket) else {
                    warn!("serve: no responder for ticket {}",
                          r.ticket);
                    continue;
                };
                let mut w = writer.lock().expect("writer lock");
                if let Err(e) = writeln!(w, "{}", response_json(&r)) {
                    warn!("serve: dropping response for request {}: \
                           {e}", r.id);
                }
            }
        } else {
            if stop.load(Ordering::Relaxed) {
                return Ok(sched.metrics);
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ServeConfig {
        ServeConfig {
            heads: 2,
            d: 4,
            block_tokens: 4,
            pool_blocks: 8,
            max_batch: 4,
            max_gen_len: 12,
            max_prompt_len: 8,
            default_gen_len: 12,
            inbox_cap: 64,
            mask: MaskSpec::Causal,
            exec: ExecOptions::scalar(),
        }
    }

    /// A request with no prompt (the PR-9 shape).
    fn decode_req(id: u64, seed: u64, gen_len: usize) -> Request {
        Request { id, seed, gen_len, prompt_len: 0, prompt_seed: 0 }
    }

    #[test]
    fn config_validation_rejects_unfinishable_pools() {
        let mut cfg = tiny_cfg();
        // prompt 8 + gen 12 over 4-token blocks needs ceil(20/4) = 5
        cfg.pool_blocks = 4;
        assert!(cfg.validate().is_err());
        cfg.pool_blocks = 5;
        assert!(cfg.validate().is_ok());
        cfg.max_batch = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn config_validation_names_default_gen_and_inbox_errors() {
        let mut cfg = tiny_cfg();
        cfg.default_gen_len = 0;
        let e = cfg.validate().unwrap_err().to_string();
        assert!(e.contains("default_gen_len"), "{e}");
        cfg.default_gen_len = cfg.max_gen_len + 1;
        assert!(cfg.validate().is_err());
        cfg.default_gen_len = cfg.max_gen_len;
        cfg.inbox_cap = 0;
        let e = cfg.validate().unwrap_err().to_string();
        assert!(e.contains("inbox_cap"), "{e}");
    }

    #[test]
    fn batched_fingerprints_match_single_request_path() {
        let cfg = tiny_cfg();
        let mut sched = Scheduler::new(cfg.clone()).unwrap();
        let responses = sched.run_synthetic(8, 0xA11CE).unwrap();
        assert_eq!(responses.len(), 8);
        let reqs = synthetic_requests(&cfg, 8, 0xA11CE);
        assert!(reqs.iter().any(|r| r.prompt_len > 0),
                "workload must include prompts");
        for r in &responses {
            let req = &reqs[r.id as usize];
            assert_eq!(r.steps, req.gen_len, "request {}", r.id);
            assert_eq!(r.prompt_len, req.prompt_len);
            let want =
                single_request_fingerprint(&cfg, req).unwrap();
            assert_eq!(r.fingerprint, want,
                       "request {} batched ≠ single", r.id);
        }
    }

    #[test]
    fn eviction_under_pressure_is_bitwise_equal_to_retry() {
        // Pool of 5 blocks (the lone-sequence minimum for prompt 8 +
        // gen 12 over 4-token blocks): any batch > 1 fights for
        // blocks, forcing mid-step — and mid-prefill — evictions.
        let cfg = ServeConfig {
            pool_blocks: 5,
            ..tiny_cfg()
        };
        let mut sched = Scheduler::new(cfg.clone()).unwrap();
        let responses = sched.run_synthetic(6, 0xBEEF).unwrap();
        assert!(sched.metrics.counter("evicted") > 0,
                "pressure config must actually evict");
        let reqs = synthetic_requests(&cfg, 6, 0xBEEF);
        for r in &responses {
            let want = single_request_fingerprint(
                &cfg, &reqs[r.id as usize]).unwrap();
            assert_eq!(r.fingerprint, want,
                       "request {} (evicted {}×) diverged", r.id,
                       r.evictions);
        }
        assert_eq!(sched.free_blocks(), sched.capacity_blocks());
    }

    #[test]
    fn mid_prefill_evict_restarts_prompt_deterministically() {
        // All-prompt workload against the tightest legal pool: chunked
        // prompts collide mid-ingestion, so some evictions must land
        // while a prompt is partially cached — and every fingerprint
        // still matches the unbatched prompt-aware oracle.
        let cfg = ServeConfig {
            pool_blocks: 5,
            max_gen_len: 12,
            ..tiny_cfg()
        };
        let mut sched = Scheduler::new(cfg.clone()).unwrap();
        let reqs: Vec<Request> = (0..6).map(|i| Request {
            id: i,
            seed: 0xC0FFEE + i,
            gen_len: 6,
            prompt_len: 8, // two chunks at block_tokens = 4
            prompt_seed: 0x5EED + i,
        }).collect();
        for r in &reqs {
            sched.submit(*r).unwrap();
        }
        let mut responses = Vec::new();
        while sched.has_work() {
            responses.extend(sched.step());
        }
        assert_eq!(responses.len(), reqs.len());
        assert!(sched.metrics.counter("evicted_prefill") > 0,
                "no eviction landed mid-prefill — the test is not \
                 exercising prompt restarts");
        for r in &responses {
            let want = single_request_fingerprint(
                &cfg, &reqs[r.id as usize]).unwrap();
            assert_eq!(r.fingerprint, want,
                       "request {} (evicted {}×) diverged after \
                        prompt restart", r.id, r.evictions);
        }
        assert_eq!(sched.free_blocks(), sched.capacity_blocks());
    }

    #[test]
    fn prompt_phase_changes_the_fingerprint() {
        let cfg = tiny_cfg();
        let with = Request { id: 0, seed: 3, gen_len: 4,
                             prompt_len: 5, prompt_seed: 9 };
        let without = decode_req(0, 3, 4);
        let a = single_request_fingerprint(&cfg, &with).unwrap();
        let b = single_request_fingerprint(&cfg, &without).unwrap();
        assert_ne!(a, b, "prompt rows must be part of the identity");
    }

    #[test]
    fn identical_runs_are_identical() {
        let run = || {
            let mut s = Scheduler::new(ServeConfig {
                pool_blocks: 5,
                ..tiny_cfg()
            }).unwrap();
            let rs = s.run_synthetic(10, 7).unwrap();
            rs.iter().map(|r| (r.id, r.ticket, r.steps, r.fingerprint))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn submit_rejects_out_of_range_gen_and_prompt_len() {
        let mut s = Scheduler::new(tiny_cfg()).unwrap();
        assert!(s.submit(decode_req(0, 1, 0)).is_err());
        assert!(s.submit(decode_req(0, 1, 13)).is_err());
        assert!(s.submit(decode_req(0, 1, 12)).is_ok());
        // prompt_len above the configured bound is a named error
        let e = s.submit(Request { id: 1, seed: 1, gen_len: 4,
                                   prompt_len: 9, prompt_seed: 0 })
            .unwrap_err().to_string();
        assert!(e.contains("prompt_len"), "{e}");
        assert!(s.submit(Request { id: 1, seed: 1, gen_len: 4,
                                   prompt_len: 8, prompt_seed: 0 })
            .is_ok());
    }

    #[test]
    fn continuous_batching_admits_mid_run() {
        let mut s = Scheduler::new(tiny_cfg()).unwrap();
        s.submit(decode_req(0, 10, 8)).unwrap();
        // first step admits and decodes request 0 alone
        assert!(s.step().is_empty());
        assert_eq!(s.running(), 1);
        // a late arrival joins the running batch at the next boundary
        s.submit(decode_req(1, 11, 2)).unwrap();
        assert!(s.step().is_empty());
        assert_eq!(s.running(), 2);
        // request 1 (2 steps) retires while request 0 keeps going
        let done = s.step();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert_eq!(s.running(), 1);
        while s.has_work() {
            s.step();
        }
        assert_eq!(s.free_blocks(), s.capacity_blocks());
    }

    #[test]
    fn prefill_interleaves_with_decode_chunk_by_chunk() {
        let mut s = Scheduler::new(tiny_cfg()).unwrap();
        // 8-token prompt over 4-token blocks: two prefill steps
        // before the first decode token is produced.
        s.submit(Request { id: 0, seed: 2, gen_len: 3,
                           prompt_len: 8, prompt_seed: 7 }).unwrap();
        s.submit(decode_req(1, 5, 1)).unwrap();
        // step 1: request 0 ingests chunk 1, request 1 decodes & retires
        let done = s.step();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert_eq!(s.metrics.counter("prefill_chunks"), 1);
        // step 2: chunk 2 completes the prompt (still no decode token)
        assert!(s.step().is_empty());
        assert_eq!(s.metrics.counter("prefill_chunks"), 2);
        // three decode steps retire request 0
        assert!(s.step().is_empty());
        assert!(s.step().is_empty());
        let done = s.step();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 0);
        assert_eq!(done[0].prompt_len, 8);
        assert_eq!(s.free_blocks(), s.capacity_blocks());
    }

    #[test]
    fn request_line_parsing() {
        let cfg = tiny_cfg();
        let r = parse_request_line(
            "{\"id\": 3, \"seed\": 9, \"gen_len\": 5}", &cfg).unwrap();
        assert_eq!(r, Request { id: 3, seed: 9, gen_len: 5,
                                prompt_len: 0, prompt_seed: 9 });
        // omitted fields: seed ← id, gen_len ← default_gen_len,
        // prompt_seed ← seed
        let r = parse_request_line("{\"id\": 4}", &cfg).unwrap();
        assert_eq!(r, Request { id: 4, seed: 4, gen_len: 12,
                                prompt_len: 0, prompt_seed: 4 });
        let r = parse_request_line(
            "{\"id\":1,\"prompt_len\":6,\"prompt_seed\":42}", &cfg)
            .unwrap();
        assert_eq!(r.prompt_len, 6);
        assert_eq!(r.prompt_seed, 42);
        assert!(parse_request_line("not json", &cfg).is_err());
        assert!(parse_request_line("{\"seed\": 1}", &cfg).is_err());
        assert!(parse_request_line("{\"id\":1,\"gen_len\":0}", &cfg)
            .is_err());
        // prompt_len beyond the configured bound is a named error
        let e = parse_request_line("{\"id\":1,\"prompt_len\":9}", &cfg)
            .unwrap_err().to_string();
        assert!(e.contains("prompt_len"), "{e}");
        // oversized lines are shed before any field parsing
        let garbage = format!("{{\"id\": 1, \"pad\": \"{}\"}}",
                              "x".repeat(MAX_REQUEST_LINE_BYTES));
        let e = parse_request_line(&garbage, &cfg)
            .unwrap_err().to_string();
        assert!(e.contains("line"), "{e}");
    }

    #[test]
    fn inbox_offer_enforces_the_cap() {
        let mut q = std::collections::VecDeque::new();
        let mut shed = 0u64;
        assert!(inbox_offer(&mut q, &mut shed, 2, 'a'));
        assert!(inbox_offer(&mut q, &mut shed, 2, 'b'));
        assert!(!inbox_offer(&mut q, &mut shed, 2, 'c'));
        assert_eq!(q.len(), 2);
        assert_eq!(shed, 1);
        // draining frees a slot for the next offer
        q.pop_front();
        assert!(inbox_offer(&mut q, &mut shed, 2, 'd'));
        assert_eq!(shed, 1);
    }
}
