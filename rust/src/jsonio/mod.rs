//! Minimal JSON encode/decode — substrate for artifact manifests, metrics
//! dumps, and bench reports.
//!
//! The build environment has no `serde`; this is a small, strict RFC-8259
//! subset parser (sufficient for everything `aot.py` emits): UTF-8 input,
//! `\uXXXX` escapes decoded (surrogate pairs included), numbers parsed as
//! f64, no trailing commas, no comments.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// JSON `true` / `false`.
    Bool(bool),
    /// Any JSON number (parsed as f64).
    Num(f64),
    /// A JSON string (escapes decoded).
    Str(String),
    /// A JSON array.
    Arr(Vec<Value>),
    /// Object with insertion-order-independent (sorted) key lookup.
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Boolean payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric payload truncated to an integer.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    /// Non-negative numeric payload as a usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array payload, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object payload, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

/// Parse error with byte offset for debugging malformed manifests.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    /// Byte offset the parser stopped at.
    pub offset: usize,
    /// What went wrong there.
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError { offset: self.pos, message: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            self.err(format!("expected {:?}", b as char))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(format!("expected {word}"))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte {:?}", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.err("expected ',' or '}'");
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.err("expected ',' or ']'");
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return self.err("invalid low surrogate");
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        match char::from_u32(cp) {
                            Some(c) => out.push(c),
                            None => return self.err("invalid code point"),
                        }
                    }
                    _ => return self.err("invalid escape"),
                },
                Some(b) if b < 0x20 => return self.err("control char in string"),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if len == 0 || end > self.bytes.len() {
                        return self.err("invalid utf-8");
                    }
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.pos = end;
                        }
                        Err(_) => return self.err("invalid utf-8"),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = match self.bump() {
                Some(c) => c,
                None => return self.err("truncated \\u escape"),
            };
            match (c as char).to_digit(16) {
                Some(d) => v = v * 16 + d,
                None => return self.err("bad hex digit"),
            }
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(n) => Ok(Value::Num(n)),
            Err(_) => self.err(format!("bad number {s:?}")),
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 0,
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

/// Serialize a value to compact JSON.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(&mut s, v);
    s
}

fn write_value(out: &mut String, v: &Value) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_num(out, *n),
        Value::Str(s) => write_str(out, s),
        Value::Arr(a) => {
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(out, e);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, e)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(out, k);
                out.push(':');
                write_value(out, e);
            }
            out.push('}');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            let _ = write!(out, "{n}");
        }
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder: an object value from `(key, value)` pairs.
pub fn obj(entries: Vec<(&str, Value)>) -> Value {
    Value::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience builder: a numeric value.
pub fn num(n: f64) -> Value {
    Value::Num(n)
}

/// Convenience builder: a string value.
pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-0.5e2").unwrap(), Value::Num(-50.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\t\"c\" é 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" é 😀"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = parse("\"héllo — 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — 世界"));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "\"unterminated",
                    "1 2", "{\"a\" 1}"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"b":false,"n":null,"o":{"k":-3}}"#;
        let v = parse(src).unwrap();
        assert_eq!(to_string(&v), src);
    }

    #[test]
    fn escapes_on_write() {
        let v = Value::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(to_string(&v), "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn big_ints_stay_exact() {
        // flops counters are large; make sure we don't print exponents
        let v = Value::Num(549755813888.0); // 2^39
        assert_eq!(to_string(&v), "549755813888");
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 3, "s": "x", "b": true, "a": []}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("n").unwrap().as_i64(), Some(3));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert!(v.get("a").unwrap().as_arr().unwrap().is_empty());
        assert_eq!(v.get("missing"), None);
        assert_eq!(v.get("s").unwrap().as_f64(), None);
    }
}
