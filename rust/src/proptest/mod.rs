//! Minimal property-testing framework (no `proptest` crate offline).
//!
//! Seeded generators + a runner that, on failure, reports the case index
//! and the generator seed so any counterexample is reproducible with
//! `SPARK_PROPTEST_SEED`.  No integrated shrinking — generators are asked
//! to produce *small-biased* values instead (sufficient for coordinator
//! invariants and attention algebra, our two uses).

use crate::tensor::Rng;

/// Number of cases per property (override with SPARK_PROPTEST_CASES).
pub fn default_cases() -> usize {
    std::env::var("SPARK_PROPTEST_CASES").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("SPARK_PROPTEST_SEED").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(0x5EED_CAFE)
}

/// A value generator: draws from an `Rng`.
pub trait Gen {
    /// The type of value this generator produces.
    type Value;
    /// Draw one value from the generator.
    fn generate(&self, rng: &mut Rng) -> Self::Value;
}

/// usize in [lo, hi], biased toward the low end (≈ shrunken cases).
pub struct USize {
    /// Inclusive lower bound.
    pub lo: usize,
    /// Inclusive upper bound.
    pub hi: usize,
}

impl Gen for USize {
    type Value = usize;

    fn generate(&self, rng: &mut Rng) -> usize {
        debug_assert!(self.lo <= self.hi);
        let span = self.hi - self.lo + 1;
        // square the uniform draw: density concentrates near lo
        let u = rng.uniform();
        self.lo + ((u * u * span as f64) as usize).min(span - 1)
    }
}

/// Pick uniformly from a fixed set (block sizes, dtypes, …).
pub struct OneOf<T: Clone>(pub Vec<T>);

impl<T: Clone> Gen for OneOf<T> {
    type Value = T;

    fn generate(&self, rng: &mut Rng) -> T {
        self.0[rng.below(self.0.len())].clone()
    }
}

/// f32 in [lo, hi].
pub struct F32 {
    /// Inclusive lower bound.
    pub lo: f32,
    /// Inclusive upper bound.
    pub hi: f32,
}

impl Gen for F32 {
    type Value = f32;

    fn generate(&self, rng: &mut Rng) -> f32 {
        rng.range_f64(self.lo as f64, self.hi as f64) as f32
    }
}

/// Vec of standard normals with generated length.
pub struct NormalVec {
    /// Generator for the vector length.
    pub len: USize,
}

impl Gen for NormalVec {
    type Value = Vec<f32>;

    fn generate(&self, rng: &mut Rng) -> Vec<f32> {
        let n = self.len.generate(rng);
        rng.normal_vec(n)
    }
}

/// Run `prop` over `cases` generated inputs; panic with a reproducible
/// seed report on the first failure.
pub fn check<G: Gen>(name: &str, gen: &G, cases: usize,
                     mut prop: impl FnMut(G::Value) -> Result<(), String>) {
    let seed0 = base_seed();
    for case in 0..cases {
        let seed = seed0 ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let value = gen.generate(&mut rng);
        if let Err(msg) = prop(value) {
            panic!(
                "property {name:?} failed at case {case}/{cases}: {msg}\n\
                 reproduce with SPARK_PROPTEST_SEED={seed0} (case seed {seed})");
        }
    }
}

/// Two-generator convenience.
pub fn check2<A: Gen, B: Gen>(
    name: &str, ga: &A, gb: &B, cases: usize,
    mut prop: impl FnMut(A::Value, B::Value) -> Result<(), String>) {
    let seed0 = base_seed();
    for case in 0..cases {
        let seed = seed0 ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let a = ga.generate(&mut rng);
        let b = gb.generate(&mut rng);
        if let Err(msg) = prop(a, b) {
            panic!(
                "property {name:?} failed at case {case}/{cases}: {msg}\n\
                 reproduce with SPARK_PROPTEST_SEED={seed0}");
        }
    }
}

/// Assertion helper: approximate equality with context.
pub fn approx_eq(a: f32, b: f32, tol: f32, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usize_respects_bounds_and_biases_low() {
        let g = USize { lo: 4, hi: 64 };
        let mut rng = Rng::new(1);
        let mut low = 0;
        for _ in 0..1000 {
            let v = g.generate(&mut rng);
            assert!((4..=64).contains(&v));
            if v < 20 {
                low += 1;
            }
        }
        assert!(low > 500, "low-bias expected, got {low}/1000 below 20");
    }

    #[test]
    fn oneof_covers_choices() {
        let g = OneOf(vec!["a", "b", "c"]);
        let mut rng = Rng::new(2);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..100 {
            seen.insert(g.generate(&mut rng));
        }
        assert_eq!(seen.len(), 3);
    }

    #[test]
    fn check_passes_good_property() {
        check("sum-commutes", &USize { lo: 0, hi: 100 }, 32, |n| {
            if n + 1 == 1 + n {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "reproduce with SPARK_PROPTEST_SEED")]
    fn check_reports_seed_on_failure() {
        check("always-fails", &USize { lo: 0, hi: 10 }, 8,
              |_| Err("nope".into()));
    }

    #[test]
    fn approx_eq_tolerance() {
        assert!(approx_eq(1.0, 1.005, 0.01, "x").is_ok());
        assert!(approx_eq(1.0, 1.5, 0.01, "x").is_err());
    }

    #[test]
    fn cases_deterministic_per_seed() {
        let g = NormalVec { len: USize { lo: 1, hi: 8 } };
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        assert_eq!(g.generate(&mut r1), g.generate(&mut r2));
    }
}
