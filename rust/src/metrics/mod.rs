//! Run-time metrics: counters, gauges, and streaming timing statistics.
//!
//! The coordinator and bench harness record into a `Registry`; reports are
//! emitted as JSON (`jsonio`) or human tables.  Timing stats keep the full
//! sample vector (runs are short) so p50/p95 are exact, not sketched.

use std::collections::BTreeMap;

use crate::jsonio::{self, Value};

/// Streaming summary of one timing series (seconds).
#[derive(Debug, Clone, Default)]
pub struct Series {
    samples: Vec<f64>,
}

impl Series {
    /// Append one sample (seconds).
    pub fn record(&mut self, secs: f64) {
        self.samples.push(secs);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Smallest sample (+∞ when empty).
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample (−∞ when empty).  Folds from `NEG_INFINITY`, not
    /// `0.0`: a series of all-negative samples (e.g. a delta gauge
    /// promoted to a series) must report its true maximum, never a
    /// phantom `0.0` that was never recorded.
    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact percentile by sorting a copy (fine for bench-scale counts).
    ///
    /// Sorts with `total_cmp`, so NaN samples are ordered (after +∞)
    /// instead of panicking mid-report the way `partial_cmp().unwrap()`
    /// did — a single poisoned sample shifts the top percentiles toward
    /// NaN but can never take down the registry dump that would have
    /// told you about it.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v = self.samples.clone();
        v.sort_by(f64::total_cmp);
        let idx = ((v.len() - 1) as f64 * p.clamp(0.0, 1.0)).round() as usize;
        v[idx]
    }

    /// Median.
    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    /// 95th percentile.
    pub fn p95(&self) -> f64 {
        self.percentile(0.95)
    }

    /// 99th percentile — the serving tail-latency headline.
    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64).sqrt()
    }

    /// Summary statistics as a JSON object.
    pub fn to_json(&self) -> Value {
        jsonio::obj(vec![
            ("count", jsonio::num(self.count() as f64)),
            ("mean_s", jsonio::num(self.mean())),
            ("p50_s", jsonio::num(self.p50())),
            ("p95_s", jsonio::num(self.p95())),
            ("p99_s", jsonio::num(self.p99())),
            ("min_s", jsonio::num(self.min())),
            ("max_s", jsonio::num(self.max())),
            ("stddev_s", jsonio::num(self.stddev())),
        ])
    }
}

/// Named counters + gauges + timing series.
#[derive(Debug, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    series: BTreeMap<String, Series>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `by` to counter `name` (created at 0).
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Current value of counter `name` (0 if never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Set gauge `name` to `v` (last write wins).
    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Current value of gauge `name`.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Record one timing sample into series `name`.
    pub fn time(&mut self, name: &str, secs: f64) {
        self.series.entry(name.to_string()).or_default().record(secs);
    }

    /// Timing series `name`, if any samples were recorded.
    pub fn series(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    /// Full JSON dump for `--metrics-out`.
    pub fn to_json(&self) -> Value {
        let counters = Value::Obj(self.counters.iter()
            .map(|(k, v)| (k.clone(), jsonio::num(*v as f64))).collect());
        let gauges = Value::Obj(self.gauges.iter()
            .map(|(k, v)| (k.clone(), jsonio::num(*v))).collect());
        let series = Value::Obj(self.series.iter()
            .map(|(k, s)| (k.clone(), s.to_json())).collect());
        jsonio::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("timings", series),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut r = Registry::new();
        r.inc("steps", 1);
        r.inc("steps", 2);
        assert_eq!(r.counter("steps"), 3);
        assert_eq!(r.counter("missing"), 0);
    }

    #[test]
    fn series_stats() {
        let mut s = Series::default();
        for x in [1.0, 2.0, 3.0, 4.0, 100.0] {
            s.record(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 22.0).abs() < 1e-9);
        assert_eq!(s.p50(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
        assert!(s.stddev() > 40.0);
    }

    #[test]
    fn percentiles_on_single_sample() {
        let mut s = Series::default();
        s.record(7.0);
        assert_eq!(s.p50(), 7.0);
        assert_eq!(s.p95(), 7.0);
        assert_eq!(s.stddev(), 0.0);
    }

    // Regression: `max` used to fold from 0.0, so a series whose samples
    // are all negative reported a maximum that was never recorded.
    #[test]
    fn max_of_all_negative_series_is_negative() {
        let mut s = Series::default();
        for x in [-5.0, -1.5, -9.0] {
            s.record(x);
        }
        assert_eq!(s.max(), -1.5);
        assert_eq!(s.min(), -9.0);
    }

    #[test]
    fn empty_series_extremes_are_infinities() {
        let s = Series::default();
        assert_eq!(s.max(), f64::NEG_INFINITY);
        assert_eq!(s.min(), f64::INFINITY);
        assert_eq!(s.percentile(0.99), 0.0);
    }

    // Regression: `percentile` used to sort with
    // `partial_cmp(..).unwrap()`, so one NaN sample panicked any report
    // that touched the series.  `total_cmp` orders NaN after +∞ instead:
    // low percentiles stay real, the top of the distribution goes NaN,
    // and the dump survives to show it.
    #[test]
    fn nan_sample_does_not_panic_percentiles() {
        let mut s = Series::default();
        for x in [3.0, f64::NAN, 1.0, 2.0] {
            s.record(x);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        // sorted [1, 2, 3, NaN]: p50 index round(3·0.5) = 2
        assert_eq!(s.p50(), 3.0);
        assert!(s.p99().is_nan());
        assert!(s.max().is_nan() || s.max() == 3.0);
        // The JSON dump must also survive (non-finite renders as null).
        let _ = s.to_json();
    }

    #[test]
    fn p99_lands_on_the_tail() {
        let mut s = Series::default();
        for i in 0..100 {
            s.record(i as f64);
        }
        assert_eq!(s.p99(), 98.0);
        assert_eq!(s.percentile(1.0), 99.0);
    }

    #[test]
    fn json_dump_shape() {
        let mut r = Registry::new();
        r.inc("execs", 4);
        r.set_gauge("loss", 2.5);
        r.time("step", 0.1);
        let j = r.to_json();
        assert_eq!(j.get("counters").unwrap().get("execs").unwrap().as_i64(),
                   Some(4));
        assert_eq!(j.get("gauges").unwrap().get("loss").unwrap().as_f64(),
                   Some(2.5));
        assert!(j.get("timings").unwrap().get("step").is_some());
    }
}
