//! HBM traffic model + memory-hierarchy schedule simulator.
//!
//! The paper's core I/O argument (§2.3, §3.2): the traditional MHA forward
//! performs **5 HBM reads + 3 writes** (two of each being the N×N S and P
//! matrices), while the fused kernel performs **3 reads + 1 write**.  This
//! module reproduces that claim two independent ways:
//!
//! 1. `analytic_*` — closed-form byte counts per schedule (the numbers
//!    `layouts.py` embeds in the manifest; cross-checked in tests).
//! 2. `simulate_*` — a small event-level simulator that walks the actual
//!    tile schedule (unfused stage-by-stage, or fused block-streaming with
//!    an SRAM/VMEM residency set) and counts HBM transactions.  It exists
//!    so the 5r/3w vs 3r/1w claim is *derived from the schedule*, not just
//!    asserted.
//!
//! Both feed `perfmodel` to project V100-scale behaviour (experiment E5).
//!
//! Structured masks: `analytic_fused_fwd_masked` / `simulate_fused_fwd_masked`
//! account only the tiles the skip-aware streaming enumeration actually
//! touches ([`crate::attention::Mask::tile_counts`] is the shared ground
//! truth), so tiles outside the mask vanish from the traffic counts
//! exactly as they vanish from the pool's task set.

use crate::attention::Mask;
use std::collections::BTreeMap;

/// Element width of the streamed dtype (bf16/fp16 = 2 bytes).
pub const IN_BYTES: usize = 2;
/// Statistics width (f32).
pub const STAT_BYTES: usize = 4;

/// One MHA problem instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MhaShape {
    /// batch × heads (the kernel grid's outer dimension).
    pub bh: usize,
    /// sequence length.
    pub n: usize,
    /// head dimension.
    pub d: usize,
}

impl MhaShape {
    /// Shape from (batch × heads, sequence length, head dim).
    pub fn new(bh: usize, n: usize, d: usize) -> Self {
        MhaShape { bh, n, d }
    }

    /// Bytes of one (bh, n, d) operand tensor.
    pub fn operand_bytes(&self) -> usize {
        self.bh * self.n * self.d * IN_BYTES
    }

    /// Bytes of one materialised (bh, n, n) score tensor.
    pub fn score_bytes(&self) -> usize {
        self.bh * self.n * self.n * IN_BYTES
    }

    /// Bytes of the (bh, n) LSE statistics tensor.
    pub fn stats_bytes(&self) -> usize {
        self.bh * self.n * STAT_BYTES
    }
}

/// Traffic summary in bytes plus logical read/write tensor counts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Traffic {
    /// Bytes read from HBM.
    pub read_bytes: usize,
    /// Bytes written to HBM.
    pub write_bytes: usize,
    /// Number of logical tensor reads (the paper counts "5 reads").
    pub tensor_reads: usize,
    /// Number of logical tensor writes ("3 writes").
    pub tensor_writes: usize,
}

impl Traffic {
    /// Total bytes moved (reads + writes).
    pub fn total_bytes(&self) -> usize {
        self.read_bytes + self.write_bytes
    }
}

/// Closed-form traffic of the **unfused** forward (PyTorch dataflow §2.3):
/// read Q,K → write S; read S → write P; read P,V → write O.
pub fn analytic_unfused_fwd(s: MhaShape) -> Traffic {
    let op = s.operand_bytes();
    let nn = s.score_bytes();
    Traffic {
        //      Q    K    S    P    V
        read_bytes: op + op + nn + nn + op,
        //       S    P    O
        write_bytes: nn + nn + op,
        tensor_reads: 5,
        tensor_writes: 3,
    }
}

/// Closed-form traffic of the **fused** forward (§3.2): read Q,K,V once,
/// write O (+ LSE statistics for the backward).
pub fn analytic_fused_fwd(s: MhaShape) -> Traffic {
    let op = s.operand_bytes();
    Traffic {
        read_bytes: 3 * op,
        write_bytes: op + s.stats_bytes(),
        tensor_reads: 3,
        tensor_writes: 1, // LSE is statistics, not a tensor the paper counts
    }
}

/// Fused forward traffic with the K/V re-streaming factor made explicit:
/// with `n / block_q` Q tiles per head, K and V are re-read once per Q tile
/// (FA2's schedule; SRAM holds one K/V tile at a time).
pub fn analytic_fused_fwd_streamed(s: MhaShape, block_q: usize) -> Traffic {
    let op = s.operand_bytes();
    let sweeps = s.n.div_ceil(block_q.max(1));
    Traffic {
        read_bytes: op + 2 * op * sweeps,
        write_bytes: op + s.stats_bytes(),
        tensor_reads: 3,
        tensor_writes: 1,
    }
}

/// Unfused backward: PyTorch saves S and P from the forward and replays
/// five staged matmuls (Equation 4) with dP/dS round-trips.
pub fn analytic_unfused_bwd(s: MhaShape) -> Traffic {
    let op = s.operand_bytes();
    let nn = s.score_bytes();
    Traffic {
        // reads: P,dO (dV); dO,V (dP); dP,P (dS); dS,K (dQ); dS,Q (dK)
        read_bytes: (nn + op) + (op + op) + (nn + nn) + (nn + op) + (nn + op),
        // writes: dP, dS, dQ, dK, dV
        write_bytes: 2 * nn + 3 * op,
        tensor_reads: 10,
        tensor_writes: 5,
    }
}

/// Fused backward with recomputation (§3.3): reads Q,K,V,O,dO + LSE, writes
/// dQ,dK,dV; the N×N tensors never exist.
pub fn analytic_fused_bwd(s: MhaShape) -> Traffic {
    let op = s.operand_bytes();
    Traffic {
        read_bytes: 5 * op + s.stats_bytes(),
        write_bytes: 3 * op,
        tensor_reads: 5,
        tensor_writes: 3,
    }
}

/// Peak HBM residency (drives OOM: the paper's Fig 10/12 OOM cells).
pub fn peak_resident_bytes(s: MhaShape, fused: bool) -> usize {
    let operands = 4 * s.operand_bytes(); // Q, K, V, O
    if fused {
        operands + s.stats_bytes()
    } else {
        operands + 2 * s.score_bytes() // + S and P
    }
}

// ---------------------------------------------------------------------------
// Schedule simulator
// ---------------------------------------------------------------------------

/// Logical tensors in the simulated address space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Buf {
    /// Query operand.
    Q,
    /// Key operand.
    K,
    /// Value operand.
    V,
    /// Materialised score matrix (unfused only).
    S,
    /// Materialised probability matrix (unfused only).
    P,
    /// Attention output.
    O,
    /// Log-sum-exp statistics.
    Lse,
}

/// A memory-hierarchy simulator: an SRAM residency set over tile-granular
/// accesses.  Anything not resident is fetched from HBM (counted); writes
/// go to HBM unless the tile is marked kernel-local (SRAM scratch).
#[derive(Debug)]
pub struct MemSim {
    /// SRAM capacity in bytes (V100: 128 KiB/SM; TPU: VMEM budget).
    pub sram_bytes: usize,
    resident: BTreeMap<(Buf, usize), usize>, // (buffer, tile idx) -> bytes
    used: usize,
    /// Bytes fetched from HBM so far.
    pub hbm_reads: usize,
    /// Bytes written to HBM so far.
    pub hbm_writes: usize,
}

impl MemSim {
    /// Empty simulator with an SRAM budget.
    pub fn new(sram_bytes: usize) -> Self {
        MemSim { sram_bytes, resident: BTreeMap::new(), used: 0,
                 hbm_reads: 0, hbm_writes: 0 }
    }

    /// Read a tile; counts HBM traffic unless already resident.
    pub fn read(&mut self, buf: Buf, tile: usize, bytes: usize) {
        if !self.resident.contains_key(&(buf, tile)) {
            self.hbm_reads += bytes;
            self.insert(buf, tile, bytes);
        }
    }

    /// Write a tile back to HBM (always traffic) and keep it resident.
    pub fn write(&mut self, buf: Buf, tile: usize, bytes: usize) {
        self.hbm_writes += bytes;
        self.insert(buf, tile, bytes);
    }

    /// Allocate kernel-local scratch (SRAM only; no HBM traffic).
    pub fn scratch(&mut self, buf: Buf, tile: usize, bytes: usize) {
        self.insert(buf, tile, bytes);
    }

    /// Drop a tile from the residency set (frees SRAM).
    pub fn evict(&mut self, buf: Buf, tile: usize) {
        if let Some(b) = self.resident.remove(&(buf, tile)) {
            self.used -= b;
        }
    }

    /// Drop everything (kernel boundary: SRAM does not persist).
    pub fn flush(&mut self) {
        self.resident.clear();
        self.used = 0;
    }

    /// Bytes currently resident in SRAM.
    pub fn sram_used(&self) -> usize {
        self.used
    }

    /// Whether residency ever needs more than the SRAM budget.
    pub fn sram_overflow(&self) -> bool {
        self.used > self.sram_bytes
    }

    fn insert(&mut self, buf: Buf, tile: usize, bytes: usize) {
        if let Some(old) = self.resident.insert((buf, tile), bytes) {
            self.used -= old;
        }
        self.used += bytes;
    }
}

/// Walk the **unfused** forward schedule and count HBM traffic.
///
/// Stage boundaries flush SRAM (separate kernels), so S and P round-trip —
/// this is how the 5r/3w emerges from the schedule rather than by fiat.
pub fn simulate_unfused_fwd(s: MhaShape, sram_bytes: usize) -> Traffic {
    let mut sim = MemSim::new(sram_bytes);
    let op = s.operand_bytes();
    let nn = s.score_bytes();
    // Stage 1: S = Q Kᵀ
    sim.read(Buf::Q, 0, op);
    sim.read(Buf::K, 0, op);
    sim.write(Buf::S, 0, nn);
    sim.flush();
    // Stage 2: P = softmax(S)
    sim.read(Buf::S, 0, nn);
    sim.write(Buf::P, 0, nn);
    sim.flush();
    // Stage 3: O = P V
    sim.read(Buf::P, 0, nn);
    sim.read(Buf::V, 0, op);
    sim.write(Buf::O, 0, op);
    sim.flush();
    Traffic {
        read_bytes: sim.hbm_reads,
        write_bytes: sim.hbm_writes,
        tensor_reads: 5,
        tensor_writes: 3,
    }
}

/// Walk the **fused** forward schedule (Figure 6) and count HBM traffic.
///
/// Grid: (bh, n/block_q) thread blocks; each streams K/V tiles while its
/// Q tile, S/P scratch, and accumulator stay in SRAM.  Returns the traffic
/// plus whether the working set ever exceeded SRAM.
pub fn simulate_fused_fwd(s: MhaShape, block_q: usize, block_k: usize,
                          sram_bytes: usize) -> (Traffic, bool) {
    let mut sim = MemSim::new(sram_bytes);
    let mut overflow = false;
    let q_tile = block_q * s.d * IN_BYTES;
    let kv_tile = block_k * s.d * IN_BYTES;
    let sp_tile = block_q * block_k * STAT_BYTES; // f32 S/P scratch tile
    let acc_tile = block_q * s.d * STAT_BYTES;
    let stat_tile = 2 * block_q * STAT_BYTES;
    let nq = s.n.div_ceil(block_q);
    let nk = s.n.div_ceil(block_k);

    for b in 0..s.bh {
        for iq in 0..nq {
            let qt = b * nq + iq;
            sim.read(Buf::Q, qt, q_tile);
            sim.scratch(Buf::O, qt, acc_tile);
            sim.scratch(Buf::Lse, qt, stat_tile);
            for ik in 0..nk {
                let kt = b * nk + ik;
                sim.read(Buf::K, kt, kv_tile);
                sim.read(Buf::V, kt, kv_tile);
                // S/P tile lives only inside the step (layout transform)
                sim.scratch(Buf::S, 0, sp_tile);
                overflow |= sim.sram_overflow();
                sim.evict(Buf::S, 0);
                // K/V tiles are streamed: evicted after use
                sim.evict(Buf::K, kt);
                sim.evict(Buf::V, kt);
            }
            // final write-back of O (+ statistics for the backward)
            sim.hbm_writes += block_q * s.d * IN_BYTES + block_q * STAT_BYTES;
            sim.flush();
        }
    }
    (Traffic {
        read_bytes: sim.hbm_reads,
        write_bytes: sim.hbm_writes,
        tensor_reads: 3,
        tensor_writes: 1,
    }, overflow)
}

/// Closed-form traffic of the **masked** fused forward under skip-aware
/// tile enumeration: per head, each *live* query tile reads its Q tile
/// and writes its O tile + statistics once, and each *live* (q, k)
/// score tile streams one K and one V tile — tiles outside the mask
/// ([`Mask::tile_live`]) contribute nothing, and a query tile with no
/// live key tile contributes nothing at all (it is never scheduled).
/// Tile bytes use the full block size (the simulator's convention for
/// trailing partial tiles), so with dense masks and dividing blocks
/// this reproduces [`analytic_fused_fwd_streamed`] exactly.
pub fn analytic_fused_fwd_masked(s: MhaShape, mask: &Mask, block_q: usize,
                                 block_k: usize) -> Traffic {
    let c = mask.tile_counts(s.n, block_q, block_k);
    let q_tile = block_q * s.d * IN_BYTES;
    let kv_tile = block_k * s.d * IN_BYTES;
    let o_tile = block_q * s.d * IN_BYTES + block_q * STAT_BYTES;
    Traffic {
        read_bytes: s.bh * (c.live_q_tiles * q_tile + c.live * 2 * kv_tile),
        write_bytes: s.bh * c.live_q_tiles * o_tile,
        tensor_reads: 3,
        tensor_writes: 1,
    }
}

/// Walk the **masked** fused forward schedule: the same block-streaming
/// walk as [`simulate_fused_fwd`], except key tiles outside the mask
/// are never fetched and query tiles with no live key tile are skipped
/// entirely (no Q read, no O write-back) — mirroring the streaming
/// task builders.  With [`Mask::Dense`] this is byte-identical to
/// [`simulate_fused_fwd`]; for every mask it must agree with
/// [`analytic_fused_fwd_masked`] (asserted in tests and the
/// `longseq_sparse` bench).
pub fn simulate_fused_fwd_masked(s: MhaShape, mask: &Mask, block_q: usize,
                                 block_k: usize, sram_bytes: usize)
                                 -> (Traffic, bool) {
    let mut sim = MemSim::new(sram_bytes);
    let mut overflow = false;
    let q_tile = block_q * s.d * IN_BYTES;
    let kv_tile = block_k * s.d * IN_BYTES;
    let sp_tile = block_q * block_k * STAT_BYTES;
    let acc_tile = block_q * s.d * STAT_BYTES;
    let stat_tile = 2 * block_q * STAT_BYTES;
    let nq = s.n.div_ceil(block_q);
    let nk = s.n.div_ceil(block_k);
    let tile_live = |iq: usize, ik: usize| {
        let (q0, k0) = (iq * block_q, ik * block_k);
        mask.tile_live(q0, block_q.min(s.n - q0), k0,
                       block_k.min(s.n - k0))
    };

    for b in 0..s.bh {
        for iq in 0..nq {
            if !(0..nk).any(|ik| tile_live(iq, ik)) {
                continue; // dead query tile: never scheduled at all
            }
            let qt = b * nq + iq;
            sim.read(Buf::Q, qt, q_tile);
            sim.scratch(Buf::O, qt, acc_tile);
            sim.scratch(Buf::Lse, qt, stat_tile);
            for ik in 0..nk {
                if !tile_live(iq, ik) {
                    continue; // dead score tile: K/V never streamed
                }
                let kt = b * nk + ik;
                sim.read(Buf::K, kt, kv_tile);
                sim.read(Buf::V, kt, kv_tile);
                sim.scratch(Buf::S, 0, sp_tile);
                overflow |= sim.sram_overflow();
                sim.evict(Buf::S, 0);
                sim.evict(Buf::K, kt);
                sim.evict(Buf::V, kt);
            }
            sim.hbm_writes += block_q * s.d * IN_BYTES + block_q * STAT_BYTES;
            sim.flush();
        }
    }
    (Traffic {
        read_bytes: sim.hbm_reads,
        write_bytes: sim.hbm_writes,
        tensor_reads: 3,
        tensor_writes: 1,
    }, overflow)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SHAPE: MhaShape = MhaShape { bh: 4, n: 1024, d: 64 };

    #[test]
    fn paper_tensor_counts() {
        let u = analytic_unfused_fwd(SHAPE);
        let f = analytic_fused_fwd(SHAPE);
        assert_eq!((u.tensor_reads, u.tensor_writes), (5, 3));
        assert_eq!((f.tensor_reads, f.tensor_writes), (3, 1));
    }

    #[test]
    fn fused_traffic_is_much_smaller() {
        let u = analytic_unfused_fwd(SHAPE);
        let f = analytic_fused_fwd(SHAPE);
        // At n ≫ d the N×N round-trips dominate: expect ≥ 4× reduction.
        assert!(u.total_bytes() > 4 * f.total_bytes(),
                "unfused {} vs fused {}", u.total_bytes(), f.total_bytes());
    }

    #[test]
    fn traffic_gap_grows_with_sequence_length() {
        let mut last_ratio = 0.0;
        for n in [256, 512, 1024, 2048, 4096] {
            let s = MhaShape::new(4, n, 64);
            let r = analytic_unfused_fwd(s).total_bytes() as f64
                / analytic_fused_fwd(s).total_bytes() as f64;
            assert!(r > last_ratio, "ratio must grow: n={n} r={r}");
            last_ratio = r;
        }
    }

    #[test]
    fn simulator_matches_analytic_unfused() {
        let sim = simulate_unfused_fwd(SHAPE, 128 * 1024);
        let ana = analytic_unfused_fwd(SHAPE);
        assert_eq!(sim.read_bytes, ana.read_bytes);
        assert_eq!(sim.write_bytes, ana.write_bytes);
    }

    #[test]
    fn simulator_matches_analytic_fused_streamed() {
        let (sim, _) = simulate_fused_fwd(SHAPE, 128, 128, 16 << 20);
        let ana = analytic_fused_fwd_streamed(SHAPE, 128);
        assert_eq!(sim.read_bytes, ana.read_bytes);
        assert_eq!(sim.write_bytes, ana.write_bytes);
    }

    #[test]
    fn fused_working_set_fits_sram() {
        // The paper's block sizing must fit the 128 KiB/SM budget…
        let (_, overflow) = simulate_fused_fwd(
            MhaShape::new(1, 2048, 64), 64, 64, 128 * 1024);
        assert!(!overflow, "64×64 tiles must fit 128 KiB SRAM at d=64");
        // …and a deliberately oversized tile must not.
        let (_, overflow) = simulate_fused_fwd(
            MhaShape::new(1, 2048, 128), 1024, 1024, 128 * 1024);
        assert!(overflow, "1024×1024 tiles cannot fit 128 KiB SRAM");
    }

    #[test]
    fn peak_memory_blows_up_only_unfused() {
        let long = MhaShape::new(32, 16384, 64);
        let fused = peak_resident_bytes(long, true);
        let unfused = peak_resident_bytes(long, false);
        // 32 heads × 16384² × 2 B × 2 tensors = 32 GiB of N×N alone
        assert!(unfused > 32 * (1usize << 30));
        assert!(fused < (1usize << 30));
    }

    #[test]
    fn backward_counts() {
        let ub = analytic_unfused_bwd(SHAPE);
        let fb = analytic_fused_bwd(SHAPE);
        assert!(ub.total_bytes() > 2 * fb.total_bytes());
        assert_eq!(fb.tensor_writes, 3); // dQ, dK, dV
    }

    #[test]
    fn masked_dense_reproduces_streamed_closed_form() {
        let ana = analytic_fused_fwd_masked(SHAPE, &Mask::Dense, 128, 128);
        let streamed = analytic_fused_fwd_streamed(SHAPE, 128);
        assert_eq!(ana.read_bytes, streamed.read_bytes);
        assert_eq!(ana.write_bytes, streamed.write_bytes);
        let (sim, _) = simulate_fused_fwd_masked(SHAPE, &Mask::Dense,
                                                 128, 128, 16 << 20);
        let (dense_sim, _) = simulate_fused_fwd(SHAPE, 128, 128, 16 << 20);
        assert_eq!(sim.read_bytes, dense_sim.read_bytes);
        assert_eq!(sim.write_bytes, dense_sim.write_bytes);
    }

    #[test]
    fn masked_simulator_matches_masked_analytic() {
        use crate::attention::BlockLayout;
        let masks = [
            Mask::Dense,
            Mask::Causal,
            Mask::SlidingWindow { w: 1 },
            Mask::SlidingWindow { w: 200 },
            Mask::SlidingWindow { w: 0 },
            Mask::BlockSparse {
                layout: BlockLayout::random(128, SHAPE.n / 128, 30, 5)
                    .unwrap(),
            },
        ];
        for mask in &masks {
            for (bq, bk) in [(128usize, 128usize), (64, 128), (128, 64)] {
                let (sim, _) =
                    simulate_fused_fwd_masked(SHAPE, mask, bq, bk,
                                              16 << 20);
                let ana = analytic_fused_fwd_masked(SHAPE, mask, bq, bk);
                assert_eq!(sim.read_bytes, ana.read_bytes,
                           "mask {mask:?} blocks ({bq},{bk})");
                assert_eq!(sim.write_bytes, ana.write_bytes,
                           "mask {mask:?} blocks ({bq},{bk})");
            }
        }
    }

    #[test]
    fn skipped_tiles_vanish_from_traffic() {
        // a fully-masked problem moves zero bytes
        let zero = analytic_fused_fwd_masked(SHAPE,
                                             &Mask::SlidingWindow { w: 0 },
                                             128, 128);
        assert_eq!(zero.total_bytes(), 0);
        // causal skips ~half the tiles; its K/V streaming must shrink
        // accordingly relative to dense
        let dense = analytic_fused_fwd_masked(SHAPE, &Mask::Dense,
                                              128, 128);
        let causal = analytic_fused_fwd_masked(SHAPE, &Mask::Causal,
                                               128, 128);
        assert!(causal.read_bytes < dense.read_bytes);
        let c = Mask::Causal.tile_counts(SHAPE.n, 128, 128);
        assert!(c.skipped > 0);
        let kv = 128 * SHAPE.d * IN_BYTES;
        assert_eq!(dense.read_bytes - causal.read_bytes,
                   SHAPE.bh * c.skipped * 2 * kv,
                   "every skipped tile must remove exactly one K+V \
                    stream");
    }

    #[test]
    fn window_traffic_scales_linearly_dense_quadratically() {
        let w = 128usize;
        let mut prev_win = 0usize;
        let mut prev_dense = 0usize;
        for n in [2048usize, 4096, 8192] {
            let s = MhaShape::new(1, n, 64);
            let win = analytic_fused_fwd_masked(
                s, &Mask::SlidingWindow { w }, 128, 128);
            let dense = analytic_fused_fwd_masked(s, &Mask::Dense,
                                                  128, 128);
            if prev_win > 0 {
                let wr = win.read_bytes as f64 / prev_win as f64;
                let dr = dense.read_bytes as f64 / prev_dense as f64;
                assert!(wr < 2.5, "window reads must ~double: {wr}");
                assert!(dr > 3.5, "dense reads must ~quadruple: {dr}");
            }
            prev_win = win.read_bytes;
            prev_dense = dense.read_bytes;
        }
    }

    #[test]
    fn memsim_residency() {
        let mut sim = MemSim::new(1000);
        sim.read(Buf::Q, 0, 400);
        sim.read(Buf::Q, 0, 400); // second read: resident, no traffic
        assert_eq!(sim.hbm_reads, 400);
        assert_eq!(sim.sram_used(), 400);
        sim.scratch(Buf::S, 0, 700);
        assert!(sim.sram_overflow());
        sim.evict(Buf::S, 0);
        assert!(!sim.sram_overflow());
        sim.flush();
        assert_eq!(sim.sram_used(), 0);
    }
}
