//! `spark check` — static analysis of the crate's own sources.
//!
//! The repo's correctness story rests on contracts a compiler cannot
//! see: every backend must be bitwise-deterministic across thread
//! counts, `exec::run_pool` is sound only because tasks write disjoint
//! ranges, FMA is confined to the mixed-precision kernels, and every
//! `unsafe` site carries its justification.  This module turns those
//! contracts from reviewer lore into named, individually waivable
//! rules enforced over the crate's sources — dependency-free, built on
//! the lightweight token [`scanner`] rather than a full parser.
//!
//! The rule set lives in [`RULES`]; the semantics of each rule, the
//! waiver grammar, and the companion dynamic check (the pool's
//! write-set race detector) are documented in DESIGN.md §7.
//!
//! **Waivers.**  A finding is suppressed by a comment on the same line
//! or the line directly above:
//!
//! ```text
//! // spark-check: allow(det-hash): why this site is exempt
//! ```
//!
//! The rule id must exist and the reason must be non-empty; a
//! malformed waiver is itself a finding (`waiver-syntax`) and waives
//! nothing, so a typo'd suppression fails the build instead of
//! silently widening it.
//!
//! Entry points: [`check_source`] checks one file (what the fixture
//! tests drive); [`check_tree`] walks the repository (what the
//! `spark check` subcommand and the `spark_check` CI bin drive).

pub mod scanner;

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use self::scanner::{has_token, scan, Line};

/// Static description of one rule, for `spark check --list-rules` and
/// the DESIGN.md invariant table.
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    /// Stable kebab-case identifier — the name used in waivers.
    pub id: &'static str,
    /// One-line summary of the invariant the rule enforces.
    pub summary: &'static str,
}

/// The rule set, in reporting order.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "unsafe-safety",
        summary: "every `unsafe` site carries a SAFETY: (or `# Safety`) \
                  comment",
    },
    RuleInfo {
        id: "feature-gate",
        summary: "files with #[target_feature] kernels must probe \
                  is_x86_feature_detected!",
    },
    RuleInfo {
        id: "det-hash",
        summary: "no HashMap/HashSet anywhere — iteration order is \
                  nondeterministic; use BTreeMap/BTreeSet",
    },
    RuleInfo {
        id: "det-instant",
        summary: "no wall-clock reads (Instant/SystemTime) in \
                  result-affecting modules (exec, attention, tensor)",
    },
    RuleInfo {
        id: "det-thread-id",
        summary: "no thread-identity dependence in result-affecting \
                  modules (exec, attention, tensor)",
    },
    RuleInfo {
        id: "fma-confinement",
        summary: "mul_add / FMA intrinsics only in the mixed-precision \
                  SIMD kernels (exec/simd.rs)",
    },
    RuleInfo {
        id: "allow-justify",
        summary: "#[allow(...)] requires a justification comment",
    },
    RuleInfo {
        id: "waiver-syntax",
        summary: "spark-check waivers must name a known rule and give \
                  a reason (never waivable itself)",
    },
];

/// One rule violation (or malformed waiver).
#[derive(Debug, Clone)]
pub struct Finding {
    /// Path of the offending file, as labelled by the caller.
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    /// Id of the rule that fired — one of [`RULES`].
    pub rule: &'static str,
    /// Human-readable description of the violation.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.message
        )
    }
}

/// Result of checking a single source file.
#[derive(Debug, Default)]
pub struct SourceCheck {
    /// Findings that survived waivers, in line order.
    pub findings: Vec<Finding>,
    /// Number of findings suppressed by well-formed waivers.
    pub waived: usize,
}

/// Result of checking a whole source tree.
#[derive(Debug, Default)]
pub struct Report {
    /// All surviving findings, ordered by file then line.
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// Total findings suppressed by waivers across the tree.
    pub waived: usize,
}

/// The comment prefix that opens a waiver.
const WAIVER_TAG: &str = "spark-check: allow(";

/// Comment markers that satisfy the `unsafe-safety` rule: the in-body
/// convention and the rustdoc section heading used on `unsafe fn`s.
const SAFETY_MARKS: [&str; 2] = ["SAFETY:", "# Safety"];

/// Whether `id` names a rule in [`RULES`].
fn known_rule(id: &str) -> bool {
    RULES.iter().any(|r| r.id == id)
}

/// The `&'static str` id object for a rule name (panics on unknown
/// ids — callers validate with [`known_rule`] first).
fn rule_id(id: &str) -> &'static str {
    RULES
        .iter()
        .find(|r| r.id == id)
        .map(|r| r.id)
        .expect("rule_id called with unknown rule")
}

/// Normalize a file label for path-scoped rules: forward slashes and
/// a leading `/` so `contains("/exec/")` works for relative labels.
fn normalize(label: &str) -> String {
    let mut p = label.replace('\\', "/");
    if !p.starts_with('/') {
        p.insert(0, '/');
    }
    p
}

/// Modules whose code feeds result bytes.  Nondeterminism here breaks
/// the bitwise contract (DESIGN.md §3); elsewhere (bench, logging,
/// coordinator) wall clocks and thread identities are legitimate.
fn result_affecting(norm: &str) -> bool {
    ["/exec/", "/attention/", "/tensor/"]
        .iter()
        .any(|m| norm.contains(m))
}

/// Files allowed to use fused multiply-add: the SIMD kernel module,
/// whose mixed-precision path is *defined* to fuse.  Anywhere else an
/// FMA silently changes f32 rounding and breaks Scalar equivalence.
fn fma_allowed(norm: &str) -> bool {
    norm.ends_with("/exec/simd.rs")
}

/// Whether the comments attached to line `idx` (same line, or a run of
/// comment/attribute lines directly above) satisfy `hit`.
fn attached_comment(
    lines: &[Line],
    idx: usize,
    hit: impl Fn(&Line) -> bool,
) -> bool {
    if hit(&lines[idx]) {
        return true;
    }
    for l in lines[..idx].iter().rev() {
        let code = l.code.trim();
        let attr = code.starts_with("#[") || code.starts_with("#!");
        if !code.is_empty() && !attr {
            // A real code line ends the attached block.
            return false;
        }
        if hit(l) {
            return true;
        }
        if code.is_empty() && l.comment.is_empty() && l.strings.is_empty()
        {
            // A fully blank line detaches the comment above it.
            return false;
        }
    }
    false
}

/// Whether an `unsafe` on line `idx` is documented: a SAFETY: comment
/// or a rustdoc `# Safety` section on the same line or directly above
/// (attributes and doc lines may sit in between).
fn safety_documented(lines: &[Line], idx: usize) -> bool {
    attached_comment(lines, idx, |l| {
        SAFETY_MARKS.iter().any(|m| l.comment.contains(m))
    })
}

/// Whether an allow-attribute on line `idx` has any comment attached —
/// the rule only demands that *some* justification exists.
fn allow_justified(lines: &[Line], idx: usize) -> bool {
    attached_comment(lines, idx, |l| !l.comment.trim().is_empty())
}

/// Parse a waiver out of a comment.  `None` when the comment holds no
/// waiver tag; `Some(Err(why))` for a malformed waiver; `Some(Ok((rule,
/// reason)))` for a well-formed one.
fn parse_waiver(comment: &str) -> Option<Result<(String, String), String>>
{
    let at = comment.find(WAIVER_TAG)?;
    let rest = &comment[at + WAIVER_TAG.len()..];
    let close = match rest.find(')') {
        Some(c) => c,
        None => {
            return Some(Err("unclosed rule name".to_string()));
        }
    };
    let rule = rest[..close].trim().to_string();
    if rule == "waiver-syntax" {
        return Some(Err("the waiver-syntax rule cannot be waived"
            .to_string()));
    }
    if !known_rule(&rule) {
        return Some(Err(format!("unknown rule '{rule}'")));
    }
    let reason = rest[close + 1..]
        .trim_start_matches(|c: char| {
            c == ':' || c == '-' || c.is_whitespace()
        })
        .trim()
        .to_string();
    if reason.is_empty() {
        return Some(Err(format!(
            "waiver for '{rule}' gives no reason"
        )));
    }
    Some(Ok((rule, reason)))
}

/// Check one source file.  `label` is the path used in findings and in
/// path-scoped rules; `text` is the file contents.
pub fn check_source(label: &str, text: &str) -> SourceCheck {
    let lines = scan(text);
    let norm = normalize(label);
    let det = result_affecting(&norm);
    let fma_ok = fma_allowed(&norm);
    let probed = lines
        .iter()
        .any(|l| has_token(&l.code, "is_x86_feature_detected"));

    let mut raw: Vec<Finding> = Vec::new();
    // Well-formed waivers as (line, rule-id) pairs; each suppresses
    // findings of that rule on its own line or the line below.
    let mut waivers: Vec<(usize, String)> = Vec::new();

    let mut push = |raw: &mut Vec<Finding>,
                    n: usize,
                    rule: &str,
                    msg: String| {
        raw.push(Finding {
            file: label.to_string(),
            line: n,
            rule: rule_id(rule),
            message: msg,
        });
    };

    for (idx, l) in lines.iter().enumerate() {
        let n = idx + 1;
        match parse_waiver(&l.comment) {
            Some(Ok((rule, _reason))) => waivers.push((n, rule)),
            Some(Err(why)) => push(
                &mut raw,
                n,
                "waiver-syntax",
                format!("malformed waiver: {why}"),
            ),
            None => {}
        }

        let code = l.code.as_str();
        if has_token(code, "unsafe") && !safety_documented(&lines, idx) {
            push(
                &mut raw,
                n,
                "unsafe-safety",
                "`unsafe` without a SAFETY: comment on the same line \
                 or directly above"
                    .to_string(),
            );
        }
        if has_token(code, "target_feature") && !probed {
            push(
                &mut raw,
                n,
                "feature-gate",
                "#[target_feature] in a file that never calls \
                 is_x86_feature_detected!"
                    .to_string(),
            );
        }
        if has_token(code, "HashMap") || has_token(code, "HashSet") {
            push(
                &mut raw,
                n,
                "det-hash",
                "hash-map iteration order is nondeterministic; use \
                 BTreeMap/BTreeSet"
                    .to_string(),
            );
        }
        if det
            && (has_token(code, "Instant")
                || has_token(code, "SystemTime"))
        {
            push(
                &mut raw,
                n,
                "det-instant",
                "wall-clock read in a result-affecting module"
                    .to_string(),
            );
        }
        if det
            && (has_token(code, "ThreadId")
                || code.contains("thread::current"))
        {
            push(
                &mut raw,
                n,
                "det-thread-id",
                "thread-identity dependence in a result-affecting \
                 module"
                    .to_string(),
            );
        }
        if (has_token(code, "mul_add") || code.contains("fmadd"))
            && !fma_ok
        {
            push(
                &mut raw,
                n,
                "fma-confinement",
                "FMA outside exec/simd.rs changes f32 rounding and \
                 breaks bitwise backend equivalence"
                    .to_string(),
            );
        }
        if (code.contains("#[allow(") || code.contains("#![allow("))
            && !allow_justified(&lines, idx)
        {
            push(
                &mut raw,
                n,
                "allow-justify",
                "#[allow(...)] without a justification comment"
                    .to_string(),
            );
        }
    }

    let mut out = SourceCheck::default();
    for f in raw {
        let waived = f.rule != "waiver-syntax"
            && waivers.iter().any(|(ln, rule)| {
                rule == f.rule && (*ln == f.line || ln + 1 == f.line)
            });
        if waived {
            out.waived += 1;
        } else {
            out.findings.push(f);
        }
    }
    out.findings.sort_by_key(|f| (f.line, f.rule));
    out
}

/// Subtrees of the repo that hold first-party Rust sources.  The
/// vendored shims under `rust/vendor/` are third-party API stand-ins
/// and are deliberately out of scope.
const SCAN_ROOTS: &[&str] =
    &["rust/src", "rust/benches", "rust/tests", "examples", "tools"];

/// Recursively collect `.rs` files under `dir`.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?
    {
        let path = entry
            .with_context(|| format!("reading entry in {}", dir.display()))?
            .path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if matches!(path.extension(), Some(e) if e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Check every first-party `.rs` file under `root` (the repository
/// checkout).  Files are visited in sorted path order so reports are
/// stable; labels in findings are root-relative.
pub fn check_tree(root: &Path) -> Result<Report> {
    let mut files = Vec::new();
    for sub in SCAN_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    if files.is_empty() {
        bail!(
            "no .rs files found under {} — is --root the repo checkout?",
            root.display()
        );
    }
    files.sort();

    let mut report = Report::default();
    for path in &files {
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let label = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let checked = check_source(&label, &text);
        report.files += 1;
        report.waived += checked.waived;
        report.findings.extend(checked.findings);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_hit(label: &str, src: &str) -> Vec<&'static str> {
        let mut ids: Vec<&'static str> = check_source(label, src)
            .findings
            .iter()
            .map(|f| f.rule)
            .collect();
        ids.sort();
        ids.dedup();
        ids
    }

    #[test]
    fn unsafe_requires_safety_comment() {
        let bad = "fn f(p: *const f32) -> f32 {\n\
                   \x20   unsafe { *p }\n}\n";
        assert_eq!(rules_hit("rust/src/exec/x.rs", bad),
                   vec!["unsafe-safety"]);
        let good = "fn f(p: *const f32) -> f32 {\n\
                    \x20   // SAFETY: caller guarantees p is valid.\n\
                    \x20   unsafe { *p }\n}\n";
        assert!(rules_hit("rust/src/exec/x.rs", good).is_empty());
    }

    #[test]
    fn safety_comment_walks_past_attributes_and_docs() {
        let src = "/// Kernel.\n\
                   ///\n\
                   /// # Safety\n\
                   /// Caller upholds the length contract.\n\
                   #[inline]\n\
                   pub unsafe fn k() {}\n\
                   fn probe() { std::is_x86_feature_detected!(\"avx2\"); }\n";
        assert!(rules_hit("rust/src/exec/x.rs", src).is_empty());
    }

    #[test]
    fn waivers_suppress_and_malformed_waivers_report() {
        let waived = "// spark-check: allow(det-hash): fixture only\n\
                      use std::collections::HashMap;\n";
        let c = check_source("rust/src/util.rs", waived);
        assert!(c.findings.is_empty());
        assert_eq!(c.waived, 1);

        let reasonless = "// spark-check: allow(det-hash)\n\
                          use std::collections::HashMap;\n";
        assert_eq!(rules_hit("rust/src/util.rs", reasonless),
                   vec!["det-hash", "waiver-syntax"]);

        let unknown = "// spark-check: allow(no-such-rule): whatever\n";
        assert_eq!(rules_hit("rust/src/util.rs", unknown),
                   vec!["waiver-syntax"]);
    }

    #[test]
    fn det_rules_scope_to_result_affecting_modules() {
        let src = "use std::time::Instant;\n";
        assert_eq!(rules_hit("rust/src/exec/x.rs", src),
                   vec!["det-instant"]);
        assert!(rules_hit("rust/src/bench/mod.rs", src).is_empty());
    }

    #[test]
    fn fma_confined_to_simd_module() {
        let src = "let y = a.mul_add(b, c);\n";
        assert_eq!(rules_hit("rust/src/tensor/mod.rs", src),
                   vec!["fma-confinement"]);
        assert!(rules_hit("rust/src/exec/simd.rs", src).is_empty());
    }

    #[test]
    fn tokens_in_comments_and_strings_do_not_trip() {
        let src = "// HashMap, Instant, unsafe — all commentary.\n\
                   let s = \"HashMap unsafe mul_add\";\n";
        assert!(rules_hit("rust/src/exec/x.rs", src).is_empty());
    }
}
