//! A lightweight line-oriented Rust token scanner for `spark check`.
//!
//! The analyzer's rules match on *identifier tokens in code*, so the
//! scanner's whole job is to split each source line into three views:
//! the code text with comments and literal contents removed, the
//! comment text, and the string-literal contents.  That is enough to
//! keep the rules exact — `Instantiate` in a doc comment never matches
//! the `Instant` token, and a fixture's `"unsafe"` string never trips
//! the unsafety rule — without pulling a real parser into the build.
//!
//! Handled Rust surface: line and doc comments, nested block comments,
//! string literals with escapes, raw strings (`r"…"`, `r#"…"#`, any
//! hash depth), and the char-literal vs lifetime ambiguity at `'`.
//! Everything else passes through as code verbatim.

/// One source line, split into its code, comment, and string parts.
///
/// `code` keeps the original text minus comments, with every string
/// literal collapsed to `""` and every char literal to `''` — so token
/// positions shift but token *identity* is preserved.  Block comments
/// and multi-line strings contribute to every line they span.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Line {
    /// Code text (comments stripped, literal contents removed).
    pub code: String,
    /// Comment text on this line (line, doc, and block comments).
    pub comment: String,
    /// Contents of string literals that end or continue on this line.
    pub strings: Vec<String>,
}

/// Whether `code` contains `word` as a whole identifier token — both
/// neighbours must be non-identifier characters.  This is the exactness
/// the determinism rules need (`Instant` must not match `Instantiate`).
pub fn has_token(code: &str, word: &str) -> bool {
    let ident = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let start = from + pos;
        let end = start + word.len();
        let left_ok = start == 0 || !ident(bytes[start - 1]);
        let right_ok = end == bytes.len() || !ident(bytes[end]);
        if left_ok && right_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

#[derive(Clone, Copy)]
enum Mode {
    /// Ordinary code.
    Code,
    /// Inside a `//` comment (ends at newline).
    LineComment,
    /// Inside a `/* … */` comment, tracking nesting depth.
    Block(u32),
    /// Inside a `"…"` string literal.
    Str,
    /// Inside a raw string, remembering its hash count.
    RawStr(u32),
}

/// Split `text` into per-line code/comment/string views.  Lines are
/// returned in order; `lines[i]` is source line `i + 1`.
pub fn scan(text: &str) -> Vec<Line> {
    let chars: Vec<char> = text.chars().collect();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut lit = String::new();
    let mut mode = Mode::Code;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            match mode {
                Mode::LineComment => mode = Mode::Code,
                Mode::Str | Mode::RawStr(_) => {
                    // multi-line literal: flush this line's fragment
                    cur.strings.push(std::mem::take(&mut lit));
                }
                _ => {}
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    mode = Mode::Block(1);
                    i += 2;
                } else if c == '"' {
                    mode = Mode::Str;
                    cur.code.push_str("\"\"");
                    i += 1;
                } else if c == 'r' && !prev_is_ident(&cur.code) {
                    match raw_str_hashes(&chars, i) {
                        Some(hashes) => {
                            mode = Mode::RawStr(hashes);
                            cur.code.push_str("\"\"");
                            // skip `r`, the hashes, the opening quote
                            i += 2 + hashes as usize;
                        }
                        None => {
                            cur.code.push(c);
                            i += 1;
                        }
                    }
                } else if c == '\'' {
                    i = eat_quote(&chars, i, &mut cur.code);
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            Mode::Block(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    mode = Mode::Block(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    mode = if depth == 1 {
                        Mode::Code
                    } else {
                        Mode::Block(depth - 1)
                    };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    if chars.get(i + 1) == Some(&'\n') {
                        // line-continuation escape: let the newline
                        // branch handle the line break
                        i += 1;
                    } else {
                        if let Some(&esc) = chars.get(i + 1) {
                            lit.push(esc);
                        }
                        i += 2;
                    }
                } else if c == '"' {
                    cur.strings.push(std::mem::take(&mut lit));
                    mode = Mode::Code;
                    i += 1;
                } else {
                    lit.push(c);
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                let h = hashes as usize;
                let closed = c == '"'
                    && (1..=h).all(|k| chars.get(i + k) == Some(&'#'));
                if closed {
                    cur.strings.push(std::mem::take(&mut lit));
                    mode = Mode::Code;
                    i += 1 + h;
                } else {
                    lit.push(c);
                    i += 1;
                }
            }
        }
    }
    if !lit.is_empty() {
        cur.strings.push(lit);
    }
    if !cur.code.is_empty() || !cur.comment.is_empty()
        || !cur.strings.is_empty()
    {
        lines.push(cur);
    }
    lines
}

/// Hash count of a raw string opener at `chars[i] == 'r'` (`r"` → 0,
/// `r#"` → 1, …), or `None` if this `r` starts no raw string.
fn raw_str_hashes(chars: &[char], i: usize) -> Option<u32> {
    let mut hashes = 0u32;
    let mut j = i + 1;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some(hashes)
    } else {
        None
    }
}

/// Whether the last code character could end an identifier — used to
/// tell a raw-string `r"` from an identifier that merely ends in `r`.
fn prev_is_ident(code: &str) -> bool {
    matches!(code.chars().next_back(),
             Some(c) if c.is_ascii_alphanumeric() || c == '_')
}

/// Consume the `'` at `chars[i]`: a char literal (escaped or plain) is
/// skipped and collapsed to `''` in `code`; a lifetime keeps its quote.
/// Returns the index of the next unconsumed character.
fn eat_quote(chars: &[char], i: usize, code: &mut String) -> usize {
    match chars.get(i + 1) {
        // escaped char literal: skip the backslash and its payload,
        // then scan to the closing quote
        Some('\\') => {
            code.push_str("''");
            let mut j = i + 3;
            while j < chars.len() && chars[j] != '\'' {
                j += 1;
            }
            j + 1
        }
        // plain one-char literal `'x'`
        Some(_) if chars.get(i + 2) == Some(&'\'') => {
            code.push_str("''");
            i + 3
        }
        // a lifetime (`'a`, `'static`, `'_`)
        _ => {
            code.push('\'');
            i + 1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_are_split_from_code() {
        let lines = scan("let x = 1; // HashMap here\n/* Instant */\n");
        assert_eq!(lines[0].code.trim(), "let x = 1;");
        assert!(lines[0].comment.contains("HashMap"));
        assert!(!has_token(&lines[0].code, "HashMap"));
        assert!(lines[1].code.trim().is_empty());
        assert!(lines[1].comment.contains("Instant"));
    }

    #[test]
    fn nested_block_comments() {
        let lines = scan("a /* one /* two */ still */ b\n");
        assert_eq!(lines[0].code.replace(' ', ""), "ab");
        assert!(lines[0].comment.contains("two"));
    }

    #[test]
    fn strings_are_extracted_not_matched() {
        let lines = scan("probe(\"avx2\"); let s = \"unsafe\";\n");
        assert_eq!(lines[0].strings, vec!["avx2", "unsafe"]);
        assert!(!has_token(&lines[0].code, "unsafe"));
        assert!(has_token(&lines[0].code, "probe"));
    }

    #[test]
    fn raw_strings_and_escapes() {
        let lines = scan("let a = r#\"x \"quoted\" y\"#;\nlet b = \
                          \"esc \\\" done\";\n");
        assert_eq!(lines[0].strings, vec!["x \"quoted\" y"]);
        assert_eq!(lines[1].strings, vec!["esc \" done"]);
        assert!(!has_token(&lines[0].code, "quoted"));
    }

    #[test]
    fn multiline_strings_keep_line_numbers() {
        let lines = scan("let s = \"first\nsecond\";\nlet t = 1;\n");
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].strings, vec!["first"]);
        assert_eq!(lines[1].strings, vec!["second"]);
        assert!(has_token(&lines[2].code, "t"));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let lines = scan("fn f<'a>(x: &'a str) -> char { '\\'' }\n\
                          let c = '\"'; let d = 'z';\n");
        // lifetimes survive as code, char literal payloads vanish
        assert!(lines[0].code.contains("'a"));
        assert!(!lines[1].code.contains('z'));
        // the '"' char literal must not open a string
        assert!(lines[1].strings.is_empty());
        assert!(has_token(&lines[1].code, "d"));
    }

    #[test]
    fn tokens_match_exactly() {
        assert!(has_token("use std::time::Instant;", "Instant"));
        assert!(!has_token("Instantiate the backend", "Instant"));
        assert!(!has_token("let my_unsafe_flag = 1;", "unsafe"));
        assert!(has_token("unsafe { ptr::read(p) }", "unsafe"));
        assert!(has_token("a.mul_add(b, c)", "mul_add"));
        assert!(!has_token("smul_add(b, c)", "mul_add"));
    }

    #[test]
    fn identifier_ending_in_r_is_not_a_raw_string() {
        let lines = scan("for r in xs { r(\"lit\"); }\n");
        assert!(has_token(&lines[0].code, "for"));
        assert_eq!(lines[0].strings, vec!["lit"]);
    }
}
